"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr: float, decay: float = 0.1, every: int = 30):
    """Paper §VI-B: initial 0.1, ×0.1 every 30 epochs."""
    def fn(step):
        k = jnp.floor(step.astype(jnp.float32) / every)
        return jnp.asarray(lr, jnp.float32) * decay ** k
    return fn


def cosine(lr: float, total: int, final: float = 0.0):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total, 0.0, 1.0)
        return final + 0.5 * (lr - final) * (1 + jnp.cos(jnp.pi * t))
    return fn


def warmup_cosine(lr: float, warmup: int, total: int, final: float = 0.0):
    cos = cosine(lr, max(1, total - warmup), final)
    def fn(step):
        s = step.astype(jnp.float32)
        wu = lr * s / max(1, warmup)
        return jnp.where(s < warmup, wu, cos(s - warmup))
    return fn
