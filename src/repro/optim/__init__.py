from .optimizers import Optimizer, sgd, momentum, adamw  # noqa: F401
from .schedules import constant, cosine, step_decay, warmup_cosine  # noqa: F401
