"""Minimal pytree optimizers (optax-style init/update pairs).

R-FAST composes as the *distribution* layer: the tracked direction ``z``
replaces the raw gradient fed to the local optimizer.  The paper's ResNet
experiments use SGD + momentum 0.9 + weight decay 1e-4; we provide that
plus AdamW for the transformer examples.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr)


def sgd(lr, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        g = _lr_at(lr, step)
        new = jax.tree.map(
            lambda p, gr: p - g * (gr + weight_decay * p), params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    """Polyak heavy-ball, the paper's ResNet-50 setup (β=0.9, wd=1e-4)."""

    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, m, params, step):
        g = _lr_at(lr, step)
        m = jax.tree.map(
            lambda mm, gr, p: beta * mm + gr + weight_decay * p,
            m, grads, params)
        new = jax.tree.map(lambda p, mm: p - g * mm, params, m)
        return new, m

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = jax.tree.map(jnp.zeros_like, params)
        return (z, jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params, step):
        m, v = state
        g = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda mm, gr: b1 * mm + (1 - b1) * gr, m, grads)
        v = jax.tree.map(lambda vv, gr: b2 * vv + (1 - b2) * gr * gr, v, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        new = jax.tree.map(
            lambda p, mm, vv: p - g * (
                (mm / bc1) / (jnp.sqrt(vv / bc2) + eps) + weight_decay * p),
            params, m, v)
        return new, (m, v)

    return Optimizer(init, update)
