"""Pallas TPU flash attention (forward) with online softmax.

Grid: (B, H, num_q_blocks, num_kv_blocks) — the kv dim is the innermost
(sequential) axis; running max / denominator / accumulator live in VMEM
scratch and persist across kv steps.  Causal and sliding-window tiles that
are fully masked are skipped with ``pl.when``.

GQA: the kv head index is ``h // (H // KV)`` in the k/v index maps, so
kv blocks are never materialized per query head.

VMEM per step: (BQ + 2·BK)·D·4 + BQ·D·4 + BQ·BK·4 ≈ 0.6 MB at
BQ=BK=128, D=128 — far under the ~16 MB v5e budget; BQ/BK are tunable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, bq, bk, n_kv):
    kv_i = pl.program_id(3)
    q_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = q_i * bq
    k0 = kv_i * bk

    def body():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = ki <= qi
            if window is not None:
                mask &= ki > qi - window
            s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # tile-level skip: any unmasked (q, k) pair in this tile?
        run = k0 <= q0 + bq - 1
        if window is not None:
            run = jnp.logical_and(run, k0 + bk - 1 > q0 - window)
        pl.when(run)(body)
    else:
        body()

    @pl.when(kv_i == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           bq=128, bk=128, interpret=True):
    """q (B,H,Sq,D); k/v (B,KV,Sk,D) with H % KV == 0.  -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_kv = Sk // bk
    grid = (B, H, Sq // bq, n_kv)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
