"""Pure-jnp oracle for flash attention (causal / sliding-window / full),
with GQA (kv heads broadcast over query-head groups)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q (B,Sq,H,D); k/v (B,Sk,KV,D) with H % KV == 0.  fp32 softmax."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, rep, D)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qf, k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + (k.shape[1] - Sq)
        ki = jnp.arange(k.shape[1])[None, :]
        m = ki <= qi
        if window:
            m &= ki > qi - window
        s = jnp.where(m[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
