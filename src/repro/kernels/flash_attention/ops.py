"""jit'd wrapper: model-layout (B,S,H,D) flash attention with impl switch."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


@partial(jax.jit, static_argnames=("causal", "window", "impl", "interpret",
                                   "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=None, impl="ref",
                    interpret=True, bq=128, bk=128):
    """q (B,Sq,H,D); k/v (B,Sk,KV,D) — the model's natural layout."""
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window)
    o = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
