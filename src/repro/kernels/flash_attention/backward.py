"""Pallas TPU flash attention BACKWARD (two-pass, no S² HBM traffic).

Standard flash-bwd decomposition using the saved fp32 row statistic
lse = m + log l from the forward, plus delta = rowsum(dO ⊙ O):

  p     = exp(q·kᵀ·scale − lse)
  dv   += pᵀ · dO
  dp    = dO · vᵀ
  ds    = p ⊙ (dp − delta) · scale
  dq   += ds · k        (grid over q blocks, sequential over kv blocks)
  dk   += dsᵀ · q       (grid over kv blocks, sequential over q blocks)

Two pallas_calls (dq-kernel, dkv-kernel) so every output is accumulated
in a VMEM scratch owned by exactly one grid slot — no cross-step
read-modify-write of HBM outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_vjp"]

NEG = -1e30


def _mask(q0, k0, bq, bk, causal, window):
    if not causal:
        return None
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


def _p_block(q, k, lse, q0, k0, bq, bk, scale, causal, window):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m = _mask(q0, k0, bq, bk, causal, window)
    if m is not None:
        s = jnp.where(m, s, NEG)
    return jnp.exp(s - lse)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, window, bq, bk, n_kv):
    kv_i = pl.program_id(3)
    q_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0, k0 = q_i * bq, kv_i * bk

    def body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        p = _p_block(q, k, lse, q0, k0, bq, bk, scale, causal, window)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        run = k0 <= q0 + bq - 1
        if window is not None:
            run = jnp.logical_and(run, k0 + bk - 1 > q0 - window)
        pl.when(run)(body)
    else:
        body()

    @pl.when(kv_i == n_kv - 1)
    def _done():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, window, bq, bk, n_q):
    q_i = pl.program_id(3)
    kv_i = pl.program_id(2)

    @pl.when(q_i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q0, k0 = q_i * bq, kv_i * bk

    def body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        p = _p_block(q, k, lse, q0, k0, bq, bk, scale, causal, window)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        run = k0 <= q0 + bq - 1
        if window is not None:
            run = jnp.logical_and(run, k0 + bk - 1 > q0 - window)
        pl.when(run)(body)
    else:
        body()

    @pl.when(q_i == n_q - 1)
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _run_dq(q, k, v, do, lse, delta, *, scale, causal, window, bq, bk,
            interpret):
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    n_kv = Sk // bk
    grid = (B, H, Sq // bq, n_kv)
    kern = functools.partial(_dq_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, n_kv=n_kv)
    qs = pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0))
    ks = pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h, ki, 0))
    rs = pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi))
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[qs, ks, ks, qs, rs, rs],
        out_specs=qs,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)


def _run_dkv(q, k, v, do, lse, delta, *, scale, causal, window, bq, bk,
             interpret):
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    n_q = Sq // bq
    grid = (B, H, Sk // bk, n_q)
    kern = functools.partial(_dkv_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, n_q=n_q)
    qs = pl.BlockSpec((1, 1, bq, D), lambda b, h, ki, qi: (b, h, qi, 0))
    ks = pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h, ki, 0))
    rs = pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi))
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[qs, ks, ks, qs, rs, rs],
        out_specs=(ks, ks),
        out_shape=(jax.ShapeDtypeStruct((B, H, Sk, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, Sk, D), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_vjp(q, k, v, causal=True, window=None, scale=None,
                        bq=128, bk=128, interpret=True):
    """Differentiable flash attention, (B,H,S,D) layout, GQA via caller
    repeat of kv heads (grads flow back through the repeat)."""
    o, _ = _fwd(q, k, v, causal, window, scale, bq, bk, interpret)
    return o


def _fwd(q, k, v, causal, window, scale, bq, bk, interpret):
    """Forward that also returns lse, via the fwd kernel run in fp32
    (reference jnp fwd with streaming over kv blocks would be equally
    valid; we reuse the kernel's math here in jnp for lse exactness)."""
    B, H, Sq, D = q.shape
    scale_ = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32)) * scale_
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(k.shape[2])[None, :]
        m = ki <= qi
        if window is not None:
            m &= ki > qi - window
        s = jnp.where(m[None, None], s, NEG)
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    p = jnp.exp(s - lse[..., None])
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype), (q, k, v, lse, o.astype(jnp.float32))


def _fwd_rule(q, k, v, causal, window, scale, bq, bk, interpret):
    o, res = _fwd(q, k, v, causal, window, scale, bq, bk, interpret)
    return o, res


def _bwd_rule(causal, window, scale, bq, bk, interpret, res, do):
    q, k, v, lse, o = res
    D = q.shape[-1]
    scale_ = scale if scale is not None else D ** -0.5
    dof = do.astype(jnp.float32)
    delta = jnp.sum(dof * o, axis=-1)                    # (B,H,Sq)
    bq_ = min(bq, q.shape[2])
    bk_ = min(bk, k.shape[2])
    kw = dict(scale=scale_, causal=causal, window=window, bq=bq_, bk=bk_,
              interpret=interpret)
    dq = _run_dq(q, k, v, dof, lse, delta, **kw)
    dk, dv = _run_dkv(q, k, v, dof, lse, delta, **kw)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_vjp.defvjp(_fwd_rule, _bwd_rule)
