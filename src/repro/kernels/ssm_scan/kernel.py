"""Pallas TPU kernel: Mamba-1 selective scan, chunked along the sequence.

The recurrence  h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t B_t) u_t ,
y_t = C_t · h_t + D u_t  is sequential in t, so the kernel tiles:

* grid = (B, d_inner / BD, S / CHUNK) with the chunk axis innermost and
  sequential ("arbitrary"); the carry h (BD, N) persists in VMEM scratch
  across chunk steps — HBM traffic is exactly one read of (u, dt, B, C)
  and one write of y; h never leaves VMEM.
* within a chunk, a fori loop applies the recurrence column-by-column on
  a (BD, N) state held in registers/VMEM — the TPU-native replacement for
  the CUDA warp-parallel scan of the original Mamba kernel (VPU lanes
  vectorize over the BD channel dim instead of warps over threads).

VMEM per step: (4·CHUNK·BD + BD·N + CHUNK·N) · 4 B ≈ 1.1 MB at
CHUNK=256, BD=256, N=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssm_scan_pallas"]


def _kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, hout_ref,
            h_ref, *, chunk, n_chunks):
    c_i = pl.program_id(2)

    @pl.when(c_i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...].astype(jnp.float32)             # (BD, N)
    Dp = D_ref[...].astype(jnp.float32)            # (1, BD)

    def step(t, h):
        u_t = u_ref[0, t].astype(jnp.float32)      # (BD,)
        dt_t = dt_ref[0, t].astype(jnp.float32)    # (BD,)
        B_t = B_ref[0, t].astype(jnp.float32)      # (N,)
        C_t = C_ref[0, t].astype(jnp.float32)      # (N,)
        dA = jnp.exp(dt_t[:, None] * A)            # (BD, N)
        h = dA * h + (dt_t * u_t)[:, None] * B_t[None, :]
        y = jnp.sum(h * C_t[None, :], axis=1) + Dp[0] * u_t
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(c_i == n_chunks - 1)
    def _done():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "bd", "interpret"))
def ssm_scan_pallas(u, dt, A, B, C, D, *, chunk=256, bd=256, interpret=True):
    """u/dt (B,S,di); A (di,N); B/C (B,S,N); D (di,).

    Returns (y (B,S,di) fp32, h_last (B,di,N) fp32).
    """
    Bsz, S, di = u.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    bd = min(bd, di)
    assert S % chunk == 0 and di % bd == 0
    n_chunks = S // chunk
    grid = (Bsz, di // bd, n_chunks)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),   # u
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),   # dt
            pl.BlockSpec((bd, N), lambda b, d, c: (d, 0)),             # A
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),    # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),    # C
            pl.BlockSpec((1, bd), lambda b, d, c: (0, d)),             # D
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, c: (b, d, 0)),       # h
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Bsz, S, di), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, di, N), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(u, dt, A, B, C, D[None, :])
    return y, h_last
