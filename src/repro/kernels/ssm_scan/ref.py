"""Pure-jnp oracle for the chunked selective scan — re-exports the model
layer's reference implementation so kernel and model share one oracle."""
from repro.models.ssm import selective_scan_ref  # noqa: F401

__all__ = ["selective_scan_ref"]
