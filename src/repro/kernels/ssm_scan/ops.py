"""jit'd wrapper for the selective scan with impl switch."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssm_scan_pallas
from .ref import selective_scan_ref

__all__ = ["selective_scan"]


@partial(jax.jit, static_argnames=("impl", "interpret", "chunk", "bd"))
def selective_scan(u, dt, A, B, C, D, *, impl="ref", interpret=True,
                   chunk=256, bd=256):
    if impl == "ref":
        return selective_scan_ref(u, dt, A, B, C, D)
    return ssm_scan_pallas(u, dt, A, B, C, D, chunk=chunk, bd=bd,
                           interpret=interpret)
