"""Shape-specialized dispatch cache for the fleet-grid commit kernel.

Every caller of the grid launch (``ops.rfast_commit``, the wavefront and
sweep engines, ``core/protocol.py``'s pallas backend) resolves through
this module: the launch callable is constructed ONCE per static shape
signature — (execution mode, lane count B, p-tile count T, gather
degrees ka/ko, source row counts, source dtypes) — and reused for every
subsequent wave, chunk, seed, and hot-swapped plan that shares the
signature.  Plans padded to common fleet maxima (``schedule.pad_plan`` /
``plan.pad_comm_plan``) deliberately share signatures, so a whole sweep
resolves to one cached launch.

The cache is instrumented: :func:`stats` exposes hit/miss counters
(incremented at trace time, when a caller actually resolves a launch)
and :func:`clear` resets both the cache and the counters, so recompile
bugs surface as a counter assertion in tests instead of a silent
wall-time cliff.

Execution modes (:func:`resolve_mode` maps the engines' tri-state
``interpret`` flag onto them):

* ``"compiled"``  — the real Mosaic TPU launch (``interpret=False``).
* ``"interpret"`` — the Pallas interpreter; orders of magnitude slower
  than XLA on CPU, retained purely as the bit-faithful kernel oracle
  for tests (``interpret=True``).
* ``"emulate"``   — a jnp program with gather/commit semantics identical
  to the grid kernel (same index tables, same blend math).  The CPU
  default: off-TPU benchmarks then measure the grid *architecture*
  (one fused dispatch per wave over flat sources) rather than the
  interpreter's per-operand overhead.

``interpret=None`` (the default everywhere) resolves to ``compiled`` on
TPU and ``emulate`` elsewhere.

Under the mesh-mapped sweep engine the commit runs *inside* a shard_map
region, so the shapes that reach :func:`lookup` are the **local shard
shapes** — lane count ``S_loc·B`` and flat width ``p_pad // M``.  The
key therefore shard-localizes automatically: every device of a wave
resolves the same signature, and a whole mesh-mapped fleet still
compiles to ONE launch per shard shape (pinned by the sweep tests).
"""
from __future__ import annotations

from typing import Callable

import jax

__all__ = ["MODES", "resolve_mode", "lookup", "stats", "clear"]

MODES = ("compiled", "interpret", "emulate")

_cache: dict[tuple, Callable] = {}
_hits = 0
_misses = 0


def resolve_mode(interpret: bool | None) -> str:
    """Map the engines' ``interpret`` tri-state to an execution mode.

    ``True`` → ``"interpret"`` (the oracle), ``False`` → ``"compiled"``
    (force the real launch), ``None`` → autodetect from
    ``jax.default_backend()``: ``compiled`` on TPU, ``emulate`` off it.
    """
    if interpret is True:
        return "interpret"
    if interpret is False:
        return "compiled"
    return "compiled" if jax.default_backend() == "tpu" else "emulate"


def lookup(key: tuple, build: Callable[[], Callable]) -> Callable:
    """Return the cached callable for ``key``, constructing it with
    ``build()`` on the first request.  Counts a hit or a miss."""
    global _hits, _misses
    fn = _cache.get(key)
    if fn is None:
        _misses += 1
        fn = build()
        _cache[key] = fn
    else:
        _hits += 1
    return fn


def stats() -> dict:
    """Current counters: ``{"hits", "misses", "entries"}``.  Misses count
    distinct launch signatures constructed since the last :func:`clear`;
    a steady-state engine loop must not grow them."""
    return {"hits": _hits, "misses": _misses, "entries": len(_cache)}


def clear() -> None:
    """Drop every cached launch and zero the counters (test isolation)."""
    global _hits, _misses
    _cache.clear()
    _hits = 0
    _misses = 0
