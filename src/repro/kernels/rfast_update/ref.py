"""Pure-jnp oracle for the fused R-FAST protocol update (S1, S2a-c, S4).

Operates on flat per-node parameter vectors:

  v      = x − γ z
  x'     = w_self · v + Σ_j w_in[j] · v_in[j]
  recv   = Σ_j m[j] · (rho_in[j] − rho_buf[j])
  z_half = z + recv + g_new − g_old
  z'     = a_self · z_half
  rho_out'[j] = rho_out[j] + a_out[j] · z_half
  rho_buf'[j] = m[j] ? rho_in[j] : rho_buf[j]

Eight elementwise passes over the parameter vector fused into one HBM
sweep by the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rfast_update_ref", "rfast_commit_ref"]


def rfast_update_ref(x, z, g_new, g_old, v_in, w_in, rho_in, rho_buf, mask,
                     rho_out, a_out, *, gamma, w_self, a_self):
    """Shapes: x/z/g_* (P,); v_in (Kw,P); w_in (Kw,);
    rho_in/rho_buf (Ka,P); mask (Ka,); rho_out (Ko,P); a_out (Ko,).
    Returns (x', v, z', rho_out', rho_buf')."""
    f32 = jnp.float32
    xf, zf = x.astype(f32), z.astype(f32)
    v = xf - gamma * zf
    x_new = w_self * v + jnp.einsum("k,kp->p", w_in.astype(f32),
                                    v_in.astype(f32))
    recv = jnp.einsum("k,kp->p", mask.astype(f32),
                      rho_in.astype(f32) - rho_buf.astype(f32))
    z_half = zf + recv + g_new.astype(f32) - g_old.astype(f32)
    z_new = a_self * z_half
    rho_out_new = rho_out.astype(f32) + a_out.astype(f32)[:, None] * z_half
    rho_buf_new = jnp.where(mask[:, None] > 0, rho_in, rho_buf)
    dt = x.dtype
    return (x_new.astype(dt), v.astype(dt), z_new.astype(dt),
            rho_out_new.astype(dt), rho_buf_new.astype(rho_buf.dtype))


def rfast_commit_ref(z, g_new, g_old, rho_in, rho_buf, mask, rho_out, a_out,
                     *, a_self):
    """Commit-only oracle: the S.2b–S.4 tail of :func:`rfast_update_ref`.

    Skips the ``x'``/``v`` outputs (and the x/v_in/w_in inputs that feed
    only them) for callers that commit x⁺ from their own consensus pull —
    the runtime's pallas backend, which discards those writes anyway.
    Returns (z', rho_out', rho_buf')."""
    f32 = jnp.float32
    zf = z.astype(f32)
    recv = jnp.einsum("k,kp->p", mask.astype(f32),
                      rho_in.astype(f32) - rho_buf.astype(f32))
    z_half = zf + recv + g_new.astype(f32) - g_old.astype(f32)
    rho_out_new = rho_out.astype(f32) + a_out.astype(f32)[:, None] * z_half
    rho_buf_new = jnp.where(mask[:, None] > 0, rho_in, rho_buf)
    dt = z.dtype
    return ((a_self * z_half).astype(dt), rho_out_new.astype(dt),
            rho_buf_new.astype(rho_buf.dtype))
