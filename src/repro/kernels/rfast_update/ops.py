"""jit'd public wrapper: flat-vector (and pytree) R-FAST update.

Handles padding/reshaping to the kernel's (R, 128) layout and exposes a
``ref``/``pallas`` switch.  ``impl="pallas"`` resolves through the
three-mode dispatch in :mod:`.dispatch`: ``interpret=None`` (default)
autodetects — the real compiled launch on TPU, the jnp emulation of the
grid data flow elsewhere — ``interpret=True`` forces the Pallas
interpreter (the slow bit-faithful oracle, tests only), and
``interpret=False`` forces a compiled launch.

The commit path (``rfast_commit`` and ``outputs="commit"``) routes
through the fleet-grid kernel (:func:`.grid.commit_grid`) at lane count
B=1 except in interpret mode, which keeps the original per-node kernel
as the oracle.  The full-outputs pallas path has no grid twin; in
emulate mode it falls back to the jnp reference (same math by
construction).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import dispatch
from .grid import block_pad_width, commit_grid
from .kernel import (BLK_R, LANE, rfast_commit_pallas, rfast_update_pallas)
from .ref import rfast_commit_ref, rfast_update_ref

__all__ = ["rfast_update", "rfast_commit", "pad_to_blocks", "unpad"]


def pad_to_blocks(v: jax.Array) -> tuple[jax.Array, int]:
    """(..., P) -> (..., R, 128) with R a multiple of BLK_R."""
    P = v.shape[-1]
    per = BLK_R * LANE
    Pp = -(-P // per) * per
    v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, Pp - P)])
    return v.reshape(*v.shape[:-1], Pp // LANE, LANE), P


def unpad(v: jax.Array, P: int) -> jax.Array:
    return v.reshape(*v.shape[:-2], -1)[..., :P]


@partial(jax.jit, static_argnames=("impl", "interpret", "outputs"))
def rfast_update(x, z, g_new, g_old, v_in, w_in, rho_in, rho_buf, mask,
                 rho_out, a_out, *, gamma, w_self, a_self,
                 impl: str = "ref", interpret: bool | None = None,
                 outputs: str = "full"):
    """Flat-vector protocol update; see ref.py for the math.

    impl="ref" uses the jnp oracle; impl="pallas" the fused kernel.
    outputs="full" returns (x', v, z', rho_out', rho_buf');
    outputs="commit" skips the x'/v streams — and the x/v_in/w_in inputs
    that feed only them — returning (z', rho_out', rho_buf') for callers
    that commit x⁺ from their own consensus pull.
    """
    if outputs not in ("full", "commit"):
        raise ValueError(f"outputs must be 'full' or 'commit', "
                         f"got {outputs!r}")
    if outputs == "commit":
        return rfast_commit(z, g_new, g_old, rho_in, rho_buf, mask, rho_out,
                            a_out, a_self=a_self, impl=impl,
                            interpret=interpret)

    if impl == "ref":
        return rfast_update_ref(
            x, z, g_new, g_old, v_in, w_in, rho_in, rho_buf, mask, rho_out,
            a_out, gamma=gamma, w_self=w_self, a_self=a_self)

    mode = dispatch.resolve_mode(interpret)
    if mode == "emulate":
        # No grid twin for the x'/v streams: the jnp reference IS the
        # emulation (identical expressions, fp32 accumulation).
        return rfast_update_ref(
            x, z, g_new, g_old, v_in, w_in, rho_in, rho_buf, mask, rho_out,
            a_out, gamma=gamma, w_self=w_self, a_self=a_self)

    xb, P = pad_to_blocks(x)
    zb, _ = pad_to_blocks(z)
    gnb, _ = pad_to_blocks(g_new)
    gob, _ = pad_to_blocks(g_old)
    vib, _ = pad_to_blocks(v_in)
    rib, _ = pad_to_blocks(rho_in)
    rbb, _ = pad_to_blocks(rho_buf)
    rob, _ = pad_to_blocks(rho_out)
    scal = jnp.asarray([[gamma, w_self, a_self]], jnp.float32)
    out = rfast_update_pallas(
        xb, zb, gnb, gob, vib, w_in[None].astype(jnp.float32),
        rib, rbb, mask[None].astype(jnp.float32), rob,
        a_out[None].astype(jnp.float32), scal,
        interpret=(mode == "interpret"))
    x_n, v_n, z_n, ro_n, rb_n = out
    return (unpad(x_n, P), unpad(v_n, P), unpad(z_n, P),
            unpad(ro_n, P), unpad(rb_n, P))


@partial(jax.jit, static_argnames=("impl", "interpret"))
def rfast_commit(z, g_new, g_old, rho_in, rho_buf, mask, rho_out, a_out, *,
                 a_self, impl: str = "ref", interpret: bool | None = None):
    """Commit-only protocol update: the S.2b–S.4 tail of
    :func:`rfast_update` without the x'/v streams (see ref.py).
    Returns (z', rho_out', rho_buf')."""
    if impl == "ref":
        return rfast_commit_ref(z, g_new, g_old, rho_in, rho_buf, mask,
                                rho_out, a_out, a_self=a_self)
    mode = dispatch.resolve_mode(interpret)
    if mode == "interpret":
        # Per-node kernel in the Pallas interpreter: the oracle path.
        zb, P = pad_to_blocks(z)
        gnb, _ = pad_to_blocks(g_new)
        gob, _ = pad_to_blocks(g_old)
        rib, _ = pad_to_blocks(rho_in)
        rbb, _ = pad_to_blocks(rho_buf)
        rob, _ = pad_to_blocks(rho_out)
        scal = jnp.asarray([[a_self]], jnp.float32)
        z_n, ro_n, rb_n = rfast_commit_pallas(
            zb, gnb, gob, rib, rbb, mask[None].astype(jnp.float32), rob,
            a_out[None].astype(jnp.float32), scal, interpret=True)
        return unpad(z_n, P), unpad(ro_n, P), unpad(rb_n, P)

    # Grid path at lane count B=1: identity gather tables, one launch.
    ka, P = rho_in.shape
    ko = rho_out.shape[0]
    z1, gn1, go1 = z[None], g_new[None], g_old[None]
    ri, rb, ro = rho_in, rho_buf, rho_out
    if mode == "compiled":
        Pp = block_pad_width(P)
        if Pp != P:
            pad = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1)
                                    + [(0, Pp - P)])
            z1, gn1, go1 = pad(z1), pad(gn1), pad(go1)
            ri, rb, ro = pad(ri), pad(rb), pad(ro)
    zero = jnp.zeros((1,), jnp.int32)
    z_n, ro_n, rb_n = commit_grid(
        zero, zero,
        jnp.arange(ka, dtype=jnp.int32)[None],
        jnp.arange(ka, dtype=jnp.int32)[None],
        jnp.arange(ko, dtype=jnp.int32)[None],
        jnp.asarray(a_self, jnp.float32)[None],
        mask[None], a_out[None],
        z1, gn1, go1, ri, rb, ro, mode=mode)
    return z_n[0, :P], ro_n[0, :, :P], rb_n[0, :, :P]
