"""Pallas TPU kernel: fused R-FAST protocol update.

The protocol inner loop touches 6+ full-parameter arrays; unfused, XLA
emits ~8 separate HBM sweeps (one per elementwise op).  This kernel makes
ONE pass: every operand is tiled into VMEM blocks of (BLK_R, 128) and all
arithmetic happens in registers/VMEM before the single write-back.

Layout: the caller reshapes the flat parameter vector to (R, 128) rows
(padding the tail); neighbour stacks get a leading K dim and are tiled
(K, BLK_R, 128) — K is tiny (tree/ring in-degree 1-2), so VMEM holds
(3 + 2·Ka + Kw + Ko) · BLK_R · 128 · 4 B; BLK_R=256 with K=2 ≈ 1.2 MB,
far under the ~16 MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dispatch

__all__ = ["rfast_update_pallas", "rfast_commit_pallas", "BLK_R", "LANE"]


def _resolve_interpret(interpret: bool | None) -> bool:
    """None → autodetect: real launch on TPU, interpreter elsewhere."""
    if interpret is None:
        return dispatch.resolve_mode(None) != "compiled"
    return bool(interpret)

BLK_R = 256     # rows per block (8-aligned for fp32 sublanes)
LANE = 128      # TPU lane width


def _kernel(scal_ref, w_in_ref, mask_ref, a_out_ref,
            x_ref, z_ref, gn_ref, go_ref, v_in_ref, rho_in_ref, rho_buf_ref,
            rho_out_ref,
            x_o_ref, v_o_ref, z_o_ref, rho_out_o_ref, rho_buf_o_ref):
    gamma = scal_ref[0, 0]
    w_self = scal_ref[0, 1]
    a_self = scal_ref[0, 2]

    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    v = x - gamma * z

    # consensus pull
    x_new = w_self * v
    for k in range(v_in_ref.shape[0]):
        x_new += w_in_ref[0, k] * v_in_ref[k].astype(jnp.float32)

    # robust tracking
    recv = jnp.zeros_like(z)
    for k in range(rho_in_ref.shape[0]):
        m = mask_ref[0, k]
        recv += m * (rho_in_ref[k].astype(jnp.float32)
                     - rho_buf_ref[k].astype(jnp.float32))
    z_half = z + recv + gn_ref[...].astype(jnp.float32) \
        - go_ref[...].astype(jnp.float32)

    x_o_ref[...] = x_new.astype(x_o_ref.dtype)
    v_o_ref[...] = v.astype(v_o_ref.dtype)
    z_o_ref[...] = (a_self * z_half).astype(z_o_ref.dtype)
    for k in range(rho_out_ref.shape[0]):
        rho_out_o_ref[k] = (rho_out_ref[k].astype(jnp.float32)
                            + a_out_ref[0, k] * z_half
                            ).astype(rho_out_o_ref.dtype)
    for k in range(rho_buf_ref.shape[0]):
        m = mask_ref[0, k]
        rho_buf_o_ref[k] = (m * rho_in_ref[k].astype(jnp.float32)
                            + (1.0 - m) * rho_buf_ref[k].astype(jnp.float32)
                            ).astype(rho_buf_o_ref.dtype)


def _commit_kernel(scal_ref, mask_ref, a_out_ref,
                   z_ref, gn_ref, go_ref, rho_in_ref, rho_buf_ref,
                   rho_out_ref,
                   z_o_ref, rho_out_o_ref, rho_buf_o_ref):
    """Commit-only variant: the S.2b–S.4 tail without the x'/v outputs.

    The runtime commits x⁺ from its own consensus pull (the gradient must
    be sampled at that exact point) and discards the full kernel's x'/v
    writes — 2 of its 5 output streams.  This kernel also drops the x and
    (Kw, R, 128) v_in *input* streams the skipped outputs fed, so per
    block it moves (3 + 2·Ka + Ko) tiles in and (1 + Ka + Ko) out versus
    the full kernel's (4 + Kw + 2·Ka + Ko) / (3 + Ka + Ko)."""
    a_self = scal_ref[0, 0]

    z = z_ref[...].astype(jnp.float32)
    recv = jnp.zeros_like(z)
    for k in range(rho_in_ref.shape[0]):
        m = mask_ref[0, k]
        recv += m * (rho_in_ref[k].astype(jnp.float32)
                     - rho_buf_ref[k].astype(jnp.float32))
    z_half = z + recv + gn_ref[...].astype(jnp.float32) \
        - go_ref[...].astype(jnp.float32)

    z_o_ref[...] = (a_self * z_half).astype(z_o_ref.dtype)
    for k in range(rho_out_ref.shape[0]):
        rho_out_o_ref[k] = (rho_out_ref[k].astype(jnp.float32)
                            + a_out_ref[0, k] * z_half
                            ).astype(rho_out_o_ref.dtype)
    for k in range(rho_buf_ref.shape[0]):
        m = mask_ref[0, k]
        rho_buf_o_ref[k] = (m * rho_in_ref[k].astype(jnp.float32)
                            + (1.0 - m) * rho_buf_ref[k].astype(jnp.float32)
                            ).astype(rho_buf_o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rfast_commit_pallas(z, g_new, g_old, rho_in, rho_buf, mask, rho_out,
                        a_out, scalars, *, interpret=None):
    """Commit-only launch: operands as in :func:`rfast_update_pallas`
    minus x/v_in/w_in; scalars (1, 1) = [a_self].
    Returns (z', rho_out', rho_buf')."""
    interpret = _resolve_interpret(interpret)
    R = z.shape[0]
    grid = (R // BLK_R,)
    blk = lambda: pl.BlockSpec((BLK_R, LANE), lambda i: (i, 0))
    blk_k = lambda K: pl.BlockSpec((K, BLK_R, LANE), lambda i: (0, i, 0))
    smem = lambda K: pl.BlockSpec((1, K), lambda i: (0, 0))

    Ka, Ko = rho_in.shape[0], rho_out.shape[0]
    out_shapes = (
        jax.ShapeDtypeStruct(z.shape, z.dtype),           # z'
        jax.ShapeDtypeStruct(rho_out.shape, rho_out.dtype),
        jax.ShapeDtypeStruct(rho_buf.shape, rho_buf.dtype),
    )
    return pl.pallas_call(
        _commit_kernel,
        grid=grid,
        in_specs=[smem(1), smem(Ka), smem(Ko),
                  blk(), blk(), blk(), blk_k(Ka), blk_k(Ka), blk_k(Ko)],
        out_specs=(blk(), blk_k(Ko), blk_k(Ka)),
        out_shape=out_shapes,
        interpret=interpret,
    )(scalars, mask, a_out, z, g_new, g_old, rho_in, rho_buf, rho_out)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rfast_update_pallas(x, z, g_new, g_old, v_in, w_in, rho_in, rho_buf,
                        mask, rho_out, a_out, scalars, *, interpret=None):
    """All 2-D operands shaped (R, 128); stacks (K, R, 128); R % BLK_R == 0.

    scalars: (1, 3) = [gamma, w_self, a_self]; w_in (1, Kw); mask (1, Ka);
    a_out (1, Ko).  Returns (x', v, z', rho_out', rho_buf').
    """
    interpret = _resolve_interpret(interpret)
    R = x.shape[0]
    grid = (R // BLK_R,)
    blk = lambda: pl.BlockSpec((BLK_R, LANE), lambda i: (i, 0))
    blk_k = lambda K: pl.BlockSpec((K, BLK_R, LANE), lambda i: (0, i, 0))
    smem = lambda K: pl.BlockSpec((1, K), lambda i: (0, 0))

    Kw, Ka, Ko = v_in.shape[0], rho_in.shape[0], rho_out.shape[0]
    out_shapes = (
        jax.ShapeDtypeStruct(x.shape, x.dtype),       # x'
        jax.ShapeDtypeStruct(x.shape, x.dtype),       # v
        jax.ShapeDtypeStruct(z.shape, z.dtype),       # z'
        jax.ShapeDtypeStruct(rho_out.shape, rho_out.dtype),
        jax.ShapeDtypeStruct(rho_buf.shape, rho_buf.dtype),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[smem(3), smem(Kw), smem(Ka), smem(Ko),
                  blk(), blk(), blk(), blk(),
                  blk_k(Kw), blk_k(Ka), blk_k(Ka), blk_k(Ko)],
        out_specs=(blk(), blk(), blk(), blk_k(Ko), blk_k(Ka)),
        out_shape=out_shapes,
        interpret=interpret,
    )(scalars, w_in, mask, a_out, x, z, g_new, g_old, v_in, rho_in,
      rho_buf, rho_out)
