"""Fleet-grid R-FAST commit: ONE Pallas launch per wavefront commit.

The per-node commit kernel (:mod:`.kernel`) pays a launch and a
host-side neighbour gather per node per event — ``vmap``-ing it across a
wavefront (or a whole fleet wave) multiplies that overhead by the lane
count.  This module replaces the vmap with a single launch whose grid
spans **(lane, p-tile)**: scalar-prefetched int32 slot tables drive the
``BlockSpec`` index maps, so each grid step gathers its lane's z/g/ρ/ρ̃
block rows directly from the packed state arrays —

* ``z_src``/``go_src`` — the flattened ``(S·n·4, p)`` node state (the
  wavefront engines pass the same array twice; the protocol round passes
  its separate z/g leaves),
* ``ri_src``           — the ``(H·S·e_a, p)`` delta-history rows,
* ``rb_src``/``ro_src`` — the ``(2·S·e_a, p)`` flat ρ/ρ̃ state

— instead of materializing ``(B, k, p)`` neighbour stacks host-side.
Per-lane float parameters (a_self, mask, a_out) ride along as regular
blocked operands (Mosaic scalar prefetch is int32-only).

Three execution modes share this entry point (see
:mod:`.dispatch`): ``compiled`` (the real TPU launch), ``interpret``
(the Pallas-interpreter oracle), and ``emulate`` (a jnp twin with
identical gather tables and blend math — the off-TPU default, so CPU
rows measure the grid data flow, not interpreter overhead).  Launches
are shape-specialized and cached through :func:`.dispatch.lookup`.

Commit math per lane b (identical to :func:`.ref.rfast_commit_ref`):

  recv    = Σ_k mask[b,k] · (ri[b,k] − rb[b,k])
  z_half  = z[b] + recv + g_new[b] − g_old[b]
  z'      = a_self[b] · z_half
  ρ_out'  [k] = ro[b,k] + a_out[b,k] · z_half
  ρ̃'     [k] = mask[b,k] · ri[b,k] + (1 − mask[b,k]) · rb[b,k]

Index tables must be pre-clamped into their source's row range by the
caller (:func:`repro.core.schedule.grid_gather_tables`): drop-sentinel
lanes clamp to a valid row, read garbage weighted by zero, and their
commits are discarded by the caller's drop-mode scatters — exactly the
inertness contract of the jnp wavefront path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import dispatch
from .kernel import BLK_R, LANE

__all__ = ["commit_grid", "block_pad_width"]


def block_pad_width(p: int, shards: int = 1) -> int:
    """Smallest flat width >= p that tiles into (BLK_R, LANE) blocks.

    With ``shards > 1`` the width is additionally a multiple of
    ``shards`` whose *per-shard* slice still tiles into whole blocks, so
    a parameter axis split over a ``model`` mesh axis hands each device a
    launch-compatible local width (``block_pad_width(p, M) // M``).
    """
    per = BLK_R * LANE
    loc = -(-int(p) // int(shards))
    return int(shards) * (-(-loc // per) * per)


def _grid_kernel(ka: int, ko: int):
    """Kernel body for one (lane, p-tile) grid step.  The five prefetch
    refs (consumed by the index maps) arrive first; per-lane floats and
    the gathered (1, BLK_R, LANE) source blocks follow."""

    def kernel(*refs):
        (a_self_ref, mask_ref, a_out_ref,
         z_ref, gn_ref, go_ref, *rest) = refs[5:]
        ri = rest[:ka]
        rb = rest[ka:2 * ka]
        ro = rest[2 * ka:2 * ka + ko]
        z_o, ro_o, rb_o = rest[2 * ka + ko:]

        f32 = jnp.float32
        z = z_ref[0].astype(f32)
        recv = jnp.zeros_like(z)
        for k in range(ka):
            m = mask_ref[0, k]
            recv += m * (ri[k][0].astype(f32) - rb[k][0].astype(f32))
        z_half = z + recv + gn_ref[0].astype(f32) - go_ref[0].astype(f32)

        z_o[0] = (a_self_ref[0, 0] * z_half).astype(z_o.dtype)
        for k in range(ko):
            ro_o[0, k] = (ro[k][0].astype(f32)
                          + a_out_ref[0, k] * z_half).astype(ro_o.dtype)
        for k in range(ka):
            m = mask_ref[0, k]
            rb_o[0, k] = (m * ri[k][0].astype(f32)
                          + (1.0 - m) * rb[k][0].astype(f32)
                          ).astype(rb_o.dtype)

    return kernel


def _lane_map(b, t, iz, ig, iri, irb, iro):
    return (b, 0)


def _z_map(b, t, iz, ig, iri, irb, iro):
    return (iz[b], t, 0)


def _g_map(b, t, iz, ig, iri, irb, iro):
    return (ig[b], t, 0)


def _gn_map(b, t, iz, ig, iri, irb, iro):
    return (b, t, 0)


def _ri_map(k, b, t, iz, ig, iri, irb, iro):
    return (iri[b, k], t, 0)


def _rb_map(k, b, t, iz, ig, iri, irb, iro):
    return (irb[b, k], t, 0)


def _ro_map(k, b, t, iz, ig, iri, irb, iro):
    return (iro[b, k], t, 0)


def _out_z_map(b, t, iz, ig, iri, irb, iro):
    return (b, t, 0)


def _out_k_map(b, t, iz, ig, iri, irb, iro):
    return (b, 0, t, 0)


def _build_launch(B: int, T: int, ka: int, ko: int, dtypes: tuple,
                  interpret: bool):
    """Construct the (B, T)-grid pallas_call for one shape signature."""
    z_dt, ro_dt, rb_dt = dtypes
    R = T * BLK_R
    blk = lambda idx_fn: pl.BlockSpec((1, BLK_R, LANE), idx_fn)
    in_specs = [
        pl.BlockSpec((1, 1), _lane_map),      # a_self
        pl.BlockSpec((1, ka), _lane_map),     # mask
        pl.BlockSpec((1, ko), _lane_map),     # a_out
        blk(_z_map), blk(_gn_map), blk(_g_map),
    ]
    in_specs += [blk(functools.partial(_ri_map, k)) for k in range(ka)]
    in_specs += [blk(functools.partial(_rb_map, k)) for k in range(ka)]
    in_specs += [blk(functools.partial(_ro_map, k)) for k in range(ko)]
    out_specs = (
        blk(_out_z_map),
        pl.BlockSpec((1, ko, BLK_R, LANE), _out_k_map),
        pl.BlockSpec((1, ka, BLK_R, LANE), _out_k_map),
    )
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5, grid=(B, T),
        in_specs=in_specs, out_specs=out_specs)
    return pl.pallas_call(
        _grid_kernel(ka, ko), grid_spec=gs,
        out_shape=(jax.ShapeDtypeStruct((B, R, LANE), z_dt),
                   jax.ShapeDtypeStruct((B, ko, R, LANE), ro_dt),
                   jax.ShapeDtypeStruct((B, ka, R, LANE), rb_dt)),
        interpret=interpret)


def _emulate(idx_z, idx_g, idx_ri, idx_rb, idx_ro, a_self, mask, a_out,
             z_src, g_new, go_src, ri_src, rb_src, ro_src):
    """jnp twin of the grid kernel: same flat-row gather tables, same
    masked blend — an XLA program per launch instead of a kernel, with
    bit-matching semantics (fp32 accumulation over the tiny k axis)."""
    f32 = jnp.float32
    z = z_src[idx_z].astype(f32)                       # (B, Pf)
    go = go_src[idx_g].astype(f32)
    ri = ri_src[idx_ri].astype(f32)                    # (B, ka, Pf)
    rb = rb_src[idx_rb].astype(f32)
    ro = ro_src[idx_ro].astype(f32)
    m = mask.astype(f32)[..., None]
    recv = jnp.sum(m * (ri - rb), axis=1)
    z_half = z + recv + g_new.astype(f32) - go
    z_o = (a_self.astype(f32)[:, None] * z_half).astype(z_src.dtype)
    ro_o = (ro + a_out.astype(f32)[..., None]
            * z_half[:, None]).astype(ro_src.dtype)
    rb_o = (m * ri + (1.0 - m) * rb).astype(rb_src.dtype)
    return z_o, ro_o, rb_o


def commit_grid(idx_z, idx_g, idx_ri, idx_rb, idx_ro,
                a_self, mask, a_out,
                z_src, g_new, go_src, ri_src, rb_src, ro_src,
                *, mode: str | None = None):
    """One fused commit over B lanes gathered from flat source arrays.

    Args:
      idx_z / idx_g: (B,) int32 rows of ``z_src`` / ``go_src``.
      idx_ri: (B, ka) int32 rows of ``ri_src`` (delivered ρ payloads).
      idx_rb: (B, ka) int32 rows of ``rb_src`` (receiver ρ̃ buffers).
      idx_ro: (B, ko) int32 rows of ``ro_src`` (sender ρ running sums).
      a_self: (B,); mask: (B, ka) 0/1; a_out: (B, ko) floats.
      z_src/go_src/ri_src/rb_src/ro_src: (rows, Pf) flat sources —
        aliasing is fine (the engines pass one array several times).
      g_new: (B, Pf) — this lane's fresh gradient, indexed by lane.
      mode: dispatch mode (see :mod:`.dispatch`); None autodetects.
        ``compiled``/``interpret`` require ``Pf`` to be a multiple of
        ``BLK_R·LANE`` (pre-pad with :func:`block_pad_width` — the zero
        tail is inert under the linear commit); ``emulate`` takes any Pf.

    Returns ``(z' (B, Pf), rho_out' (B, ko, Pf), rho_buf' (B, ka, Pf))``
    in the respective source dtypes.  All index tables are clamped into
    their source's row range (drop-sentinel lanes must be discarded by
    the caller's scatters).
    """
    if mode is None:
        mode = dispatch.resolve_mode(None)
    if mode not in dispatch.MODES:
        raise ValueError(f"mode must be one of {dispatch.MODES}, "
                         f"got {mode!r}")
    B, ka = idx_ri.shape
    ko = idx_ro.shape[1]
    Pf = z_src.shape[-1]
    i32 = lambda a, hi: jnp.clip(a.astype(jnp.int32), 0, hi - 1)
    idx_z = i32(idx_z, z_src.shape[0])
    idx_g = i32(idx_g, go_src.shape[0])
    idx_ri = i32(idx_ri, ri_src.shape[0])
    idx_rb = i32(idx_rb, rb_src.shape[0])
    idx_ro = i32(idx_ro, ro_src.shape[0])
    dtypes = (z_src.dtype, ro_src.dtype, rb_src.dtype)

    key = ("commit_grid", mode, B, Pf, ka, ko,
           z_src.shape[0], go_src.shape[0], ri_src.shape[0],
           rb_src.shape[0], ro_src.shape[0],
           tuple(str(d) for d in dtypes), str(g_new.dtype))
    if mode == "emulate":
        fn = dispatch.lookup(key, lambda: _emulate)
        return fn(idx_z, idx_g, idx_ri, idx_rb, idx_ro,
                  a_self, mask, a_out, z_src, g_new, go_src,
                  ri_src, rb_src, ro_src)

    if Pf % (BLK_R * LANE):
        raise ValueError(
            f"mode={mode!r} needs the flat width to tile into "
            f"(BLK_R={BLK_R}, LANE={LANE}) blocks; got Pf={Pf} — pad to "
            f"block_pad_width(Pf)={block_pad_width(Pf)} first")
    T = Pf // (BLK_R * LANE)
    R = T * BLK_R
    launch = dispatch.lookup(
        key, lambda: _build_launch(B, T, ka, ko, dtypes,
                                   interpret=(mode == "interpret")))
    b3 = lambda a: a.reshape(a.shape[0], R, LANE)
    f32 = jnp.float32
    z_o, ro_o, rb_o = launch(
        idx_z, idx_g, idx_ri, idx_rb, idx_ro,
        a_self.astype(f32)[:, None], mask.astype(f32), a_out.astype(f32),
        b3(z_src), b3(g_new), b3(go_src),
        *([b3(ri_src)] * ka), *([b3(rb_src)] * ka), *([b3(ro_src)] * ko))
    return (z_o.reshape(B, Pf), ro_o.reshape(B, ko, Pf),
            rb_o.reshape(B, ka, Pf))
