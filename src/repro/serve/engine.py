"""Continuous-batching decode engine with a compiled-executable cache.

One engine owns a fixed-shape ``(B, C)`` KV ring (``B`` slots × ring
capacity ``C = cache_capacity(cfg, max_len)``) and exactly TWO kinds of
jitted executables, resolved through ``serve.cache``:

* ``("decode", arch, B, C, dtype)`` — one fused
  :func:`~repro.models.transformer.decode_step_slots` step advancing
  every slot at its own position, plus greedy sampling.  ONE executable
  for the engine's whole lifetime.
* ``("prefill", arch, B, C, Sb, dtype)`` — bucketized
  :func:`~repro.models.transformer.prefill_rows` for one slot, with the
  true prompt length AND the target slot as *traced* arguments: one
  executable per prompt-length bucket ``Sb``, shared by every slot and
  every prompt length ≤ ``Sb``.

Parameters enter both as ordinary (non-donated) jit arguments, so a
:class:`~repro.serve.weights.WeightStore` flip changes WHICH buffer the
next step reads without invalidating any executable: steady-state
serving — including serving straight through a live checkpoint swap —
performs ZERO compiles (pinned by ``tests/test_serve.py``).

Slot lifecycle: a request finishing at step ``k`` frees its slot; the
admission phase of step ``k+1`` re-prefills the same batch row while the
other rows keep decoding — no batch-wide restart, no shape change.

Swap modes (checked between decode steps, never inside one):

* ``"drain"`` (default, the paper-loop semantics): once a newer
  checkpoint is staged, admissions pause; in-flight requests finish on
  the old weights; the flip lands on the first step with no in-flight
  work and admissions resume on the new weights.  The *batch* never
  stalls — only the admission queue waits, bounded by the longest
  in-flight generation.
* ``"immediate"``: flip as soon as staged; in-flight requests keep
  their old-weight KV prefix and finish on the new weights (safe —
  see DESIGN.md §14 — and swap latency is one reference assignment).
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig
from . import cache as serve_cache
from .scheduler import Request, Scheduler
from .weights import WeightStore

__all__ = ["ServeEngine", "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS = (4, 8, 16, 32, 64)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, store: WeightStore | Any, *,
                 batch: int = 4, max_len: int = 64,
                 buckets: tuple[int, ...] | None = DEFAULT_BUCKETS,
                 dtype=jnp.float32, swap_mode: str = "drain",
                 poll_every: int = 0, ckpt_dir: str | None = None):
        if cfg.mixer != "attn" or cfg.enc_dec or cfg.frontend:
            raise ValueError(
                f"ServeEngine serves decoder-only attention archs; "
                f"{cfg.name} (mixer={cfg.mixer!r}, enc_dec={cfg.enc_dec}, "
                f"frontend={cfg.frontend!r}) has no bucketized prefill "
                "path — see models.transformer.prefill_rows")
        if swap_mode not in ("drain", "immediate"):
            raise ValueError(f"swap_mode {swap_mode!r} not in "
                             "('drain', 'immediate')")
        self.cfg = cfg
        self.store = store if isinstance(store, WeightStore) \
            else WeightStore(store)
        self.B = int(batch)
        self.max_len = int(max_len)
        self.C = transformer.cache_capacity(cfg, max_len)
        self.buckets = tuple(sorted(buckets)) if buckets else None
        self.dtype = dtype
        self.swap_mode = swap_mode
        self.poll_every = int(poll_every)
        self.ckpt_dir = ckpt_dir

        cache0 = transformer.init_cache(cfg, self.store.params, self.B,
                                        max_len, dtype=dtype)
        self._cache = {
            "idx": jnp.zeros((self.B,), jnp.int32),
            "slot_pos": jnp.full((self.B, self.C), -1, jnp.int32),
            "layers": cache0["layers"],
        }
        self._slot_req: list[Request | None] = [None] * self.B
        self._remaining = np.zeros(self.B, np.int64)
        self._last_tok = np.zeros(self.B, np.int32)
        self._step = 0
        self.step_records: list[dict] = []
        self._t0: float | None = None

    # -- executables ----------------------------------------------------
    def bucket_for(self, sp: int) -> int:
        """Smallest configured bucket >= the prompt length (identity when
        bucketing is disabled — every distinct length then costs a fresh
        executable, which is exactly what the RF205 lint flags)."""
        if self.buckets is None:
            return int(sp)
        for b in self.buckets:
            if sp <= b:
                return b
        raise ValueError(f"prompt length {sp} exceeds largest bucket "
                         f"{self.buckets[-1]}")

    def _decode_exec(self):
        cfg, B, C = self.cfg, self.B, self.C
        key = ("decode", cfg.name, B, C, str(jnp.dtype(self.dtype)))

        def build():
            def f(params, cache, tokens):
                logits, nc = transformer.decode_step_slots(
                    cfg, params, cache, tokens)
                nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
                return nxt, nc
            return jax.jit(f, donate_argnums=(1,))
        return serve_cache.lookup(key, build)

    def _prefill_exec(self, sb: int):
        cfg, C = self.cfg, self.C
        key = ("prefill", cfg.name, self.B, C, int(sb),
               str(jnp.dtype(self.dtype)))

        def build():
            def f(params, cache, slot, tokens, true_len):
                ring, slot_pos, logits = transformer.prefill_rows(
                    cfg, params, tokens[None], true_len, C,
                    dtype=self.dtype)

                def scat(dst, src):
                    return jax.lax.dynamic_update_slice_in_dim(
                        dst, src.astype(dst.dtype), slot, axis=1)
                layers = jax.tree.map(scat, cache["layers"], ring)
                idx = jax.lax.dynamic_update_slice(
                    cache["idx"],
                    jnp.full((1,), true_len, jnp.int32), (slot,))
                sp = jax.lax.dynamic_update_slice(
                    cache["slot_pos"], slot_pos[None], (slot, 0))
                nxt = jnp.argmax(logits[0]).astype(jnp.int32)
                return nxt, {"idx": idx, "slot_pos": sp, "layers": layers}
            return jax.jit(f, donate_argnums=(1,))
        return serve_cache.lookup(key, build)

    # -- lifecycle ------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def _now(self) -> float:
        return 0.0 if self._t0 is None else time.perf_counter() - self._t0

    def _finish(self, slot: int, now: float) -> None:
        req = self._slot_req[slot]
        req.done_step = self._step
        req.done_s = now
        req.weights_step = self.store.step
        req.weights_age_s = (0.0 if self.store.published_at is None
                             else max(0.0, time.time()
                                      - self.store.published_at))
        self._slot_req[slot] = None
        self._remaining[slot] = 0

    def _admit(self, slot: int, req: Request, params, now: float) -> None:
        sp = len(req.prompt)
        sb = self.bucket_for(sp)
        padded = np.zeros(sb, np.int32)
        padded[:sp] = req.prompt
        fn = self._prefill_exec(sb)
        nxt, self._cache = fn(params, self._cache, jnp.int32(slot),
                              jnp.asarray(padded), jnp.int32(sp))
        req.slot = slot
        req.admit_step = self._step
        req.admit_s = now
        req.tokens = [int(nxt)]
        self._slot_req[slot] = req
        self._last_tok[slot] = req.tokens[-1]
        self._remaining[slot] = req.gen - 1
        if self._remaining[slot] <= 0:
            self._finish(slot, now)

    def step(self, sched: Scheduler) -> dict:
        """One engine step: maybe poll/flip, admit into free slots,
        decode every slot once, retire finished requests."""
        t_start = time.perf_counter()
        swap_affected = False

        if (self.poll_every and self.ckpt_dir is not None
                and self._step % self.poll_every == 0):
            if self.store.poll(self.ckpt_dir):
                swap_affected = True
        if self.store.staged and (self.swap_mode == "immediate"
                                  or self.in_flight == 0):
            self.store.flip(at_step=self._step)
            swap_affected = True
        params = self.store.params

        now = self._now()
        admitted = 0
        if not (self.swap_mode == "drain" and self.store.staged):
            for slot in range(self.B):
                if self._slot_req[slot] is not None:
                    continue
                req = sched.pop_ready(now)
                if req is None:
                    break
                self._admit(slot, req, params, now)
                admitted += 1

        active = self.in_flight
        if active:
            fn = self._decode_exec()
            nxt, self._cache = fn(params, self._cache,
                                  jnp.asarray(self._last_tok)[:, None])
            nxt = np.asarray(jax.block_until_ready(nxt))
            now = self._now()
            for slot in range(self.B):
                req = self._slot_req[slot]
                if req is None:
                    continue
                req.tokens.append(int(nxt[slot]))
                self._last_tok[slot] = nxt[slot]
                self._remaining[slot] -= 1
                if self._remaining[slot] <= 0:
                    self._finish(slot, now)

        rec = {"step": self._step,
               "us": (time.perf_counter() - t_start) * 1e6,
               "swap": swap_affected, "active": active,
               "admitted": admitted}
        self.step_records.append(rec)
        self._step += 1
        return rec

    def run(self, requests: list[Request], *,
            max_steps: int = 200_000) -> dict:
        """Drive the engine until every request is served (open-loop:
        the clock starts at the first step and arrivals are honoured
        against wall time).  Returns the serving report."""
        sched = Scheduler(list(requests))
        self._t0 = time.perf_counter()
        served0 = self._step
        while len(sched) or self.in_flight or self.store.staged:
            if self._step - served0 >= max_steps:
                raise RuntimeError(f"serve loop exceeded {max_steps} steps "
                                   f"with {len(sched)} pending")
            if (not self.in_flight and len(sched)
                    and not self.store.staged):
                nxt = sched.next_arrival()
                gap = nxt - self._now()
                if gap > 0:
                    time.sleep(min(gap, 0.05))
            self.step(sched)
        wall = self._now()
        done = [r for r in requests if r.done]
        return {
            "requests": requests,
            "steps": self.step_records[:],
            "wall_s": wall,
            "reqs_per_s": len(done) / wall if wall > 0 else float("inf"),
            "tokens": sum(len(r.tokens) for r in done),
            "swaps": list(self.store.swaps),
            "cache": serve_cache.stats(),
        }
