"""Serving side of the async fleet: continuous-batching decode over
hot-swappable checkpoints (DESIGN.md §14)."""
from . import cache
from .engine import DEFAULT_BUCKETS, ServeEngine
from .scheduler import Request, Scheduler
from .traffic import make_workload
from .weights import WeightStore

__all__ = ["cache", "ServeEngine", "DEFAULT_BUCKETS", "Request",
           "Scheduler", "make_workload", "WeightStore"]
