"""Zipfian open-loop traffic generator.

Reuses ``data/pipeline.zipf_probs`` — the same unigram law the training
corpus is drawn from — for every marginal of the workload: token
content, prompt length, and generation length are all Zipf(s), so the
serving benchmark sees the heavy-tailed mix (many short prompts, a fat
tail of long ones) that makes length bucketing earn its keep.  Arrivals
are open-loop Poisson: inter-arrival gaps are Exponential(rate) drawn up
front, so load does NOT back off when the server falls behind — queueing
delay shows up in the latency percentiles instead of being hidden by a
closed loop.  ``rate_rps=0`` degenerates to a closed backlog (everything
arrives at t=0), which is what the deterministic tests use.
"""
from __future__ import annotations

import numpy as np

from ..data.pipeline import zipf_probs
from .scheduler import Request

__all__ = ["make_workload"]


def make_workload(n_requests: int, *, vocab: int, max_prompt: int,
                  max_gen: int, rate_rps: float = 0.0, s: float = 1.2,
                  seed: int = 0) -> list[Request]:
    if n_requests <= 0:
        return []
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, 0x5E12, n_requests]))
    plen = 1 + rng.choice(max_prompt, size=n_requests,
                          p=zipf_probs(max_prompt, s))
    glen = 1 + rng.choice(max_gen, size=n_requests,
                          p=zipf_probs(max_gen, s))
    tok_p = zipf_probs(vocab, s)
    if rate_rps > 0:
        arrive = np.cumsum(rng.exponential(1.0 / rate_rps,
                                           size=n_requests))
    else:
        arrive = np.zeros(n_requests)
    return [Request(rid=i,
                    prompt=rng.choice(vocab, size=int(plen[i]),
                                      p=tok_p).astype(np.int32),
                    gen=int(glen[i]),
                    arrive_s=float(arrive[i]))
            for i in range(n_requests)]
