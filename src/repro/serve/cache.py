"""Shape-keyed compiled-plan cache for the serving engine.

The serving counterpart of ``kernels/rfast_update/dispatch.py`` — same
contract (``lookup(key, build)`` + instrumented ``stats``/``clear``),
different population: here the cached callables are jitted **decode and
prefill executables**, keyed by

    ("decode",  arch, B, C, dtype)
    ("prefill", arch, B, C, Sb, dtype)

where ``B`` is the fixed batch width, ``C`` the KV ring capacity and
``Sb`` a *bucketized* prompt length (``engine.bucket_for``).  The true
prompt length is a traced argument of the prefill executable, never part
of the key, so every prompt inside a bucket — and every hot-swapped
parameter set, which enters as a donated argument rather than a baked
constant — resolves to the SAME executable.  Steady-state serving
therefore performs ZERO compiles: ``misses`` counts distinct executables
built since :func:`clear`, and the serving tests pin it with
``assert_no_recompiles(cache=serve_cache)``.
"""
from __future__ import annotations

from typing import Callable

__all__ = ["lookup", "stats", "clear"]

_cache: dict[tuple, Callable] = {}
_hits = 0
_misses = 0


def lookup(key: tuple, build: Callable[[], Callable]) -> Callable:
    """Return the cached executable for ``key``, constructing it with
    ``build()`` on the first request.  Counts a hit or a miss."""
    global _hits, _misses
    fn = _cache.get(key)
    if fn is None:
        _misses += 1
        fn = build()
        _cache[key] = fn
    else:
        _hits += 1
    return fn


def stats() -> dict:
    """Current counters: ``{"hits", "misses", "entries"}``.  Misses count
    distinct (arch, shape, bucket) executables built since the last
    :func:`clear`; a steady-state serving loop must not grow them."""
    return {"hits": _hits, "misses": _misses, "entries": len(_cache)}


def clear() -> None:
    """Drop every cached executable and zero the counters (test isolation)."""
    global _hits, _misses
    _cache.clear()
    _hits = 0
    _misses = 0
