"""Double-buffered parameter store with zero-recompile hot swap.

The trainer (``launch/train.py --publish-dir``) publishes checkpoints at
chunk boundaries through ``checkpoint/ckpt.py``'s atomic npz + manifest
protocol.  The server side is this store:

* :meth:`poll` reads ``LATEST.json``; when it names a step newer than
  the active one, the checkpoint is loaded and ``device_put`` into the
  **spare** buffer.  The active buffer — and any decode step currently
  tracing over it — is untouched.
* :meth:`flip` swaps the buffer references.  It is a plain Python
  assignment the engine performs strictly *between* decode steps, so
  the memory-ordering argument is trivial: a dispatched step captured
  the old reference and completes on the old weights; every later step
  reads the new one.  Nothing is mutated in place, nothing recompiles —
  parameters are jit *arguments* with unchanged shapes/dtypes, so the
  executable cache key is identical before and after the swap.

The store records every swap (``swaps``) and exposes the provenance of
the active weights (``step``, ``published_at``) so the engine can stamp
each finished request with the checkpoint age at answer time — the
staleness axis of ``serve/staleness_vs_loss``.
"""
from __future__ import annotations

from typing import Any

import jax

from ..checkpoint import ckpt

__all__ = ["WeightStore"]


class WeightStore:
    def __init__(self, params: Any, *, step: int = -1,
                 published_at: float | None = None):
        self._active = params
        self._spare: Any = None
        self._spare_meta: tuple[int, float] | None = None
        self.step = int(step)
        self.published_at = published_at
        self.polls = 0
        self.loads = 0
        self.swaps: list[dict] = []

    @property
    def params(self) -> Any:
        """The active buffer.  Engines must re-read this property each
        step rather than caching the reference — that re-read IS the
        acquire side of the swap."""
        return self._active

    @property
    def staged(self) -> bool:
        return self._spare_meta is not None

    def offer(self, params: Any, step: int, published_at: float) -> None:
        """Stage an in-memory parameter set into the spare buffer
        (tests and in-process publishers; newer steps only)."""
        if step <= self.step:
            return
        self._spare = params
        self._spare_meta = (int(step), float(published_at))

    def poll(self, ckpt_dir: str) -> bool:
        """Check the manifest; load a newer checkpoint into the spare
        buffer.  Returns True when something was staged.  The load is
        synchronous (manifest read is ~free; the npz read happens only
        on the step that discovers a new checkpoint)."""
        self.polls += 1
        man = ckpt.read_manifest(ckpt_dir)
        if man is None or int(man["step"]) <= self.step:
            return False
        loaded = ckpt.load_checkpoint(ckpt_dir, self._active,
                                      step=int(man["step"]))
        self._spare = jax.device_put(loaded)
        self._spare_meta = (int(man["step"]), float(man["time"]))
        self.loads += 1
        return True

    def flip(self, *, at_step: int = -1) -> bool:
        """Make the staged buffer active (reference swap, between decode
        steps).  Returns True when a swap happened."""
        if self._spare_meta is None:
            return False
        step, published_at = self._spare_meta
        self._active, self._spare = self._spare, None
        self._spare_meta = None
        self.swaps.append({"engine_step": int(at_step),
                           "from": self.step, "to": step})
        self.step = step
        self.published_at = published_at
        return True
