"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain the placeholder devices.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_sweep_mesh", "node_axes_for",
           "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sweep_mesh(*, lanes: int | None = None, param_shards: int = 1,
                    devices=None, lane_axis: str = "data",
                    param_axis: str = "model"):
    """(lane-groups × param-shards) mesh for the mesh-mapped fleet sweep
    (``repro.core.simulator.run_sweep(mesh=...)``).

    Uses however many devices the backend exposes — real accelerators or
    the CPU dev loop's forced host devices
    (:func:`repro.launch.xla_env.force_host_devices` /
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``, which must be
    set before jax initializes its backends).  Defaults: all devices on
    the lane axis, no parameter sharding.  Unlike
    :func:`make_production_mesh` this never *requires* a device count —
    any ``lanes * param_shards <= len(devices)`` prefix works, so the
    same call runs on 1-device CI and a 256-chip pod.
    """
    devices = list(devices) if devices is not None else jax.devices()
    m = int(param_shards)
    if m < 1:
        raise ValueError(f"param_shards must be >= 1, got {m}")
    d = int(lanes) if lanes is not None else max(1, len(devices) // m)
    if d < 1:
        raise ValueError(f"lanes must be >= 1, got {d}")
    if d * m > len(devices):
        raise ValueError(f"mesh {d}x{m} needs {d * m} devices, have "
                         f"{len(devices)} (force more host devices via "
                         "repro.launch.xla_env.force_host_devices)")
    arr = np.array(devices[:d * m]).reshape(d, m)
    return jax.sharding.Mesh(arr, (lane_axis, param_axis))


def node_axes_for(mesh, *, n_nodes: int | None = None) -> tuple[str, ...]:
    """Which mesh axes carry the R-FAST node dimension.

    Default: all non-'model' axes (16 nodes single-pod, 32 multi-pod).
    ``n_nodes`` may select the 'pod'-only variant (nodes span pods, the
    'data' axis is then free for FSDP) — used by the memory hillclimb.
    """
    names = mesh.axis_names
    if n_nodes is None:
        return tuple(a for a in names if a != "model")
    if "pod" in names and n_nodes == mesh.shape["pod"]:
        return ("pod",)
    non_model = tuple(a for a in names if a != "model")
    prod = 1
    for a in non_model:
        prod *= mesh.shape[a]
    if n_nodes == prod:
        return non_model
    raise ValueError(f"unsupported n_nodes={n_nodes} for mesh {names}")


# TPU v5e hardware constants for the roofline (per chip)
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
}
