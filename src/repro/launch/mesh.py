"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain the placeholder devices.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "node_axes_for", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def node_axes_for(mesh, *, n_nodes: int | None = None) -> tuple[str, ...]:
    """Which mesh axes carry the R-FAST node dimension.

    Default: all non-'model' axes (16 nodes single-pod, 32 multi-pod).
    ``n_nodes`` may select the 'pod'-only variant (nodes span pods, the
    'data' axis is then free for FSDP) — used by the memory hillclimb.
    """
    names = mesh.axis_names
    if n_nodes is None:
        return tuple(a for a in names if a != "model")
    if "pod" in names and n_nodes == mesh.shape["pod"]:
        return ("pod",)
    non_model = tuple(a for a in names if a != "model")
    prod = 1
    for a in non_model:
        prod *= mesh.shape[a]
    if n_nodes == prod:
        return non_model
    raise ValueError(f"unsupported n_nodes={n_nodes} for mesh {names}")


# TPU v5e hardware constants for the roofline (per chip)
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
}
