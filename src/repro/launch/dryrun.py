import os

# MUST precede any jax import; appends to the operator's own XLA_FLAGS
# (an explicit operator device count wins).  This is dry-run-only —
# tests/benches see the real single CPU device.
from repro.launch.xla_env import force_host_devices
force_host_devices()

_DOC = """Multi-pod dry-run: lower + compile every (architecture × input shape)
for the production meshes and capture memory / cost / collective data.

Per case:
  1. full config, layers scanned  -> compile proof, memory_analysis()
  2. unrolled L=2 and L=4 configs -> cost_analysis() linear fit in L
     (XLA counts while-loop bodies once, so scanned cost_analysis cannot
     be trusted for totals; the unrolled fit is exact for everything
     linear in depth — model flops, protocol update, collectives)
Artifacts: reports/dryrun/<arch>__<shape>__<mesh>[__<rules>].json

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--rules fsdp]
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.launch import shardings as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, build_case, shape_supported

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9_\[\],\s{}:\*]+?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_summary(hlo_text: str) -> dict:
    """Per-device bytes and op counts per collective kind (result sizes)."""
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        b = _type_bytes(m.group(1))
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def scale_layers(cfg, k: int):
    return dataclasses.replace(
        cfg, n_layers=k,
        n_enc_layers=(min(k, cfg.n_enc_layers) if cfg.enc_dec else 0))


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")}


def run_case(arch: str, shape: str, *, multi_pod: bool = False,
             rules_name: str = "base", fit: bool = True,
             build_kw: dict | None = None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    rules = shd.RULES_FSDP if rules_name == "fsdp" else shd.RULES_BASE
    rec: dict = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "rules": rules_name, "ok": False,
    }
    ok, why = shape_supported(cfg, shape)
    if not ok:
        rec["skipped"] = why
        return rec
    kw = dict(rules=rules, **(build_kw or {}))
    try:
        t0 = time.perf_counter()
        fn, args = build_case(cfg, mesh, shape, **kw)
        lowered = jax.jit(fn).lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        rec["ok"] = True
        rec["lower_s"] = round(t1 - t0, 1)
        rec["compile_s"] = round(t2 - t1, 1)
        rec["memory"] = _mem_dict(compiled)
        rec["cost_scanned"] = _cost_dict(compiled)
        rec["collectives_scanned"] = collective_summary(compiled.as_text())
        if verbose:
            ma = compiled.memory_analysis()
            print(f"  [{arch} {shape} {rec['mesh']}] compile ok "
                  f"({rec['compile_s']}s): args/device="
                  f"{ma.argument_size_in_bytes/2**30:.2f} GiB, "
                  f"temp/device={ma.temp_size_in_bytes/2**30:.2f} GiB")
        if fit:
            costs = {}
            for k in (2, 4):
                cfgk = scale_layers(cfg, k)
                fnk, argsk = build_case(cfgk, mesh, shape, unroll=True, **kw)
                ck = jax.jit(fnk).lower(*argsk).compile()
                costs[k] = _cost_dict(ck)
                costs[k]["collectives"] = collective_summary(ck.as_text())
            def lin(f2, f4, L):
                body = (f4 - f2) / 2.0
                return max(0.0, f2 - 2 * body) + L * body
            L = cfg.n_layers
            coll2 = sum(v["bytes"] for v in costs[2]["collectives"].values())
            coll4 = sum(v["bytes"] for v in costs[4]["collectives"].values())
            rec["fit"] = {
                "L": L,
                "flops_perdev": lin(costs[2]["flops"], costs[4]["flops"], L),
                "bytes_perdev": lin(costs[2]["bytes"], costs[4]["bytes"], L),
                "coll_bytes_perdev": lin(coll2, coll4, L),
                "l2": costs[2], "l4": costs[4],
            }
    except Exception as e:  # noqa: BLE001 — a failed case is a data point
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  [{arch} {shape} {rec['mesh']}] FAILED: {rec['error']}")
    return rec


def case_path(outdir: str, rec: dict) -> str:
    name = (f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
            + ("" if rec["rules"] == "base" else f"__{rec['rules']}")
            + ".json")
    return os.path.join(outdir, name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default="base", choices=["base", "fsdp"])
    ap.add_argument("--no-fit", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCHS[:10] if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_case(arch, shape, multi_pod=mp,
                               rules_name=args.rules, fit=not args.no_fit)
                with open(case_path(args.out, rec), "w") as f:
                    json.dump(rec, f, indent=1)
                n_ok += rec["ok"]
                n_fail += (not rec["ok"]) and ("skipped" not in rec)
                n_skip += "skipped" in rec
    print(f"dry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
