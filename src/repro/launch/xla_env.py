"""XLA_FLAGS handling for the dry-run drivers (jax-free: must be
importable and called before anything touches jax, which locks the
device count on first init)."""
import os

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(count: int = 512) -> None:
    """Append ``--xla_force_host_platform_device_count=<count>`` to
    ``XLA_FLAGS``, preserving every flag the operator already set.  If
    the operator set a device count themselves (any value), their
    explicit choice wins and nothing is changed."""
    tokens = os.environ.get("XLA_FLAGS", "").split()
    if any(t.startswith(_FORCE_FLAG) for t in tokens):
        return
    os.environ["XLA_FLAGS"] = " ".join(tokens + [f"{_FORCE_FLAG}={count}"])
