"""Leaf-path → PartitionSpec resolution for params, protocol state,
batches and KV caches.

Every param leaf gets *logical* axes from a name table; logical axes map
to mesh axes through a rule dict; a divisibility check drops any mapping
that does not divide the dim (e.g. whisper's vocab 51866 % 16 != 0 →
vocab falls back to replicated and the embed dim picks up 'model').

The name-table path covers the model *pytree*.  The wavefront sweep's
packed flat substrate has no leaf names to resolve — its specs are the
fixed per-rank builders in
:func:`repro.core.runtime_sharded.packed_sweep_specs` (lane-group axis →
'data', flat parameter axis → 'model'; DESIGN.md §13).  Divisibility is
handled upstream there too: ``run_sweep`` pads lanes to a multiple of
the 'data' size and the flat axis to a multiple of the 'model' size
(``block_pad_width(p, shards)`` under the pallas commit), so the
fall-back-to-replicated rule this module needs never applies.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["RULES_BASE", "RULES_FSDP", "param_pspec", "tree_pspecs",
           "tree_shardings", "batch_pspec", "cache_pspecs", "mesh_axis_size"]

# logical axis -> mesh axis
RULES_BASE: dict[str, Optional[str]] = {
    "vocab": "model",
    "embed": None,
    "model_out": "model",
    "model_in": "model",
    "expert": "model",
    "batch": "data",
    "kv_heads": "model",
    "head_dim": None,
}
# beyond-baseline: FSDP the embed dim over 'data' (memory hillclimb)
RULES_FSDP = dict(RULES_BASE, embed="data")

# trailing-dims logical axes by parameter leaf name
_TABLE: dict[str, tuple] = {
    "wq": ("embed", "model_out"), "wk": ("embed", "model_out"),
    "wv": ("embed", "model_out"), "wi": ("embed", "model_out"),
    "wg": ("embed", "model_out"), "k_up": (None, "model_out"),
    "v_up": (None, "model_out"), "q_b": (None, "model_out"),
    "in_proj": ("embed", "model_out"), "dt_proj": (None, "model_out"),
    "bq": ("model_out",), "bk": ("model_out",), "bv": ("model_out",),
    "bi": ("model_out",), "bo": ("embed",),
    "wo": ("model_in", "embed"), "out_proj": ("model_in", "embed"),
    "x_proj": ("model_in", None),
    "w_dkv": ("embed", None), "q_a": ("embed", None), "w_kr": ("embed", None),
    "c_scale": (None,), "q_scale": (None,),
    "conv_w": (None, "model_out"), "conv_b": ("model_out",),
    "dt_bias": ("model_out",), "D": ("model_out",),
    "A_log": ("model_in", None),
    "router": ("embed", None),
    "scale": (None,), "bias": (None,),
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "frontend_proj": (None, "embed"),
}


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


def _resolve(axes: Sequence, shape: tuple, mesh: Mesh, rules: dict) -> P:
    """Map logical axes to mesh axes, dropping non-dividing / duplicate."""
    used: set[str] = set()
    out = []
    for ax, dim in zip(axes, shape):
        m = rules.get(ax) if isinstance(ax, str) else ax
        if isinstance(m, str):
            m = (m,)
        if m:
            flat = tuple(a for a in m if a not in used)
            sz = mesh_axis_size(mesh, flat) if flat else 1
            if flat and dim % sz == 0 and sz > 1:
                used.update(flat)
                out.append(flat if len(flat) > 1 else flat[0])
                continue
        out.append(None)
    return P(*out)


def _path_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def param_pspec(path, leaf, mesh: Mesh, rules: dict,
                lead_axes: tuple = ()) -> P:
    names = _path_names(path)
    base = _TABLE.get(names[-1], ())
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    lead = ndim - len(base) - len(lead_axes)
    axes = list(lead_axes) + [None] * lead + list(base)
    if "experts" in names and len(axes) >= 2:
        axes[len(lead_axes) + 1] = "expert"   # (L, E, ...) expert dim
    return _resolve(axes, leaf.shape, mesh, rules)


def tree_pspecs(tree: Any, mesh: Mesh, rules: dict,
                lead_axes: tuple = ()) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_pspec(p, l, mesh, rules, lead_axes), tree)


def tree_shardings(tree: Any, mesh: Mesh, rules: dict,
                   lead_axes: tuple = ()) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_pspecs(tree, mesh, rules, lead_axes))


def batch_pspec(ndim: int, mesh: Mesh, batch_axes, shape=None) -> P:
    """Leading-dim batch sharding, remaining dims replicated."""
    if batch_axes and shape is not None:
        sz = mesh_axis_size(mesh, tuple(batch_axes))
        if shape[0] % sz:
            batch_axes = ()
    spec = [tuple(batch_axes) if batch_axes else None] + [None] * (ndim - 1)
    return P(*spec)


# ---------------- KV-cache specs ------------------------------------- #
def cache_pspecs(cache_struct: Any, mesh: Mesh, batch_axes,
                 seq_shard: bool = False) -> Any:
    """seq_shard=True: shard the cache LENGTH dim over 'model'
    (flash-decode style): attention reduces over the sharded length with
    an O(B·H·hd) psum instead of all-gathering / all-reducing
    O(B·H·C) score rows — the fix for GQA archs whose kv_heads don't
    divide the model axis (§Perf 3)."""
    msz = mesh.shape["model"]
    baxes = tuple(batch_axes)

    def spec(path, leaf):
        names = _path_names(path)
        nm = names[-1]
        nd = leaf.ndim
        bsz = mesh_axis_size(mesh, baxes) if baxes else 1

        def b(dim_size):
            return baxes if (baxes and dim_size % bsz == 0) else None

        if nm in ("k", "v") and nd == 5:          # (L,B,C,KV,hd)
            L, B, C, KV, hd = leaf.shape
            # kv-head sharding is contraction-free and preferred when it
            # divides; otherwise sequence-shard (flash-decode) — measured
            # 10x collective win for GQA, but a 2.4x memory REGRESSION for
            # MHA archs whose kv heads divide the axis (§Perf 3).
            if KV % msz == 0:
                return P(None, b(B), None, "model", None)
            if seq_shard and C % msz == 0:
                return P(None, b(B), "model", None, None)
            if hd % msz == 0:
                return P(None, b(B), None, None, "model")
            return P(None, b(B), None, None, None)
        if nm == "c" and nd == 4:                  # (L,B,C,r)
            if seq_shard and leaf.shape[2] % msz == 0:
                return P(None, b(leaf.shape[1]), "model", None)
            return P(None, b(leaf.shape[1]), None,
                     "model" if leaf.shape[3] % msz == 0 else None)
        if nm == "kr" and nd == 4:
            return P(None, b(leaf.shape[1]), None, None)
        if nm == "conv" and nd == 4:               # (L,B,K-1,di)
            return P(None, b(leaf.shape[1]), None,
                     "model" if leaf.shape[3] % msz == 0 else None)
        if nm == "h" and nd == 4:                  # (L,B,di,N)
            return P(None, b(leaf.shape[1]),
                     "model" if leaf.shape[2] % msz == 0 else None, None)
        if nm in ("cross_k", "cross_v") and nd == 5:
            L, B, F, KV, hd = leaf.shape
            if KV % msz == 0:
                return P(None, b(B), None, "model", None)
            if hd % msz == 0:
                return P(None, b(B), None, None, "model")
            return P(None, b(B), None, None, None)
        return P(*([None] * nd))                   # idx, slot_pos, ...

    return jax.tree_util.tree_map_with_path(spec, cache_struct)
