"""Roofline analysis over the dry-run artifacts.

For every (arch × shape × mesh) JSON produced by dryrun.py, derive:

  compute term    = HLO_FLOPs_perdev / peak_FLOP/s          [s]
  memory term     = HLO_bytes_perdev / HBM_bw               [s]
  collective term = collective_bytes_perdev / ICI_link_bw   [s]

HLO_FLOPs/bytes come from the exact linear-in-L fit (dryrun.py §fit);
SSM/hybrid architectures get a documented analytic correction for the
selective-scan while-loop (its body is counted once per layer by XLA's
cost analysis regardless of sequence length).

Also reports MODEL_FLOPS (6·N_active·tokens for training, 2·N_active·tokens
for inference), the MODEL/HLO usefulness ratio, the HBM-fit verdict
(args+temp vs 16 GiB v5e), the dominant term, and a one-line lever.

    PYTHONPATH=src python -m repro.launch.roofline --reports reports/dryrun \
        --out reports/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.launch.mesh import HW
from repro.launch.specs import SHAPES

HBM_PER_CHIP = 16 * 2**30          # v5e


def ssm_correction_flops(cfg, shape: str, kind: str) -> float:
    """Global extra FLOPs for selective-scan bodies (counted once by XLA).

    Per timestep per layer: dA=exp(dt·A), dB·u, state update, C·h ≈
    8·d_inner·d_state FLOPs.  Backward ≈ 2× forward.
    """
    if cfg.mixer not in ("ssm", "hybrid"):
        return 0.0
    info = SHAPES[shape]
    tokens = info["batch"] * (info["seq"] if kind != "decode" else 1)
    if kind == "decode":
        return 0.0                      # decode has no scan
    mult = 3.0 if kind == "train" else 1.0
    return mult * cfg.n_layers * 8.0 * cfg.d_inner * cfg.ssm_state * tokens


def model_flops(cfg, shape: str) -> tuple[float, str]:
    info = SHAPES[shape]
    n_active = cfg.active_param_count()
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        return 6.0 * n_active * tokens, "6·N_active·tokens"
    if info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        return 2.0 * n_active * tokens, "2·N_active·tokens"
    return 2.0 * n_active * info["batch"], "2·N_active·batch"


def lever(dom: str, rec: dict) -> str:
    if dom == "memory":
        return ("cut HBM traffic: coarser remat policy / fused protocol "
                "update (rfast_update kernel) / bf16 CE chunking")
    if dom == "collective":
        return ("cut gossip+TP bytes: overlap ppermute with compute, "
                "quantize protocol messages, widen tree fan-out")
    return "raise MXU utilization: larger per-chip tiles, fused attention"


def analyze(path: str) -> dict | None:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("skipped"):
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "skipped": rec["skipped"]}
    if not rec.get("ok"):
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "error": rec.get("error", "?")}
    cfg = get_config(rec["arch"])
    chips = rec["chips"]
    kind = SHAPES[rec["shape"]]["kind"]

    fit = rec.get("fit")
    if fit:
        fl_pd = fit["flops_perdev"]
        by_pd = fit["bytes_perdev"]
        co_pd = fit["coll_bytes_perdev"]
    else:
        cs = rec["cost_scanned"]
        fl_pd, by_pd = cs["flops"], cs["bytes"]
        co_pd = sum(v["bytes"]
                    for v in rec.get("collectives_scanned", {}).values())

    ssm_fix = ssm_correction_flops(cfg, rec["shape"], kind) / chips
    fl_pd_corr = fl_pd + ssm_fix

    compute_s = fl_pd_corr / HW["peak_flops_bf16"]
    memory_s = by_pd / HW["hbm_bw"]
    coll_s = co_pd / HW["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=terms.get)

    mf, mf_kind = model_flops(cfg, rec["shape"])
    hlo_global = fl_pd_corr * chips
    mem = rec["memory"]
    hbm_need = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "rules": rec.get("rules", "base"),
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dom,
        "model_flops": mf, "model_flops_kind": mf_kind,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "ssm_corr_perdev": ssm_fix,
        "args_gib": mem["argument_size_in_bytes"] / 2**30,
        "temp_gib": mem["temp_size_in_bytes"] / 2**30,
        "fits_hbm": hbm_need <= HBM_PER_CHIP,
        "lever": lever(dom, rec),
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def to_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compute | memory | collective | "
           "dominant | MODEL/HLO | args GiB | temp GiB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP: {r['skipped'][:40]}… ||||||||")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR: {r['error'][:40]} ||||||||")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['args_gib']:.1f} | "
            f"{r['temp_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default="reports/dryrun")
    ap.add_argument("--out", default="reports/roofline.md")
    ap.add_argument("--json-out", default="reports/roofline.json")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.reports, "*.json"))):
        r = analyze(path)
        if r:
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    md = to_markdown(rows)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("# Roofline (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, "
                "50 GB/s ICI)\n\n" + md + "\n")
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(md)


if __name__ == "__main__":
    main()
