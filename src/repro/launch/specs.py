"""Input specs (ShapeDtypeStruct stand-ins) and step functions for every
(architecture × input shape) combination — the dry-run's subject matter.

Shapes (assigned):
  train_4k     seq 4096    global_batch 256   train_step (R-FAST round)
  prefill_32k  seq 32768   global_batch 32    prefill (forward logits)
  decode_32k   seq 32768   global_batch 128   serve_step (1 token + cache)
  long_500k    seq 524288  global_batch 1     serve_step, sub-quadratic only
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.plan import build_comm_plan
from repro.core.runtime import init_node_state, make_rfast_round
from repro.core.runtime_sharded import (init_sharded_state,
                                        make_sharded_round,
                                        packed_sweep_specs,
                                        partial_auto_shard_map_supported)
from repro.core.topology import binary_tree
from repro.models import sharding as msh
from repro.models.config import ModelConfig
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, loss_fn)
from . import shardings as sh

__all__ = ["SHAPES", "LONG_WINDOW", "shape_supported", "build_train",
           "build_prefill", "build_decode", "build_case",
           "packed_sweep_specs"]
# packed_sweep_specs is re-exported for launch-level consumers: the
# mesh-mapped fleet sweep's packed state has no logical axis names (a
# flat (group, lanes·n, 4, p) substrate), so it bypasses the name-table
# resolution below and uses the fixed per-rank specs from
# core/runtime_sharded — lane-group axis -> lane_axis ('data'), flat
# parameter axis -> param_axis ('model').  See DESIGN.md §13.

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode", long=True),
}
LONG_WINDOW = 8192          # sliding window used by dense archs at 500k

# measured per-arch tuning (reports/roofline_*.json): sequence-parallel
# residual sharding regresses MHA-32 (deepseek-7b, resharding between
# head- and seq-layouts each layer) and deepseek-v2's MoE dispatch.
SEQ_PARALLEL_OPT_OUT = {"deepseek-7b", "deepseek-v2-236b"}


def shape_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.enc_dec:
        return False, ("enc-dec audio model: quadratic encoder context, no "
                       "sliding-window decoder analogue (DESIGN.md §4)")
    return True, ""


def _long_variant(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic serving variant for the 500k shape."""
    if cfg.mixer == "ssm":
        return cfg
    if cfg.attn_window and cfg.attn_window <= LONG_WINDOW:
        return cfg
    return dataclasses.replace(cfg, attn_window=LONG_WINDOW)


# activation rules (models/sharding.py logical axes -> mesh axes)
def act_rules(batch_axes, seq_parallel: bool = False) -> dict:
    """seq_parallel: shard the residual stream's sequence dim over
    'model' (sequence parallelism) — per-layer activation all-reduces
    become all-gather/reduce-scatter pairs and the attention-score
    working set shrinks by the model-axis factor (§Perf 1.It5: memory
    −44%, collective −60%, temp −66% on llama3-8b train_4k)."""
    return dict(
        batch=tuple(batch_axes) if batch_axes else None,
        seq="model" if seq_parallel else None,
        embed=None, mlp="model", heads="model",
        kv_heads="model", head_dim=None, vocab="model", expert="model",
        cap=None, ssm_inner="model", ssm_state=None, kv_seq=None,
        frontend=None, node=None,
    )


def _sds(struct_tree, shardings_tree):
    return jax.tree.map(
        lambda s, shd: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=shd),
        struct_tree, shardings_tree)


def _frontend_struct(cfg, n_lead, b, dtype):
    if not cfg.frontend:
        return None
    shape = (cfg.frontend_seq, cfg.frontend_dim or cfg.d_model)
    lead = ((n_lead, b) if n_lead else (b,))
    return jax.ShapeDtypeStruct(lead + shape, dtype)


# ------------------------------------------------------------------ #
# train_4k: one R-FAST production round
# ------------------------------------------------------------------ #
def build_train(cfg: ModelConfig, mesh, *, seq: int, global_batch: int,
                rules=None, node_axes=None, gamma=1e-2, topo=None,
                dtype=jnp.bfloat16, unroll=False, comm: str = "auto",
                ce: str = "lse", seq_parallel: bool | None = None):
    """comm="ppermute": shard_map spanning-tree gossip (production).
    comm="dense": GSPMD dense-mixing baseline (paper-naive port).
    comm="auto": ppermute when shard_map supports partial-auto mode
    (model axis GSPMD inside the manual node region), dense otherwise
    (jax 0.4.x — fully-manual regions reject the model's sharding
    constraints; DESIGN.md §2).
    ce: cross-entropy mode (see models.transformer.loss_fn)."""
    rules = rules or sh.RULES_BASE
    if seq_parallel is None:
        seq_parallel = cfg.name not in SEQ_PARALLEL_OPT_OUT
    if node_axes is None:
        node_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_nodes = sh.mesh_axis_size(mesh, tuple(node_axes))
    b_node = global_batch // n_nodes
    assert b_node >= 1, (global_batch, n_nodes)
    topo = topo or binary_tree(n_nodes)
    spec = build_comm_plan(topo)
    if comm == "auto":
        comm = ("ppermute" if partial_auto_shard_map_supported()
                else "dense")

    s_text = seq - (cfg.frontend_seq if (cfg.frontend and not cfg.enc_dec)
                    else 0)

    def grad_fn(params, batch, key):
        del key

        def loss(p):
            return loss_fn(cfg, p, batch["tokens"], batch["labels"],
                           batch.get("frontend"), remat=True, unroll=unroll,
                           ce=ce)
        return jax.value_and_grad(loss)(params)

    if comm == "ppermute":
        round_fn = make_sharded_round(topo, grad_fn, mesh, gamma=gamma,
                                      node_axes=node_axes)
    else:
        round_fn = make_rfast_round(spec, grad_fn, gamma=gamma,
                                    node_axes=node_axes)

    # mesh axes not used by the node dim carry the *within-node* batch
    # (data parallelism inside a node group — paper Remark 9)
    inner_batch = tuple(a for a in mesh.axis_names
                        if a != "model" and a not in node_axes)
    arules = act_rules(inner_batch, seq_parallel=seq_parallel)

    def train_step(state, batches, keys):
        with msh.mesh_rules(mesh, arules):
            return round_fn(state, batches, keys, None)

    # ---- structs ----------------------------------------------------- #
    params_s = jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    batch_s = {
        "tokens": jax.ShapeDtypeStruct((n_nodes, b_node, s_text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_nodes, b_node, s_text), jnp.int32),
    }
    fs = _frontend_struct(cfg, n_nodes, b_node, dtype)
    if fs is not None:
        batch_s["frontend"] = fs
    keys_s = jax.ShapeDtypeStruct((n_nodes, 2), jnp.uint32)

    # ---- shardings (computed on the STACKED structs: the node/edge dim
    # is part of the leaf shape, so base-axis alignment stays correct) --- #
    node_lead = (tuple(node_axes),)

    if comm == "ppermute":
        state_s = jax.eval_shape(
            lambda p, b, k: init_sharded_state(topo, p, grad_fn, b, k),
            params_s, batch_s, keys_s)
        x_sh = sh.tree_shardings(state_s.x, mesh, rules, lead_axes=node_lead)
        slot_lead = (tuple(node_axes), None)
        rho_sh = sh.tree_shardings(state_s.rho_out, mesh, rules,
                                   lead_axes=slot_lead)
        state_sh = type(state_s)(
            step=NamedSharding(mesh, P()),
            x=x_sh, z=x_sh, g_prev=x_sh,
            rho_out=rho_sh, rho_buf=rho_sh,
            mail_v=None, m=None,
        )
    else:
        state_s = jax.eval_shape(
            lambda p, b, k: init_node_state(spec, p, grad_fn, b, k),
            params_s, batch_s, jax.random.PRNGKey(0))
        x_sh = sh.tree_shardings(state_s.x, mesh, rules, lead_axes=node_lead)
        rho_sh = sh.tree_shardings(state_s.rho, mesh, rules,
                                   lead_axes=node_lead)
        state_sh = type(state_s)(
            step=NamedSharding(mesh, P()),
            x=x_sh, z=x_sh, g_prev=x_sh,
            rho=rho_sh, rho_buf=rho_sh,
            mail_v=None, m=None,
        )
    ib = tuple(inner_batch) if inner_batch else None
    batch_sh = jax.tree.map(
        lambda s: NamedSharding(
            mesh, P(*((tuple(node_axes), ib)
                      + (None,) * (len(s.shape) - 2)))),
        batch_s)
    keys_sh = NamedSharding(mesh, P(tuple(node_axes)))

    args = (_sds(state_s, state_sh), _sds(batch_s, batch_sh),
            jax.ShapeDtypeStruct(keys_s.shape, keys_s.dtype,
                                 sharding=keys_sh))
    return train_step, args


# ------------------------------------------------------------------ #
# prefill_32k: full forward producing logits
# ------------------------------------------------------------------ #
def build_prefill(cfg: ModelConfig, mesh, *, seq: int, global_batch: int,
                  rules=None, dtype=jnp.bfloat16, unroll=False,
                  seq_parallel: bool | None = None):
    rules = rules or sh.RULES_BASE
    if seq_parallel is None:
        seq_parallel = cfg.name not in SEQ_PARALLEL_OPT_OUT
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    arules = act_rules(batch_axes, seq_parallel=seq_parallel)
    s_text = seq - (cfg.frontend_seq if (cfg.frontend and not cfg.enc_dec)
                    else 0)

    def prefill_step(params, tokens, frontend=None):
        with msh.mesh_rules(mesh, arules):
            logits, _ = forward(cfg, params, tokens, frontend, remat=True,
                                last_only=True, unroll=unroll)
        return logits

    params_s = jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    params_sh = sh.tree_shardings(params_s, mesh, rules)
    toks = jax.ShapeDtypeStruct(
        (global_batch, s_text), jnp.int32,
        sharding=NamedSharding(mesh, sh.batch_pspec(
            2, mesh, batch_axes, (global_batch, s_text))))
    args = [_sds(params_s, params_sh), toks]
    fs = _frontend_struct(cfg, 0, global_batch, dtype)
    if fs is not None:
        args.append(jax.ShapeDtypeStruct(
            fs.shape, fs.dtype,
            sharding=NamedSharding(mesh, sh.batch_pspec(
                fs.ndim if hasattr(fs, "ndim") else len(fs.shape),
                mesh, batch_axes, fs.shape))))
    return prefill_step, tuple(args)


# ------------------------------------------------------------------ #
# decode_32k / long_500k: serve_step (one token, filled cache)
# ------------------------------------------------------------------ #
def build_decode(cfg: ModelConfig, mesh, *, seq: int, global_batch: int,
                 long: bool = False, rules=None, dtype=jnp.bfloat16,
                 unroll=False, cache_seq_shard: bool = True):
    rules = rules or sh.RULES_BASE
    if long:
        cfg = _long_variant(cfg)
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    arules = act_rules(batch_axes)

    def serve_step(params, cache, token):
        with msh.mesh_rules(mesh, arules):
            return decode_step(cfg, params, cache, token, unroll=unroll)

    params_s = jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    params_sh = sh.tree_shardings(params_s, mesh, rules)
    fs = _frontend_struct(cfg, 0, global_batch, dtype)
    cache_s = jax.eval_shape(
        lambda p, f: init_cache(cfg, p, global_batch, seq, dtype, f),
        params_s, fs)
    cache_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        sh.cache_pspecs(cache_s, mesh, batch_axes,
                        seq_shard=cache_seq_shard))
    token = jax.ShapeDtypeStruct(
        (global_batch, 1), jnp.int32,
        sharding=NamedSharding(mesh, sh.batch_pspec(
            2, mesh, batch_axes, (global_batch, 1))))
    return serve_step, (_sds(params_s, params_sh),
                        _sds(cache_s, cache_sh), token)


# ------------------------------------------------------------------ #
def build_case(cfg: ModelConfig, mesh, shape_name: str, **kw):
    info = SHAPES[shape_name]
    if info["kind"] == "train":
        return build_train(cfg, mesh, seq=info["seq"],
                           global_batch=info["batch"], **kw)
    if info["kind"] == "prefill":
        return build_prefill(cfg, mesh, seq=info["seq"],
                             global_batch=info["batch"], **kw)
    return build_decode(cfg, mesh, seq=info["seq"],
                        global_batch=info["batch"],
                        long=info.get("long", False), **kw)


def input_specs(arch: str, shape_name: str, mesh=None, **kw):
    """Public API: ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
    no device allocation) for every model input of (arch × shape), plus the
    step function they feed.  Returns (step_fn, args)."""
    from repro.configs import get_config
    from .mesh import make_production_mesh

    if mesh is None:
        mesh = make_production_mesh()
    return build_case(get_config(arch), mesh, shape_name, **kw)
