"""§Perf hillclimb driver: run named dry-run variants for the three chosen
(arch × shape) pairs and print their roofline terms side by side.

    PYTHONPATH=src python -m repro.launch.hillclimb --pair train|moe|decode
"""
import os

# must run before jax initializes; appends to the operator's own
# XLA_FLAGS (e.g. dump directives survive, an explicit device count wins)
from repro.launch.xla_env import force_host_devices
force_host_devices()

import argparse
import json

from repro.launch.dryrun import run_case
from repro.launch.mesh import HW


def terms(rec: dict) -> str:
    if not rec.get("ok"):
        return f"FAILED: {rec.get('error', '')[:160]}"
    fit = rec.get("fit")
    if fit:
        fl, by, co = (fit["flops_perdev"], fit["bytes_perdev"],
                      fit["coll_bytes_perdev"])
    else:
        fl, by = rec["cost_scanned"]["flops"], rec["cost_scanned"]["bytes"]
        co = sum(v["bytes"]
                 for v in rec.get("collectives_scanned", {}).values())
    mem = rec["memory"]
    return (f"compute={fl/HW['peak_flops_bf16']:.3f}s "
            f"memory={by/HW['hbm_bw']:.3f}s "
            f"collective={co/HW['ici_bw']:.3f}s "
            f"args={mem['argument_size_in_bytes']/2**30:.1f}GiB "
            f"temp={mem['temp_size_in_bytes']/2**30:.1f}GiB")


VARIANTS = {
    "train": [  # llama3-8b x train_4k (paper-representative)
        ("it0_dense_fullce", "llama3-8b", "train_4k",
         dict(), "base", dict(comm="dense", ce="full")),
        ("it1_ppermute_fullce", "llama3-8b", "train_4k",
         dict(), "base", dict(comm="ppermute", ce="full")),
        ("it2_ppermute_lsece", "llama3-8b", "train_4k",
         dict(), "base", dict(comm="ppermute", ce="lse")),
    ],
    "moe": [   # deepseek-v2-236b x train_4k (worst memory / does not fit)
        ("it0_nodes32_base", "deepseek-v2-236b", "train_4k",
         dict(multi_pod=True), "base", dict()),
        ("it1_nodepod_fsdp", "deepseek-v2-236b", "train_4k",
         dict(multi_pod=True), "fsdp", dict(node_axes=("pod",))),
    ],
    "decode": [  # llama3-8b x decode_32k (most collective-bound)
        ("it0_headdim_cache", "llama3-8b", "decode_32k",
         dict(), "base", dict()),
        ("it1_seqshard_cache", "llama3-8b", "decode_32k",
         dict(), "base", dict(cache_seq_shard=True)),
    ],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(VARIANTS) + ["all"],
                    default="all")
    ap.add_argument("--out", default="reports/hillclimb")
    ap.add_argument("--no-fit", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    pairs = list(VARIANTS) if args.pair == "all" else [args.pair]
    for pair in pairs:
        print(f"=== {pair} ===", flush=True)
        for name, arch, shape, case_kw, rules, build_kw in VARIANTS[pair]:
            rec = run_case(arch, shape, rules_name=rules,
                           fit=not args.no_fit, build_kw=build_kw,
                           verbose=False, **case_kw)
            rec["variant"] = name
            with open(os.path.join(args.out, f"{pair}__{name}.json"),
                      "w") as f:
                json.dump(rec, f, indent=1)
            print(f"{name:24s} {terms(rec)}", flush=True)


if __name__ == "__main__":
    main()
