"""End-to-end R-FAST training driver (CPU-runnable at reduced scale).

Trains an LM with the R-FAST protocol over a selectable topology, with
checkpointing, in one of two execution regimes:

* **synchronous rounds** (default) — the production SPMD runtime
  (``core/runtime.py``): every round runs S1–S5 for all nodes, optional
  Bernoulli per-edge loss masks (``--loss-prob``).
* **fully asynchronous** (``--scenario <name>``) — the paper's actual
  regime: a :class:`~repro.core.scenario.NetworkScenario` (stragglers,
  latency, loss bursts, crash/recovery) is realized into a per-event
  trace, and the reduced LM trains through the wavefront simulator
  engine on the flat-parameter substrate (``core/paramvec.py``): the
  model pytree rides the engines as one ``(p,)`` lane per node, with
  per-event stale reads and send outcomes.  ``--steps N`` means N
  activations per node (K = N·nodes events).  Checkpoints hold the
  packed flat state and resume mid-schedule.

    PYTHONPATH=src python -m repro.launch.train \
        --arch rfast-100m --reduced --nodes 4 --steps 200 --topology binary_tree

    PYTHONPATH=src python -m repro.launch.train \
        --arch rfast-100m --reduced --nodes 4 --steps 200 --scenario straggler

``--impl pallas`` commits the protocol state through the fused
``kernels/rfast_update`` grid launch (compiled on TPU, its jnp
emulation twin off-TPU — see kernels/rfast_update/dispatch.py) in both
regimes; the default ``--impl jnp`` is the dense/scatter path.  Both are
the same protocol (core/protocol.py) over the same CommPlan.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.core.paramvec import unravel
from repro.metrics import MetricsLogger, StepTimer
from repro.configs import ARCHS, get_config
from repro.core.protocol import IMPLS
from repro.core.runtime import edge_arrays, init_node_state, make_rfast_round
from repro.core.scenario import SCENARIOS, get_scenario
from repro.core.simulator import (run_epochs, run_rfast, run_sweep,
                                  zeros_state)
from repro.core.topology import get_topology
from repro.data.objectives import make_lm_problem
from repro.data.pipeline import LMShardConfig, node_batch
from repro.models.transformer import init_params, loss_fn
from repro.optim.schedules import warmup_cosine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rfast-100m", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CI-scale)")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--topology", default="binary_tree")
    ap.add_argument("--gamma", type=float, default=3e-3)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--loss-prob", type=float, default=0.0)
    ap.add_argument("--scenario", default="", metavar="NAME",
                    help="train asynchronously under a named "
                         f"NetworkScenario ({', '.join(sorted(SCENARIOS))}) "
                         "through the wavefront engine; default: "
                         "synchronous rounds")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print the SCENARIOS registry (dynamic entries "
                         "marked) and exit")
    ap.add_argument("--impl", default="jnp", choices=IMPLS,
                    help="protocol backend: jnp (dense GSPMD mixing) or "
                         "pallas (fused update kernel)")
    ap.add_argument("--param-shards", type=int, default=1,
                    help="shard the flat parameter axis over this many "
                         "mesh devices (async regime only: routes through "
                         "the mesh-mapped run_sweep — DESIGN.md §13; on "
                         "CPU combine with --host-devices)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force this many XLA host-platform devices "
                         "before the backend initializes (the CPU dev "
                         "loop for --param-shards)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--publish-dir", default="",
                    help="publish SERVING checkpoints (the unraveled "
                         "model pytree of the consensus average x̄, not "
                         "the packed protocol state) at every chunk "
                         "boundary through checkpoint/ckpt.py's atomic "
                         "npz+manifest protocol — the feed that "
                         "launch/serve.py polls and hot-swaps from")
    ap.add_argument("--metrics", default="", help="JSONL metrics path")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify-plans", action="store_true",
                    help="run the repro.analysis plan-invariant linter "
                         "over every compiled plan before training "
                         "(raises PlanInvariantError on any diagnostic)")
    args = ap.parse_args(argv)
    if args.host_devices:
        from repro.launch.xla_env import force_host_devices
        force_host_devices(args.host_devices)

    if args.list_scenarios:
        for name in sorted(SCENARIOS):
            sc = get_scenario(name, 7)
            tag = "  [dynamic: joins/leaves/regional failures]" \
                if sc.dynamic else ""
            print(f"{name}{tag}")
        return {"mode": "list", "scenarios": sorted(SCENARIOS)}

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.publish_dir:
        if not args.scenario:
            ap.error("--publish-dir publishes the async consensus "
                     "average at chunk boundaries; the synchronous "
                     "rounds have no flat-parameter chunk hook (pass "
                     "--scenario)")
        if args.param_shards > 1:
            ap.error("--publish-dir rides the wavefront chunk callback, "
                     "which the mesh-mapped run_sweep path does not "
                     "expose; drop --param-shards or --publish-dir")
    if args.scenario:
        if args.loss_prob:
            ap.error("--loss-prob models loss in the synchronous rounds; "
                     "with --scenario the NetworkScenario owns the "
                     "loss/delay model")
        if args.momentum:
            ap.error("--momentum applies to the synchronous round engine "
                     "only; the event-level Algorithm 2 recursion has no "
                     "momentum term")
        if args.ckpt and get_scenario(args.scenario, args.nodes).dynamic:
            ap.error("--ckpt resume is not supported for dynamic "
                     "(membership) scenarios: the packed state layout "
                     "changes at every epoch boundary, so a mid-schedule "
                     "snapshot is not replayable")
        if args.param_shards > 1:
            if args.ckpt:
                ap.error("--param-shards trains through run_sweep(mesh="
                         "...), which has no mid-schedule resume; drop "
                         "--ckpt or --param-shards")
            if get_scenario(args.scenario, args.nodes).dynamic:
                ap.error("--param-shards is not supported for dynamic "
                         "(membership) scenarios yet")
        return _train_async(args, cfg)
    if args.param_shards > 1:
        ap.error("--param-shards shards the wavefront engine's flat "
                 "parameter axis; the synchronous rounds already shard "
                 "the model pytree via GSPMD (pass --scenario for the "
                 "async regime)")
    return _train_sync(args, cfg)


# --------------------------------------------------------------------- #
# synchronous rounds (production SPMD runtime)
# --------------------------------------------------------------------- #
def _train_sync(args, cfg) -> dict:
    n = args.nodes
    topo = get_topology(args.topology, n)
    spec = edge_arrays(topo)
    shard_cfg = LMShardConfig(vocab=cfg.vocab,
                              batch_per_node=args.batch_per_node,
                              seq_len=args.seq, n_nodes=n, seed=args.seed)

    def grad_fn(params, batch, key):
        del key
        toks, labels = batch
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, toks, labels))(params)

    def batches_at(step: int):
        toks, labels = zip(*(node_batch(shard_cfg, i, step)
                             for i in range(n)))
        return jnp.asarray(np.stack(toks)), jnp.asarray(np.stack(labels))

    gamma = warmup_cosine(args.gamma, warmup=max(1, args.steps // 20),
                          total=args.steps)
    robust = args.loss_prob > 0
    # donate=True: the protocol state (x/z/ρ/ρ̃ — 2·|params|·N + 2·E_pad
    # buffers) updates in place instead of double-buffering; the loop
    # below rebinds ``state`` every step and never replays an old one
    round_fn = make_rfast_round(
        spec, grad_fn, gamma=gamma, robust=robust,
        momentum=args.momentum, impl=args.impl, donate=True)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M nodes={n} "
          f"topo={topo.name} robust={robust} impl={args.impl}")

    state = init_node_state(spec, params, grad_fn, batches_at(0), key,
                            robust=robust, momentum=args.momentum)
    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        start = latest_step(args.ckpt)
        state = load_checkpoint(args.ckpt, state)
        print(f"resumed from step {start}")

    rng = np.random.default_rng(args.seed + 1)
    logger = MetricsLogger(args.metrics) if args.metrics else None
    timer = StepTimer()
    t0 = time.perf_counter()
    losses: list[float] = []
    for step in range(start, args.steps):
        masks = None
        if robust:
            masks = jnp.asarray(
                (rng.uniform(size=spec.e_pad) >= args.loss_prob),
                jnp.float32)
        keys = jax.random.split(jax.random.fold_in(key, step), n)
        state, metrics = round_fn(state, batches_at(step), keys, masks)
        timer.tick()
        if logger:
            logger.log(step + 1, loss=metrics["loss"],
                       sps=timer.steps_per_sec)
        if (step == start or (step + 1) % args.log_every == 0
                or step + 1 == args.steps):
            l = float(metrics["loss"])
            losses.append(l)
            dt = time.perf_counter() - t0
            print(f"step {step+1:5d} loss {l:.4f} "
                  f"({dt:.1f}s, {timer.steps_per_sec:.2f} it/s)", flush=True)
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, step + 1, state)
    if logger:
        logger.close()
    print("done")
    return {"mode": "sync", "losses": losses, "steps": args.steps}


# --------------------------------------------------------------------- #
# fully asynchronous (scenario trace through the wavefront engine)
# --------------------------------------------------------------------- #
def _train_async(args, cfg) -> dict:
    n = args.nodes
    topo = get_topology(args.topology, n)
    prob = make_lm_problem(cfg, n, batch_per_node=args.batch_per_node,
                           seq_len=args.seq, seed=args.seed)
    sc = get_scenario(args.scenario, n)
    K = args.steps * n
    if sc.dynamic:
        return _train_async_dynamic(args, cfg, prob, topo, sc, K)
    trace = sc.realize(topo, K, seed=args.seed)
    sched = trace.schedule
    # delivered fraction over *attempted* sends (the active agent's
    # out-edges per event), not over the all-False inactive rows
    outdeg = np.zeros((2, n))
    for g, edges in enumerate((topo.edges_W(), topo.edges_A())):
        for (j, _i) in edges:
            outdeg[g, j] += 1
    attempts = outdeg[:, sched.agent].sum()
    delivered = float((trace.send_ok_w.sum() + trace.send_ok_a.sum())
                      / max(1.0, attempts))
    print(f"arch={cfg.name} p={prob.p} ({prob.spec.p_model} model) "
          f"nodes={n} topo={topo.name} scenario={args.scenario} "
          f"K={K} D={sched.D} T={sched.T} "
          f"send_ok={delivered:.2f} impl={args.impl}")

    x0 = prob.x0_flat
    # chunk (= eval/ckpt) boundaries: log_every activations per node
    eval_every = max(n, min(K, args.log_every * n))
    save_every_chunks = max(1, args.ckpt_every // max(1, args.log_every))

    state0 = None
    if args.ckpt and latest_step(args.ckpt) is not None:
        template = zeros_state(topo, prob.p, int(sched.D) + 2)
        state0 = load_checkpoint(args.ckpt, template)
        print(f"resumed from event {int(state0.k)}/{K}")

    logger = MetricsLogger(args.metrics) if args.metrics else None
    timer = StepTimer()
    t0 = time.perf_counter()
    losses: list[float] = [float(prob.mean_loss(x0))]
    print(f"event {0:6d} loss {losses[0]:.4f} (init)", flush=True)

    def eval_fn(state, t):
        l = float(prob.mean_loss(state.x.mean(0)))
        return {"loss": l, "t": t}

    published: list[int] = []

    def chunk_cb(state, k):
        timer.tick()
        if logger:
            logger.log(k, loss=losses[-1], sps=timer.steps_per_sec)
        if args.ckpt and (k >= K
                          or (k // eval_every) % save_every_chunks == 0):
            save_checkpoint(args.ckpt, k, state)
        if args.publish_dir:
            # serving checkpoint: the consensus average x̄ unraveled back
            # to the model pytree — what launch/serve.py hot-swaps in
            save_checkpoint(args.publish_dir, k,
                            unravel(prob.spec, state.x.mean(0)))
            published.append(k)

    k0 = int(state0.k) if state0 is not None else 0
    def eval_and_log(state, t):
        m = eval_fn(state, t)
        losses.append(m["loss"])
        ev = min(K, k0 + (len(losses) - 1) * eval_every)
        dt = time.perf_counter() - t0
        print(f"event {ev:6d} loss {m['loss']:.4f} "
              f"vtime {t:8.1f} ({dt:.1f}s)", flush=True)
        return m

    if args.param_shards > 1:
        # one lane, flat parameter axis sharded over `model`: the
        # p >= 100M path (DESIGN.md §13).  No chunk_cb/state0 hooks —
        # --ckpt was rejected in main(); logging rides eval_and_log.
        from repro.launch.mesh import make_sweep_mesh
        mesh = make_sweep_mesh(lanes=1, param_shards=args.param_shards)
        print(f"mesh: 1x{args.param_shards} (lane x param shards) over "
              f"{len(jax.devices())} devices")

        def eval_log_sharded(state, t):
            m = eval_and_log(state, t)
            timer.tick()
            if logger:
                logger.log(min(K, k0 + (len(losses) - 1) * eval_every),
                           loss=m["loss"], sps=timer.steps_per_sec)
            return m

        states, _ = run_sweep(
            topo, [sched], prob, jnp.tile(x0[None], (n, 1)), args.gamma,
            seeds=[args.seed], eval_every=eval_every,
            eval_fn=eval_log_sharded, impl=args.impl,
            verify_plans=args.verify_plans, mesh=mesh)
        state = states[0]
    else:
        state, _ = run_rfast(
            topo, sched, prob, jnp.tile(x0[None], (n, 1)), args.gamma,
            seed=args.seed, eval_every=eval_every, eval_fn=eval_and_log,
            mode="wavefront", impl=args.impl, state0=state0,
            chunk_cb=chunk_cb, verify_plans=args.verify_plans)
    if logger:
        logger.close()
    if len(losses) > 1:
        print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"over {K} events ({float(sched.times[-1]):.1f} vtime)")
    else:
        print("done (schedule already complete)")
    return {"mode": "async", "scenario": args.scenario,
            "losses": losses, "events": K, "published": published,
            "vtime": float(sched.times[-1]), "send_ok": delivered}


# --------------------------------------------------------------------- #
# dynamic scenarios (membership epochs through run_epochs)
# --------------------------------------------------------------------- #
def _train_async_dynamic(args, cfg, prob, topo, sc, K) -> dict:
    """Train under a dynamic-membership scenario: the realized trace is
    partitioned into topology epochs (joins/leaves/regional failures,
    with root re-election when a common root enters a crash window) and
    run through :func:`run_epochs`, which migrates the packed state
    across every plan change.  ``--ckpt`` is rejected in :func:`main`:
    the packed layout changes at epoch boundaries, so a mid-schedule
    snapshot is not replayable."""
    n = args.nodes
    et = sc.realize_epochs(topo, K, seed=args.seed)
    print(f"arch={cfg.name} p={prob.p} ({prob.spec.p_model} model) "
          f"nodes={n} topo={topo.name} scenario={args.scenario} "
          f"K={K} epochs={len(et.epochs)} impl={args.impl}")
    for i, ep in enumerate(et.epochs):
        act = int(ep.topology.active_mask().sum())
        print(f"  epoch {i}: t0={ep.t0:7.1f} events {ep.k0}..{ep.k0+ep.K} "
              f"root={ep.root} active={act}/{n} graph={ep.topology.name}")

    x0 = prob.x0_flat
    eval_every = max(n, min(K, args.log_every * n))
    logger = MetricsLogger(args.metrics) if args.metrics else None
    timer = StepTimer()
    t0 = time.perf_counter()
    losses: list[float] = [float(prob.mean_loss(x0))]
    print(f"event {0:6d} loss {losses[0]:.4f} (init)", flush=True)

    vt = {"t": 0.0}

    def eval_and_log(state, t):
        l = float(prob.mean_loss(state.x.mean(0)))
        losses.append(l)
        vt["t"] = t
        return {"loss": l, "t": t}

    # run_epochs calls eval_fn then chunk_cb with the same global event
    # count, so the print lands here where k is known
    published: list[int] = []

    def chunk_cb(state, k):
        timer.tick()
        dt = time.perf_counter() - t0
        print(f"event {k:6d} loss {losses[-1]:.4f} vtime {vt['t']:8.1f} "
              f"({dt:.1f}s)", flush=True)
        if logger:
            logger.log(k, loss=losses[-1], sps=timer.steps_per_sec)
        if args.publish_dir:
            save_checkpoint(args.publish_dir, k,
                            unravel(prob.spec, state.x.mean(0)))
            published.append(k)

    state, metrics = run_epochs(
        et, prob, jnp.tile(x0[None], (n, 1)), args.gamma,
        seed=args.seed, eval_every=eval_every, eval_fn=eval_and_log,
        impl=args.impl, chunk_cb=chunk_cb, verify_plans=args.verify_plans)
    if logger:
        logger.close()
    vtime = metrics[-1]["t"] if metrics else 0.0
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} over {K} "
          f"events, {len(et.epochs)} epochs ({vtime:.1f} vtime)")
    return {"mode": "async-dynamic", "scenario": args.scenario,
            "losses": losses, "events": K, "epochs": len(et.epochs),
            "published": published, "vtime": float(vtime)}


if __name__ == "__main__":
    main()
