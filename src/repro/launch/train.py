"""End-to-end R-FAST training driver (CPU-runnable at reduced scale).

Trains an LM with the R-FAST protocol wrapping per-node AdamW-free SGD on
the tracked direction, over a selectable topology, with checkpointing and
(optionally) simulated packet loss.

    PYTHONPATH=src python -m repro.launch.train \
        --arch rfast-100m --reduced --nodes 4 --steps 200 --topology binary_tree

``--impl pallas`` commits the protocol state through the fused
``kernels/rfast_update`` Pallas kernel (interpret mode off-TPU); the
default ``--impl jnp`` is the GSPMD dense-mixing path.  Both are the same
protocol (core/protocol.py) over the same CommPlan.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.metrics import MetricsLogger, StepTimer
from repro.configs import ARCHS, get_config
from repro.core.protocol import IMPLS
from repro.core.runtime import edge_arrays, init_node_state, make_rfast_round
from repro.core.topology import get_topology
from repro.data.pipeline import LMShardConfig, node_batch
from repro.models.transformer import init_params, loss_fn
from repro.optim.schedules import warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rfast-100m", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CI-scale)")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--topology", default="binary_tree")
    ap.add_argument("--gamma", type=float, default=3e-3)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--loss-prob", type=float, default=0.0)
    ap.add_argument("--impl", default="jnp", choices=IMPLS,
                    help="protocol backend: jnp (dense GSPMD mixing) or "
                         "pallas (fused update kernel)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--metrics", default="", help="JSONL metrics path")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = args.nodes
    topo = get_topology(args.topology, n)
    spec = edge_arrays(topo)
    shard_cfg = LMShardConfig(vocab=cfg.vocab,
                              batch_per_node=args.batch_per_node,
                              seq_len=args.seq, n_nodes=n, seed=args.seed)

    def grad_fn(params, batch, key):
        del key
        toks, labels = batch
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, toks, labels))(params)

    def batches_at(step: int):
        toks = np.stack([node_batch(shard_cfg, i, step)[0] for i in range(n)])
        labels = np.stack([node_batch(shard_cfg, i, step)[1]
                           for i in range(n)])
        return jnp.asarray(toks), jnp.asarray(labels)

    gamma = warmup_cosine(args.gamma, warmup=max(1, args.steps // 20),
                          total=args.steps)
    robust = args.loss_prob > 0
    # donate=True: the protocol state (x/z/ρ/ρ̃ — 2·|params|·N + 2·E_pad
    # buffers) updates in place instead of double-buffering; the loop
    # below rebinds ``state`` every step and never replays an old one
    round_fn = make_rfast_round(
        spec, grad_fn, gamma=gamma, robust=robust,
        momentum=args.momentum, impl=args.impl, donate=True)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M nodes={n} "
          f"topo={topo.name} robust={robust} impl={args.impl}")

    state = init_node_state(spec, params, grad_fn, batches_at(0), key,
                            robust=robust, momentum=args.momentum)
    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        start = latest_step(args.ckpt)
        state = load_checkpoint(args.ckpt, state)
        print(f"resumed from step {start}")

    rng = np.random.default_rng(args.seed + 1)
    logger = MetricsLogger(args.metrics) if args.metrics else None
    timer = StepTimer()
    t0 = time.time()
    for step in range(start, args.steps):
        masks = None
        if robust:
            masks = jnp.asarray(
                (rng.uniform(size=spec.e_pad) >= args.loss_prob),
                jnp.float32)
        keys = jax.random.split(jax.random.fold_in(key, step), n)
        state, metrics = round_fn(state, batches_at(step), keys, masks)
        timer.tick()
        if logger:
            logger.log(step + 1, loss=metrics["loss"],
                       sps=timer.steps_per_sec)
        if (step + 1) % args.log_every == 0:
            l = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {step+1:5d} loss {l:.4f} "
                  f"({dt:.1f}s, {timer.steps_per_sec:.2f} it/s)", flush=True)
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, step + 1, state)
    if logger:
        logger.close()
    print("done")


if __name__ == "__main__":
    main()
