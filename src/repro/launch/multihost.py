"""Multi-host bring-up for real TPU pods.

On a v5e pod slice every host runs the same program;
``jax.distributed.initialize()`` discovers the fleet from the TPU
metadata (or from COORDINATOR_ADDRESS / NUM_PROCESSES / PROCESS_ID env
for CPU/GPU clusters).  The R-FAST node axes are *global* mesh axes, so
the per-host code is identical to the single-host dry-run — only array
materialization changes (jax.make_array_from_process_local_data for
batches; checkpoint save/restore goes through the process-0 host).

    # per host (e.g. via scripts/launch_pod.sh or GKE/xpk):
    python -m repro.launch.multihost --arch llama3-8b --steps 100

Mesh-mapped sweep contract (DESIGN.md §13): the fleet engine
(``run_sweep(mesh=...)``) follows the same recipe on a pod.  Every host
calls :func:`initialize_distributed`, builds the SAME
``make_sweep_mesh(lanes=D, param_shards=M)`` over the *global* device
list, and calls ``run_sweep`` with identical host inputs (plans and wave
arrays are host-computed numpy — cheap and deterministic, so replicating
the build is simpler and safer than broadcasting it).  ``device_put``
with the §13 NamedShardings then places only each process's addressable
shards; the single-host CPU dev loop
(``repro.launch.xla_env.force_host_devices`` before jax init) runs the
exact same program on forced host devices, which is what the sharded
tests and the ``scaling/n*``/``lm100m/*`` bench rows pin.
"""
from __future__ import annotations

import argparse
import os


def initialize_distributed() -> tuple[int, int]:
    """Initialize jax.distributed; returns (process_index, process_count).

    No-ops gracefully for single-process runs (the common local case).
    """
    import jax

    if os.environ.get("COORDINATOR_ADDRESS"):
        jax.distributed.initialize(
            coordinator_address=os.environ["COORDINATOR_ADDRESS"],
            num_processes=int(os.environ["NUM_PROCESSES"]),
            process_id=int(os.environ["PROCESS_ID"]),
        )
    else:
        try:
            jax.distributed.initialize()      # TPU metadata autodetect
        except Exception:                     # noqa: BLE001 — single host
            pass
    return jax.process_index(), jax.process_count()


def host_local_batch(mesh, global_batch_struct, make_local):
    """Build a globally-sharded batch from per-host locally-produced data.

    ``make_local(process_index) -> host-local numpy pytree`` following the
    node-sharded layout; assembled with
    ``jax.make_array_from_process_local_data``.
    """
    import jax

    local = make_local(jax.process_index())
    return jax.tree.map(
        lambda struct, arr: jax.make_array_from_process_local_data(
            struct.sharding, arr),
        global_batch_struct, local)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    pid, pcount = initialize_distributed()
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_case

    if pid == 0:
        print(f"fleet: {pcount} processes, {len(jax.devices())} devices")
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, arg_structs = build_case(get_config(args.arch), mesh, args.shape)
    step = jax.jit(fn)
    compiled = step.lower(*arg_structs).compile()
    if pid == 0:
        ma = compiled.memory_analysis()
        print(f"compiled {args.arch}/{args.shape}: "
              f"{ma.argument_size_in_bytes/2**30:.2f} GiB/device args")
    # Real training would now materialize state via per-host init +
    # host_local_batch and loop `compiled(...)` — see launch/train.py for
    # the full loop at local scale.


if __name__ == "__main__":
    main()
