"""Serving driver: batched autoregressive decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch llama3-8b --reduced --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models.transformer import (decode_step, init_params,
                                      prefill_cache)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    front = None
    if cfg.frontend:
        front = jax.random.normal(
            key, (args.batch, cfg.frontend_seq,
                  cfg.frontend_dim or cfg.d_model), jnp.float32)

    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)

    t0 = time.perf_counter()
    # batched prefill: ONE forward fills the cache (models/transformer.py)
    cache, logits = jax.jit(
        lambda p, t, f: prefill_cache(cfg, p, t, max_len, frontend=f),
        static_argnames=())(params, prompts, front)
    jax.block_until_ready(logits)
    t1 = time.perf_counter()
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, 0] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    toks = np.asarray(jnp.concatenate(out, axis=1))
    t2 = time.perf_counter()
    print(f"arch={cfg.name} prefill {args.prompt_len} tok: {t1-t0:.2f}s; "
          f"decode {args.gen} tok x {args.batch} seq: {t2-t1:.2f}s "
          f"({args.gen*args.batch/(t2-t1):.1f} tok/s)")
    print("sample tokens:", toks[0, :16])


if __name__ == "__main__":
    main()
