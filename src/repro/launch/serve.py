"""Serving driver: continuous-batching decode over hot-swappable weights.

Thin CLI over :mod:`repro.serve` — a fixed-shape ``(B, max_len)`` decode
batch with slot recycling, a shape-keyed executable cache (zero compiles
at steady state) and a double-buffered :class:`WeightStore` that polls a
``--publish-dir`` written by ``launch/train.py`` and flips weights
between decode steps.  DESIGN.md §14 has the architecture.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch llama3-8b --reduced --batch 4 --requests 64 --rate 50

RNG discipline: the seed key is split ONCE per consumer (parameter init
vs traffic), matching ``core/simulator.py``'s per-event keys — the
previous one-shot script reused a single key for ``init_params``, the
frontend tensor AND the prompts, silently correlating the three streams.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import ARCHS, get_config
from repro.models.transformer import init_params
from repro.serve import (DEFAULT_BUCKETS, ServeEngine, WeightStore,
                         cache as serve_cache, make_workload)


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots B (fixed batch shape)")
    ap.add_argument("--max-len", type=int, default=64,
                    help="KV ring capacity bound per slot")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-prompt", type=int, default=16)
    ap.add_argument("--max-gen", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop arrival rate (req/s); 0 = closed "
                         "backlog (all requests queued at t=0)")
    ap.add_argument("--zipf-s", type=float, default=1.2)
    ap.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)),
                    help="comma-separated prompt-length buckets (one "
                         "prefill executable each)")
    ap.add_argument("--publish-dir", default="",
                    help="poll this checkpoint dir (written by train.py "
                         "--publish-dir) and hot-swap between decode steps")
    ap.add_argument("--poll-every", type=int, default=16,
                    help="poll the manifest every N engine steps")
    ap.add_argument("--swap-mode", default="drain",
                    choices=("drain", "immediate"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    # one stream per consumer — never reuse a key across draws
    k_init, k_traffic = jax.random.split(jax.random.PRNGKey(args.seed))
    params = init_params(cfg, k_init)
    store = WeightStore(params)
    if args.publish_dir:
        man = ckpt.read_manifest(args.publish_dir)
        if man is not None and store.poll(args.publish_dir):
            store.flip()
            print(f"loaded published step {store.step} "
                  f"from {args.publish_dir}")

    buckets = tuple(int(b) for b in args.buckets.split(",") if b)
    engine = ServeEngine(
        cfg, store, batch=args.batch, max_len=args.max_len,
        buckets=buckets, swap_mode=args.swap_mode,
        poll_every=args.poll_every if args.publish_dir else 0,
        ckpt_dir=args.publish_dir or None)

    reqs = make_workload(
        args.requests, vocab=cfg.vocab, max_prompt=args.max_prompt,
        max_gen=args.max_gen, rate_rps=args.rate, s=args.zipf_s,
        seed=int(jax.random.randint(k_traffic, (), 0, 2**31 - 1)))

    report = engine.run(reqs)
    step_us = [r["us"] for r in report["steps"]]
    p50, p99 = _percentile(step_us, 50), _percentile(step_us, 99)
    print(f"arch={cfg.name} B={args.batch} C={engine.C} "
          f"buckets={buckets} swap_mode={args.swap_mode}")
    print(f"served {len([r for r in reqs if r.done])}/{len(reqs)} req "
          f"({report['tokens']} tok) in {report['wall_s']:.2f}s "
          f"-> {report['reqs_per_s']:.1f} req/s")
    print(f"step p50 {p50:.0f}us p99 {p99:.0f}us; "
          f"swaps={len(report['swaps'])}; cache={report['cache']}")
    stats = serve_cache.stats()
    return {"mode": "serve", "arch": cfg.name,
            "served": sum(r.done for r in reqs),
            "reqs_per_s": report["reqs_per_s"], "p50_us": p50,
            "p99_us": p99, "swaps": len(report["swaps"]),
            "cache": stats, "report": report}


if __name__ == "__main__":
    main()
