"""npz-based pytree checkpointing with step metadata.

Layout: ``<dir>/step_<N>.npz`` holding flattened leaves keyed by path, plus
a ``_treedef`` json of the structure.  Atomic via tmp + rename.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path) or "_root"
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, _treedef=json.dumps(str(treedef)), **flat)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, like: Any, step: int | None = None) -> Any:
    """Restore into the structure of ``like`` (leaves replaced by saved)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    with np.load(path, allow_pickle=False) as data:
        flat = {k: data[k] for k in data.files if k != "_treedef"}
    ref = _flatten_with_paths(like)
    if set(ref) != set(flat):
        missing = set(ref) ^ set(flat)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]}")
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path_) or "_root" for path_, _ in leaves_ref]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), [flat[k] for k in keys])
