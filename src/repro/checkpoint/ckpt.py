"""npz-based pytree checkpointing with step metadata.

Layout: ``<dir>/step_<N>.npz`` holding flattened leaves keyed by path, plus
a ``_treedef`` json of the structure.  Every write is atomic (tmp file +
``fsync`` + ``os.replace``), and each successful save also replaces a
``LATEST.json`` manifest — the single pointer a polling reader (the
serving :class:`~repro.serve.weights.WeightStore`) follows, so a reader
can NEVER observe a torn checkpoint:

* the npz only appears under its final name after its bytes are durable;
* the manifest only points at a step whose npz replace already happened;
* a partial/corrupt npz (a crashed foreign writer, a truncated copy)
  is rejected by :func:`load_checkpoint` with a pointed error instead
  of a deep numpy traceback.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import time
import zipfile
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "read_manifest", "MANIFEST"]

MANIFEST = "LATEST.json"


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path) or "_root"
        flat[key] = np.asarray(leaf)
    return flat


def _atomic_write(path: str, write_fn) -> None:
    """Write via tmp file in the same dir + fsync + os.replace, so the
    final name only ever names a complete file."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            write_fn(fh)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    _atomic_write(path, lambda fh: np.savez(
        fh, _treedef=json.dumps(str(treedef)), **flat))
    manifest = {"step": int(step), "file": os.path.basename(path),
                "time": time.time(), "leaves": len(flat)}
    _atomic_write(os.path.join(ckpt_dir, MANIFEST),
                  lambda fh: fh.write(
                      (json.dumps(manifest) + "\n").encode()))
    return path


def read_manifest(ckpt_dir: str) -> dict | None:
    """The LATEST pointer: ``{"step", "file", "time", "leaves"}`` or
    ``None`` when the dir has no (readable) manifest yet.  A manifest
    pointing at a missing file is an error — the pointer is only ever
    replaced AFTER its npz, so this means external tampering."""
    path = os.path.join(ckpt_dir, MANIFEST)
    try:
        with open(path) as fh:
            man = json.load(fh)
    except FileNotFoundError:
        return None
    except (json.JSONDecodeError, OSError) as e:
        raise ValueError(
            f"unreadable checkpoint manifest {path}: {e} — manifests are "
            "written atomically by save_checkpoint; a torn one means a "
            "foreign writer bypassed it") from e
    target = os.path.join(ckpt_dir, man["file"])
    if not os.path.exists(target):
        raise ValueError(
            f"manifest {path} points at missing {man['file']} — "
            "save_checkpoint replaces the npz before the pointer, so "
            "the checkpoint file was removed out from under the reader")
    return man


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    man = read_manifest(ckpt_dir)
    if man is not None:
        return int(man["step"])
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, like: Any, step: int | None = None) -> Any:
    """Restore into the structure of ``like`` (leaves replaced by saved)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    try:
        with np.load(path, allow_pickle=False) as data:
            if "_treedef" not in data.files:
                raise ValueError("no _treedef record")
            flat = {k: data[k] for k in data.files if k != "_treedef"}
    except (zipfile.BadZipFile, EOFError, OSError, ValueError) as e:
        if isinstance(e, FileNotFoundError):
            raise
        raise ValueError(
            f"torn or partial checkpoint {path}: {e} — complete "
            "checkpoints only ever appear via save_checkpoint's "
            "tmp+fsync+rename, so this file was written by something "
            "else (or truncated in transit); refusing to load it") from e
    ref = _flatten_with_paths(like)
    if set(ref) != set(flat):
        missing = set(ref) ^ set(flat)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]}")
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path_) or "_root" for path_, _ in leaves_ref]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), [flat[k] for k in keys])
