"""JSONL metrics logging + step timing — the observability substrate.

Every record carries the step, a monotonic timestamp, and arbitrary
scalar fields; readers get pandas-free helpers for quick analysis.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Iterator


class MetricsLogger:
    """Append-only JSONL logger with buffered writes."""

    def __init__(self, path: str, flush_every: int = 10):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a", buffering=1)
        self._buf: list[str] = []
        self._flush_every = flush_every
        self._t0 = time.monotonic()

    def log(self, step: int, **fields: Any) -> None:
        rec = {"step": int(step), "t": round(time.monotonic() - self._t0, 4)}
        for k, v in fields.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        self._buf.append(json.dumps(rec))
        if len(self._buf) >= self._flush_every:
            self.flush()

    def flush(self) -> None:
        if self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._buf.clear()

    def close(self) -> None:
        self.flush()
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_metrics(path: str) -> Iterator[dict]:
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)


class StepTimer:
    """Rolling steps/sec + ETA."""

    def __init__(self, window: int = 20):
        self._times: list[float] = []
        self._window = window

    def tick(self) -> None:
        self._times.append(time.monotonic())
        if len(self._times) > self._window:
            self._times.pop(0)

    @property
    def steps_per_sec(self) -> float:
        if len(self._times) < 2:
            return 0.0
        dt = self._times[-1] - self._times[0]
        return (len(self._times) - 1) / dt if dt > 0 else 0.0

    def eta_s(self, remaining_steps: int) -> float:
        sps = self.steps_per_sec
        return remaining_steps / sps if sps > 0 else float("inf")
