"""Logical-axis sharding for model tensors (MaxText-style rules).

Model code annotates activations/params with *logical* axis names; a rule
table maps them to mesh axes.  Outside a mesh context every annotation is a
no-op, so the same model code runs in the simulator, smoke tests, and the
512-device dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["shard", "logical_to_spec", "mesh_rules", "DEFAULT_RULES",
           "FSDP_RULES", "current_rules"]

# logical axis -> mesh axis (None = replicated)
DEFAULT_RULES: dict[str, Optional[str]] = {
    "batch": "data",          # per-node batch (node axis handled outside)
    "node": "data",
    "seq": None,
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "expert": "model",
    "cap": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "kv_seq": None,
    "frontend": None,
}

# beyond-baseline: fully-sharded params (FSDP over the data axis on the
# embed dim) — used by the memory-term hillclimb.
FSDP_RULES = dict(DEFAULT_RULES, embed="data")

_local = threading.local()


def current_rules():
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def mesh_rules(mesh: Mesh | None, rules: dict[str, Optional[str]] | None = None):
    """Activate (mesh, rules) for `shard` annotations in this thread."""
    prev = current_rules()
    _local.ctx = (mesh, rules or DEFAULT_RULES) if mesh is not None else None
    try:
        yield
    finally:
        _local.ctx = prev


def _axis_size(mesh: Mesh, m) -> int:
    if isinstance(m, (tuple, list)):
        s = 1
        for a in m:
            s *= mesh.shape[a]
        return s
    return mesh.shape[m]


def logical_to_spec(axes: Sequence[Optional[str]],
                    rules: dict[str, Optional[str]],
                    shape: Sequence[int] | None = None,
                    mesh: Mesh | None = None) -> P:
    used: set[str] = set()
    spec = []
    for i, ax in enumerate(axes):
        m = rules.get(ax) if ax else None
        if m is not None:
            flat = tuple(m) if isinstance(m, (tuple, list)) else (m,)
            if any(a in used for a in flat):
                m = None
            elif shape is not None and mesh is not None \
                    and shape[i] % _axis_size(mesh, flat):
                m = None    # axis does not divide this dim: best-effort drop
            else:
                used.update(flat)
        spec.append(m)
    return P(*spec)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Best-effort logical sharding annotation; no-op without an active
    mesh, and skipped entirely when no axis maps (avoids forcing full
    replication via an all-None constraint)."""
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} vs axes {axes}")
    spec = logical_to_spec(axes, rules, x.shape, mesh)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, rules, *axes: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules))
