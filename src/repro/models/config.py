"""Unified architecture configuration covering all assigned families.

One ``ModelConfig`` describes dense / MoE / SSM / hybrid / VLM / audio
backbones; family-specific behaviour is selected by ``mixer`` /
``attention`` / ``moe_experts`` / ``enc_dec`` / ``frontend`` fields.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (ignored for pure SSM)
    n_kv_heads: int
    d_ff: int                    # dense MLP hidden (or per-expert hidden)
    vocab: int

    head_dim: int = 0            # 0 -> d_model // n_heads

    # --- token mixer ------------------------------------------------- #
    mixer: str = "attn"          # "attn" | "ssm" | "hybrid"
    attention: str = "gqa"       # "gqa" | "mla"
    attn_window: Optional[int] = None   # sliding window; None = full causal
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True        # False -> sinusoidal absolute positions

    # --- MLA (deepseek-v2) -------------------------------------------- #
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    v_head_dim: int = 0          # 0 -> head_dim

    # --- SSM (mamba-1) ------------------------------------------------ #
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0         # 0 -> ceil(d_model / 16)

    # --- MLP / MoE ----------------------------------------------------- #
    mlp: str = "swiglu"          # "swiglu" | "gelu"
    mlp_bias: bool = False
    moe_experts: int = 0         # 0 -> dense MLP
    moe_top_k: int = 0
    moe_shared: int = 0          # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- norms / embeddings -------------------------------------------- #
    norm: str = "rmsnorm"        # "rmsnorm" | "layernorm" | "nonparam_ln"
    tie_embeddings: bool = False

    # --- structure ------------------------------------------------------ #
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None   # "audio" | "vision" (stub embeds)
    frontend_seq: int = 0            # frames / patches per example
    frontend_dim: int = 0            # stub embedding dim

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.mixer not in ("attn", "ssm", "hybrid"):
            raise ValueError(f"bad mixer {self.mixer}")
        if self.attention not in ("gqa", "mla"):
            raise ValueError(f"bad attention {self.attention}")
        if self.mixer != "ssm":
            if self.n_heads <= 0:
                raise ValueError("attention mixer needs n_heads > 0")
            if self.attention == "gqa" and self.n_heads % max(1, self.n_kv_heads):
                raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.mixer in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError("ssm mixer needs ssm_state > 0")
        if self.moe_experts and not self.moe_top_k:
            raise ValueError("MoE needs moe_top_k")
        if self.enc_dec and self.n_enc_layers <= 0:
            raise ValueError("enc_dec needs n_enc_layers")

    # derived ----------------------------------------------------------- #
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def v_hd(self) -> int:
        return self.v_head_dim or self.hd

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    def reduced(self, *, n_layers: int = 2, max_d_model: int = 256,
                max_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (2 layers, tiny dims)."""
        d = min(self.d_model, max_d_model)
        # keep head structure ratios but shrink
        if self.mixer == "ssm":
            heads, kv = 0, 0
        else:
            ratio = max(1, self.n_heads // max(1, self.n_kv_heads))
            heads = max(ratio, 4)
            heads -= heads % ratio
            kv = max(1, heads // ratio)
        hd = max(8, (d // max(1, heads)) // 8 * 8) if heads else 0
        experts = min(self.moe_experts, max_experts)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            n_enc_layers=min(self.n_enc_layers, n_layers),
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 2 * d) if self.d_ff else 0,
            vocab=vocab,
            kv_lora_rank=min(self.kv_lora_rank, 32),
            q_lora_rank=min(self.q_lora_rank, 32),
            qk_rope_dim=min(self.qk_rope_dim, hd) if heads else self.qk_rope_dim,
            v_head_dim=hd if self.v_head_dim else 0,
            moe_experts=experts,
            moe_top_k=min(self.moe_top_k, max(1, experts // 2)) if experts else 0,
            moe_shared=min(self.moe_shared, 1),
            frontend_seq=min(self.frontend_seq, 16),
            frontend_dim=min(self.frontend_dim, d) if self.frontend_dim else 0,
        )

    # parameter count (analytic, for roofline MODEL_FLOPS) ---------------- #
    def param_count(self) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab
        n = 0
        n += V * d                          # embed
        if not self.tie_embeddings:
            n += d * V                      # lm head
        def attn_params() -> int:
            if self.mixer == "ssm":
                return 0
            if self.attention == "mla":
                r, rq = self.kv_lora_rank, self.q_lora_rank
                qk = self.hd + self.qk_rope_dim
                a = d * r + d * self.qk_rope_dim          # kv down + k_rope
                a += (rq and d * rq + rq * self.n_heads * qk) or d * self.n_heads * qk
                a += r * self.n_heads * (self.hd + self.v_hd)  # k_nope/v up
                a += self.n_heads * self.v_hd * d         # out
                return a
            q = d * self.n_heads * self.hd
            kv = 2 * d * self.n_kv_heads * self.hd
            o = self.n_heads * self.hd * d
            return q + kv + o
        def ssm_params() -> int:
            if self.mixer == "attn":
                return 0
            di, N, dtr = self.d_inner, self.ssm_state, self.dt_rank
            return (d * 2 * di + self.ssm_conv * di + di * (dtr + 2 * N)
                    + dtr * di + di * N + di + di * d)
        def mlp_params() -> int:
            if not ff:
                return 0
            per = (3 if self.mlp == "swiglu" else 2) * d * ff
            if self.moe_experts:
                return ((self.moe_experts + self.moe_shared) * per
                        + d * self.moe_experts)
            return per
        per_layer = attn_params() + ssm_params() + mlp_params()
        n += self.n_layers * per_layer
        if self.enc_dec:
            # encoder self-attn + mlp, decoder extra cross-attn
            enc_layer = attn_params() + mlp_params()
            n += self.n_enc_layers * enc_layer
            n += self.n_layers * attn_params()    # cross-attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if not self.moe_experts:
            return self.param_count()
        full = self.param_count()
        per = (3 if self.mlp == "swiglu" else 2) * self.d_model * self.d_ff
        layers = self.n_layers + (self.n_enc_layers if self.enc_dec else 0)
        inactive = (self.moe_experts - self.moe_top_k) * per * layers
        return full - inactive
