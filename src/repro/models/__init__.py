from .config import ModelConfig  # noqa: F401
from .transformer import (  # noqa: F401
    init_params, forward, loss_fn, init_cache, decode_step, prefill,
)
from .sharding import mesh_rules, shard, DEFAULT_RULES, FSDP_RULES  # noqa: F401
