"""Shared layer primitives: norms, MLPs, embeddings, rotary positions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import shard

__all__ = [
    "dense_init", "norm_init", "norm_apply", "mlp_init", "mlp_apply",
    "rope_cos_sin", "apply_rope", "sinusoidal_positions",
]


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ #
# norms
# ------------------------------------------------------------------ #
def norm_init(cfg: ModelConfig, dtype):
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if cfg.norm == "nonparam_ln":      # OLMo: no affine parameters
        return {}
    raise ValueError(cfg.norm)


def norm_apply(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        return (xf * r).astype(x.dtype) * p["scale"]
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-6)
    if cfg.norm == "layernorm":
        return y.astype(x.dtype) * p["scale"] + p["bias"]
    return y.astype(x.dtype)           # nonparam_ln


# ------------------------------------------------------------------ #
# dense MLP (swiglu / gelu)
# ------------------------------------------------------------------ #
def mlp_init(cfg: ModelConfig, key, dtype, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, ff, dtype),
         "wo": dense_init(ks[1], ff, d, dtype)}
    if cfg.mlp == "swiglu":
        p["wg"] = dense_init(ks[2], d, ff, dtype)
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((ff,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def mlp_apply(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    h = x @ p["wi"]
    if cfg.mlp_bias:
        h = h + p["bi"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "batch", "seq", "mlp")
    y = h @ p["wo"]
    if cfg.mlp_bias:
        y = y + p["bo"]
    return y


# ------------------------------------------------------------------ #
# positions
# ------------------------------------------------------------------ #
def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2) in fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B,S,H,D); cos/sin (B,S,D/2) or (S,D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
