"""Mixture-of-Experts MLP with top-k routing and capacity-bounded
scatter/gather dispatch (shardable: tokens over `data`, experts over
`model`; the token→expert exchange lowers to an all-to-all under GSPMD).

Supports shared (always-on) experts as in deepseek-v2 (2 shared + 160
routed, top-6) and phi-3.5-MoE (16 routed, top-2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, mlp_apply, mlp_init
from .sharding import shard

__all__ = ["moe_init", "moe_apply"]


def moe_init(cfg: ModelConfig, key, dtype):
    E = cfg.moe_experts
    ks = jax.random.split(key, 3)
    p = {
        "router": dense_init(ks[0], cfg.d_model, E, jnp.float32, scale=0.02),
        # experts stacked on a leading E axis
        "experts": jax.vmap(lambda k: mlp_init(cfg, k, dtype))(
            jax.random.split(ks[1], E)),
    }
    if cfg.moe_shared:
        p["shared"] = jax.vmap(lambda k: mlp_init(cfg, k, dtype))(
            jax.random.split(ks[2], cfg.moe_shared))
    return p


def _capacity(cfg: ModelConfig, T: int) -> int:
    c = int(cfg.capacity_factor * T * cfg.moe_top_k / cfg.moe_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8


def moe_apply(cfg: ModelConfig, p, x: jax.Array):
    """x (B,S,D) -> (y (B,S,D), aux_loss ())."""
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)        # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss (segment counts, no one-hot)
    me = probs.mean(axis=0)                                 # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx[:, 0]].add(1.0) / T
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # capacity-bounded dispatch: sort-based position assignment keeps
    # memory O(T·K) — a (T·K, E) one-hot cumsum would be ~TB-scale at
    # prefill_32k for 160-expert models.
    C = _capacity(cfg, T)
    flat_e = expert_idx.reshape(-1)                         # (T*K,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))      # (E,)
    pos_sorted = jnp.arange(flat_e.shape[0]) - starts[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    keep = pos < C
    gates = jnp.where(keep, gate_vals.reshape(-1), 0.0)

    safe_e = jnp.where(keep, flat_e, 0)
    safe_p = jnp.where(keep, pos, C - 1)
    xk = jnp.repeat(xt, K, axis=0)                          # (T*K, D)
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[safe_e, safe_p].add(
        jnp.where(keep[:, None], xk, 0).astype(x.dtype))
    buf = shard(buf, "expert", "cap", "embed")

    # expert computation via stacked einsums over the expert axis
    ep = p["experts"]
    h = jnp.einsum("ecd,edf->ecf", buf, ep["wi"])
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, ep["wg"])) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "expert", "cap", "mlp")
    out = jnp.einsum("ecf,efd->ecd", h, ep["wo"])
    out = shard(out, "expert", "cap", "embed")

    yk = out[safe_e, safe_p]                                # (T*K, D)
    y = (yk.astype(jnp.float32)
         * gates[:, None]).reshape(T, K, D).sum(axis=1)
    y = y.reshape(B, S, D)

    if cfg.moe_shared:
        for i in range(cfg.moe_shared):
            spi = jax.tree.map(lambda a, i=i: a[i], p["shared"])
            y = y + mlp_apply(cfg, spi, x).astype(jnp.float32)
    return y.astype(x.dtype), aux
