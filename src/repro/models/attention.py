"""Attention mixers: GQA (grouped-query) and MLA (multi-head latent,
deepseek-v2), with full-causal / sliding-window / non-causal masks, rotary
or absolute positions, and ring-buffer KV caches for decode.

Conventions:
* training / prefill call ``*_apply`` with the full sequence and no cache;
* decode calls ``*_decode`` with one new token and a cache dict.
* caches store K roped at absolute positions; slot validity is tracked by a
  ``pos`` array (−1 = empty) so sliding-window ring buffers need no shifts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense_init, rope_cos_sin
from .sharding import shard

__all__ = [
    "gqa_init", "gqa_apply", "gqa_decode", "gqa_cache",
    "mla_init", "mla_apply", "mla_decode", "mla_cache",
    "cross_init", "cross_apply", "cross_decode",
]

NEG = -1e30


# ------------------------------------------------------------------ #
# shared score/softmax core (grouped heads: no KV repeat materialized)
# ------------------------------------------------------------------ #
def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,G,R,dk)  k (B,Sk,G,dk)  v (B,Sk,G,dv)  mask (B,1,1,Sq,Sk)."""
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q, k) * scale
    s = jnp.where(mask, s.astype(jnp.float32), NEG)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bgrqk,bkgd->bqgrd", p, v)


def _causal_mask(Sq: int, Sk: int, window, offset: int = 0):
    """(Sq,Sk) causal (+sliding window) mask; offset = kv positions before q0."""
    qi = jnp.arange(Sq)[:, None] + offset
    ki = jnp.arange(Sk)[None, :]
    m = ki <= qi
    if window:
        m &= ki > qi - window
    return m


# ------------------------------------------------------------------ #
# GQA
# ------------------------------------------------------------------ #
def gqa_init(cfg: ModelConfig, key, dtype):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KV * hd, dtype),
        "wv": dense_init(ks[2], d, KV * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _qkv(cfg: ModelConfig, p, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    R = H // KV
    q = shard(q.reshape(B, S, H, hd), "batch", "seq", "heads", "head_dim")
    k = shard(k.reshape(B, S, KV, hd), "batch", "seq", "kv_heads", "head_dim")
    v = shard(v.reshape(B, S, KV, hd), "batch", "seq", "kv_heads", "head_dim")
    return q.reshape(B, S, KV, R, hd), k, v


def gqa_apply(cfg: ModelConfig, p, x, positions, *, causal=True,
              window=None, return_kv=False):
    """Full-sequence attention (train / prefill).  ``return_kv`` also
    returns the roped (k, v) for cache filling."""
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    if cfg.use_rope:
        cos, sin = rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
        qf = q.reshape(B, S, cfg.n_heads, cfg.hd)
        qf = apply_rope(qf, cos, sin).reshape(q.shape)
        k = apply_rope(k, cos, sin)
        q = qf
    if causal:
        mask = _causal_mask(S, S, window)[None, None, None]
    else:
        mask = jnp.ones((1, 1, 1, S, S), bool)
    o = _sdpa(q, k, v, mask, cfg.hd ** -0.5)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    out = o @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def gqa_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, capacity, KV, hd), dtype),
        "v": jnp.zeros((batch, capacity, KV, hd), dtype),
    }


def gqa_decode(cfg: ModelConfig, p, x, cache, pos, slot_pos, window=None):
    """One-token decode.  ``pos`` () current absolute position; ``slot_pos``
    (C,) the absolute position stored in each cache slot (−1 = empty),
    already including this step's write slot."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x)        # S = 1
    if cfg.use_rope:
        cos, sin = rope_cos_sin(pos[None], cfg.hd, cfg.rope_theta)
        qf = q.reshape(B, 1, cfg.n_heads, cfg.hd)
        q = apply_rope(qf, cos, sin).reshape(q.shape)
        k_new = apply_rope(k_new, cos, sin)
    C = cache["k"].shape[1]
    slot = (pos % C).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window:
        valid &= slot_pos > pos - window
    mask = valid[None, None, None, None, :]
    o = _sdpa(q, k, v, mask, cfg.hd ** -0.5)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return o, {"k": k, "v": v}


# ------------------------------------------------------------------ #
# MLA (deepseek-v2)
# ------------------------------------------------------------------ #
def mla_init(cfg: ModelConfig, key, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd, vd, r, rd = cfg.hd, cfg.v_hd, cfg.kv_lora_rank, cfg.qk_rope_dim
    ks = jax.random.split(key, 7)
    p = {
        "w_dkv": dense_init(ks[0], d, r, dtype),
        "c_scale": jnp.ones((r,), dtype),
        "w_kr": dense_init(ks[1], d, rd, dtype),
        "k_up": dense_init(ks[2], r, H * hd, dtype),
        "v_up": dense_init(ks[3], r, H * vd, dtype),
        "wo": dense_init(ks[4], H * vd, d, dtype),
    }
    if cfg.q_lora_rank:
        p["q_a"] = dense_init(ks[5], d, cfg.q_lora_rank, dtype)
        p["q_scale"] = jnp.ones((cfg.q_lora_rank,), dtype)
        p["q_b"] = dense_init(ks[6], cfg.q_lora_rank, H * (hd + rd), dtype)
    else:
        p["wq"] = dense_init(ks[5], d, H * (hd + rd), dtype)
    return p


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (xf * r).astype(x.dtype) * scale


def _mla_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H, hd, rd = cfg.n_heads, cfg.hd, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = _rms(x @ p["q_a"], p["q_scale"]) @ p["q_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, H, hd + rd)
    qn, qr = q[..., :hd], q[..., hd:]
    cos, sin = rope_cos_sin(positions, rd, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)
    return shard(qn, "batch", "seq", "heads", "head_dim"), \
        shard(qr, "batch", "seq", "heads", "head_dim")


def _mla_compress(cfg, p, x, positions):
    rd = cfg.qk_rope_dim
    c = _rms(x @ p["w_dkv"], p["c_scale"])              # (B,S,r)
    kr = (x @ p["w_kr"])[:, :, None, :]                  # (B,S,1,rd)
    cos, sin = rope_cos_sin(positions, rd, cfg.rope_theta)
    kr = apply_rope(kr, cos, sin)[:, :, 0, :]            # (B,S,rd)
    return c, kr


def _mla_attend(cfg, p, qn, qr, c, kr, mask):
    """qn (B,Sq,H,hd) qr (B,Sq,H,rd); c (B,Sk,r), kr (B,Sk,rd)."""
    B, Sk, _ = c.shape
    H, hd, vd = cfg.n_heads, cfg.hd, cfg.v_hd
    kn = (c @ p["k_up"]).reshape(B, Sk, H, hd)
    v = (c @ p["v_up"]).reshape(B, Sk, H, vd)
    kn = shard(kn, "batch", "seq", "heads", "head_dim")
    v = shard(v, "batch", "seq", "heads", "head_dim")
    scale = (hd + cfg.qk_rope_dim) ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", qn, kn)
    s = s + jnp.einsum("bqhd,bkd->bhqk", qr, kr)
    s = jnp.where(mask, s.astype(jnp.float32) * scale, NEG)
    pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", pr, v)
    return o.reshape(B, -1, H * vd) @ p["wo"]


def mla_apply(cfg: ModelConfig, p, x, positions, *, causal=True,
              window=None, return_kv=False):
    B, S, _ = x.shape
    qn, qr = _mla_q(cfg, p, x, positions)
    c, kr = _mla_compress(cfg, p, x, positions)
    mask = (_causal_mask(S, S, window) if causal
            else jnp.ones((S, S), bool))[None, None]
    out = _mla_attend(cfg, p, qn, qr, c, kr, mask)
    if return_kv:
        return out, (c, kr)
    return out


def mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    return {
        "c": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, capacity, cfg.qk_rope_dim), dtype),
    }


def mla_decode(cfg: ModelConfig, p, x, cache, pos, slot_pos, window=None):
    qn, qr = _mla_q(cfg, p, x, pos[None])
    c_new, kr_new = _mla_compress(cfg, p, x, pos[None])
    C = cache["c"].shape[1]
    slot = (pos % C).astype(jnp.int32)
    c = jax.lax.dynamic_update_slice(cache["c"], c_new, (0, slot, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr_new, (0, slot, 0))
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window:
        valid &= slot_pos > pos - window
    mask = valid[None, None, None, :]
    o = _mla_attend(cfg, p, qn, qr, c, kr, mask)
    return o, {"c": c, "kr": kr}


# ------------------------------------------------------------------ #
# cross-attention (enc-dec)
# ------------------------------------------------------------------ #
def cross_init(cfg: ModelConfig, key, dtype):
    return gqa_init(cfg, key, dtype)


def cross_kv(cfg: ModelConfig, p, enc):
    B, F, _ = enc.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = (enc @ p["wk"]).reshape(B, F, KV, hd)
    v = (enc @ p["wv"]).reshape(B, F, KV, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(KV, hd)
        v = v + p["bv"].reshape(KV, hd)
    return k, v


def cross_apply(cfg: ModelConfig, p, x, k, v):
    """x (B,S,D) queries over fixed encoder k/v (no positions: absolute
    embeddings already applied upstream)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, KV, H // KV, hd)
    mask = jnp.ones((1, 1, 1, S, k.shape[1]), bool)
    o = _sdpa(q, k, v, mask, hd ** -0.5)
    return o.reshape(B, S, H * hd) @ p["wo"]


def cross_decode(cfg: ModelConfig, p, x, k, v):
    return cross_apply(cfg, p, x, k, v)
