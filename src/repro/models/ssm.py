"""Mamba-1 selective SSM block (falcon-mamba / hymba's SSM heads).

Training/prefill uses a chunk-free ``lax.scan`` over time with an
O(B·d_inner·N) carry (no (S, d, N) materialization).  Decode carries
(conv window, ssm state) and costs O(d_inner·N) per token — the reason
``long_500k`` runs on SSM/hybrid archs.

The Pallas ``ssm_scan`` kernel implements the same recurrence with chunked
VMEM tiling; ``selective_scan_ref`` here is its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from .sharding import shard

__all__ = ["ssm_init", "ssm_apply", "ssm_decode", "ssm_cache",
           "selective_scan_ref"]


def ssm_init(cfg: ModelConfig, key, dtype):
    d, di, N, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(A),                        # (di, N) fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def selective_scan_ref(u, dt, A, Bc, Cc, D, h0=None):
    """Oracle selective scan.

    u (B,S,di) inputs; dt (B,S,di) timestep; A (di,N); Bc/Cc (B,S,N);
    D (di,).  Returns (y (B,S,di), h_last (B,di,N)).
    """
    Bsz, S, di = u.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, N), jnp.float32)

    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp       # (B,di) (B,di) (B,N) (B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])            # (B,di,N)
        dB = dt_t[..., None] * B_t[:, None, :]             # (B,di,N)
        h = dA * h + dB * u_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(u, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cc, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + u.astype(jnp.float32) * D[None, None]
    return y, h


def _conv_causal(x, w, b):
    """Depthwise causal conv1d: x (B,S,di), w (K,di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(K))
    return y + b[None, None]


def _ssm_inner(cfg, p, xz, conv_fn, h0=None):
    di = cfg.d_inner
    x, z = xz[..., :di], xz[..., di:]
    x = shard(x, "batch", "seq", "ssm_inner")
    x = jax.nn.silu(conv_fn(x))
    proj = x @ p["x_proj"]
    dtr, N = cfg.dt_rank, cfg.ssm_state
    dt = jax.nn.softplus(proj[..., :dtr] @ p["dt_proj"] + p["dt_bias"])
    Bc = proj[..., dtr:dtr + N]
    Cc = proj[..., dtr + N:]
    A = -jnp.exp(p["A_log"])
    y, h = selective_scan_ref(x, dt, A, Bc, Cc, p["D"], h0)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xz.dtype)
    return y, h, x


def ssm_apply(cfg: ModelConfig, p, x, return_state=False):
    """Full-sequence mamba block: x (B,S,D) -> (B,S,D).
    ``return_state`` also returns the decode cache (conv window, h)."""
    xz = x @ p["in_proj"]
    y, h, _ = _ssm_inner(
        cfg, p, xz, lambda u: _conv_causal(u, p["conv_w"], p["conv_b"]))
    out = y @ p["out_proj"]
    if return_state:
        K, di = cfg.ssm_conv, cfg.d_inner
        raw = xz[..., :di]
        pad = jnp.pad(raw, ((0, 0), (max(0, K - 1 - raw.shape[1]), 0),
                            (0, 0)))
        return out, {"conv": pad[:, -(K - 1):, :] if K > 1 else
                     jnp.zeros((x.shape[0], 0, di), xz.dtype), "h": h}
    return out


def ssm_cache(cfg: ModelConfig, batch: int, dtype):
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, K - 1, di), dtype),
        "h": jnp.zeros((batch, di, N), jnp.float32),
    }


def ssm_decode(cfg: ModelConfig, p, x, cache):
    """One-token decode: x (B,1,D)."""
    di, K = cfg.d_inner, cfg.ssm_conv
    xz = x @ p["in_proj"]

    def conv_fn(u):                       # u (B,1,di)
        win = jnp.concatenate([cache["conv"], u], axis=1)   # (B,K,di)
        y = jnp.einsum("bkd,kd->bd", win, p["conv_w"]) + p["conv_b"]
        return y[:, None, :]

    y, h, x_conv = _ssm_inner(cfg, p, xz, conv_fn, cache["h"])
    new_conv = jnp.concatenate(
        [cache["conv"][:, 1:], (xz[..., :di])], axis=1) if K > 1 else cache["conv"]
    return y @ p["out_proj"], {"conv": new_conv, "h": h}
