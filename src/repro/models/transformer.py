"""Model assembly: decoder-only / enc-dec transformers with attn, SSM,
hybrid mixers, dense or MoE MLPs, stub modality frontends, KV-cache decode.

Layers are *stacked* on a leading axis and executed with ``lax.scan`` so
60-layer configs lower to compact HLO (the dry-run/roofline path corrects
FLOP counts for the while-loop trip count).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (dense_init, mlp_apply, mlp_init, norm_apply, norm_init,
                     sinusoidal_positions)
from .sharding import shard

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "decode_step",
           "decode_step_slots", "prefill", "prefill_cache", "prefill_rows"]


# ------------------------------------------------------------------ #
# init
# ------------------------------------------------------------------ #
def _layer_init(cfg: ModelConfig, key, dtype, *, cross: bool, causal_attn=True):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"ln1": norm_init(cfg, dtype)}
    if cfg.mixer in ("attn", "hybrid"):
        if cfg.attention == "mla":
            p["attn"] = attn.mla_init(cfg, ks[0], dtype)
        else:
            p["attn"] = attn.gqa_init(cfg, ks[0], dtype)
    if cfg.mixer in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[1], dtype)
    if cross:
        p["ln_cross"] = norm_init(cfg, dtype)
        p["cross"] = attn.cross_init(cfg, ks[2], dtype)
    if cfg.moe_experts:
        p["ln2"] = norm_init(cfg, dtype)
        p["mlp"] = moe_mod.moe_init(cfg, ks[3], dtype)
    elif cfg.d_ff:
        p["ln2"] = norm_init(cfg, dtype)
        p["mlp"] = mlp_init(cfg, ks[3], dtype)
    return p


def _enc_layer_init(cfg: ModelConfig, key, dtype):
    """Encoder layer: full (non-causal) self-attention + dense MLP."""
    ks = jax.random.split(key, 2)
    p = {"ln1": norm_init(cfg, dtype),
         "attn": attn.gqa_init(cfg, ks[0], dtype),
         "ln2": norm_init(cfg, dtype),
         "mlp": mlp_init(cfg, ks[1], dtype)}
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": norm_init(cfg, dtype),
        "layers": jax.vmap(
            lambda k: _layer_init(cfg, k, dtype, cross=cfg.enc_dec))(
            jax.random.split(ks[1], cfg.n_layers)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, dtype)
    if cfg.frontend:
        fd = cfg.frontend_dim or cfg.d_model
        p["frontend_proj"] = dense_init(ks[3], fd, cfg.d_model, dtype)
    if cfg.enc_dec:
        p["enc_layers"] = jax.vmap(
            lambda k: _enc_layer_init(cfg, k, dtype))(
            jax.random.split(ks[4], cfg.n_enc_layers))
        p["enc_norm"] = norm_init(cfg, dtype)
    return p



def _scan_layers(body, carry, xs, unroll=False):
    """lax.scan over stacked layers, or a python unroll (used by the
    roofline's linear-in-L cost fit — XLA counts while bodies once)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    carry_out, ys = carry, []
    for i in range(L):
        xi = jax.tree.map(lambda a, i=i: a[i], xs)
        carry_out, y = body(carry_out, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry_out, ys

# ------------------------------------------------------------------ #
# forward (train / full sequence)
# ------------------------------------------------------------------ #
def _mixer_full(cfg: ModelConfig, lp, h, positions):
    if cfg.mixer == "ssm":
        return ssm_mod.ssm_apply(cfg, lp["ssm"], h)
    if cfg.attention == "mla":
        a = attn.mla_apply(cfg, lp["attn"], h, positions,
                           window=cfg.attn_window)
    else:
        a = attn.gqa_apply(cfg, lp["attn"], h, positions,
                           window=cfg.attn_window)
    if cfg.mixer == "hybrid":
        s = ssm_mod.ssm_apply(cfg, lp["ssm"], h)
        return 0.5 * (a + s)
    return a


def _layer_full(cfg: ModelConfig, lp, x, positions, enc=None, remat=False):
    def f(x):
        h = norm_apply(cfg, lp["ln1"], x)
        x1 = x + _mixer_full(cfg, lp, h, positions)
        if enc is not None:
            hc = norm_apply(cfg, lp["ln_cross"], x1)
            k, v = attn.cross_kv(cfg, lp["cross"], enc)
            x1 = x1 + attn.cross_apply(cfg, lp["cross"], hc, k, v)
        aux = jnp.zeros((), jnp.float32)
        if "mlp" in lp:
            h2 = norm_apply(cfg, lp["ln2"], x1)
            if cfg.moe_experts:
                y, aux = moe_mod.moe_apply(cfg, lp["mlp"], h2)
            else:
                y = mlp_apply(cfg, lp["mlp"], h2)
            x1 = x1 + y
        return shard(x1, "batch", "seq", "embed"), aux
    if remat:
        f = jax.checkpoint(f)
    return f(x)


def _run_encoder(cfg: ModelConfig, params, frontend, remat, unroll=False):
    e = frontend @ params["frontend_proj"]
    F = e.shape[1]
    e = e + sinusoidal_positions(jnp.arange(F), cfg.d_model).astype(e.dtype)
    positions = jnp.arange(F)

    def body(x, lp):
        h = norm_apply(cfg, lp["ln1"], x)
        x = x + attn.gqa_apply(cfg, lp["attn"], h, positions, causal=False)
        h2 = norm_apply(cfg, lp["ln2"], x)
        x = x + mlp_apply(cfg, lp["mlp"], h2)
        return shard(x, "batch", "seq", "embed"), None

    fn = jax.checkpoint(lambda x, lp: body(x, lp)) if remat else body
    e, _ = _scan_layers(fn, e, params["enc_layers"], unroll)
    return norm_apply(cfg, params["enc_norm"], e)


def forward(cfg: ModelConfig, params, tokens, frontend=None, *, remat=False,
            last_only=False, unroll=False):
    """tokens (B, S_text); frontend (B, F, fd) stub embeddings.

    Decoder-only VLM/audio-less: frontend rows are *prepended* to the token
    sequence.  Enc-dec: frontend feeds the encoder; tokens the decoder.
    ``last_only`` returns logits for the final position only (prefill
    serving: materializing (B, 32k, V) logits would be TB-scale).
    Returns (logits over the token positions, aux_loss).
    """
    x = params["embed"][tokens]
    enc = None
    n_front = 0
    if cfg.frontend and not cfg.enc_dec and frontend is not None:
        fx = frontend @ params["frontend_proj"]
        x = jnp.concatenate([fx.astype(x.dtype), x], axis=1)
        n_front = frontend.shape[1]
    if cfg.enc_dec:
        enc = _run_encoder(cfg, params, frontend, remat, unroll)
    S = x.shape[1]
    positions = jnp.arange(S)
    if not cfg.use_rope:
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq", "embed")

    def body(carry, lp):
        y, aux = _layer_full(cfg, lp, carry, positions, enc=enc, remat=remat)
        return y, aux

    x, auxs = _scan_layers(body, x, params["layers"], unroll)
    x = norm_apply(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    elif n_front:
        x = x[:, n_front:]
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, auxs.sum()


def loss_fn(cfg: ModelConfig, params, tokens, labels, frontend=None, *,
            remat=False, unroll=False, ce: str = "lse"):
    """ce="lse": CE via logsumexp — never materializes the fp32
    (B,S,V) log-prob tensor (only (B,S) reductions are fp32).
    ce="full": the naive fp32 log_softmax (kept for §Perf comparison)."""
    logits, aux = forward(cfg, params, tokens, frontend, remat=remat,
                          unroll=unroll)
    if ce == "full":
        lf = logits.astype(jnp.float32)
        ll = jax.nn.log_softmax(lf, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1)[..., 0]
        return nll.mean() + aux
    lse = jax.scipy.special.logsumexp(
        logits.astype(jnp.float32), axis=-1)             # (B, S) fp32
    tgt = jnp.take_along_axis(logits, labels[..., None],
                              axis=-1)[..., 0].astype(jnp.float32)
    return (lse - tgt).mean() + aux


# ------------------------------------------------------------------ #
# decode (serve_step)
# ------------------------------------------------------------------ #
def _mixer_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    c: dict[str, Any] = {}
    if cfg.mixer in ("attn", "hybrid"):
        if cfg.attention == "mla":
            c["attn"] = attn.mla_cache(cfg, batch, capacity, dtype)
        else:
            c["attn"] = attn.gqa_cache(cfg, batch, capacity, dtype)
    if cfg.mixer in ("ssm", "hybrid"):
        c["ssm"] = ssm_mod.ssm_cache(cfg, batch, dtype)
    return c


def cache_capacity(cfg: ModelConfig, max_len: int) -> int:
    if cfg.mixer == "ssm":
        return 1                                  # no KV cache at all
    return min(cfg.attn_window or max_len, max_len)


def init_cache(cfg: ModelConfig, params, batch: int, max_len: int,
               dtype=jnp.float32, frontend=None):
    C = cache_capacity(cfg, max_len)
    cache: dict[str, Any] = {
        "idx": jnp.zeros((), jnp.int32),
        "slot_pos": jnp.full((C,), -1, jnp.int32),
        "layers": jax.vmap(lambda _: _mixer_cache(cfg, batch, C, dtype))(
            jnp.arange(cfg.n_layers)),
    }
    if cfg.enc_dec:
        enc = _run_encoder(cfg, params, frontend, False)
        ck = jax.vmap(lambda lp: attn.cross_kv(cfg, lp, enc),
                      in_axes=(0,))(params["layers"]["cross"])
        cache["cross_k"], cache["cross_v"] = ck
    return cache


def _mixer_decode(cfg: ModelConfig, lp, lc, h, pos, slot_pos):
    new_lc = dict(lc)
    if cfg.mixer == "ssm":
        y, new_lc["ssm"] = ssm_mod.ssm_decode(cfg, lp["ssm"], h, lc["ssm"])
        return y, new_lc
    dec = attn.mla_decode if cfg.attention == "mla" else attn.gqa_decode
    a, new_lc["attn"] = dec(cfg, lp["attn"], h, lc["attn"], pos, slot_pos,
                            window=cfg.attn_window)
    if cfg.mixer == "hybrid":
        s, new_lc["ssm"] = ssm_mod.ssm_decode(cfg, lp["ssm"], h, lc["ssm"])
        a = 0.5 * (a + s)
    return a, new_lc


def decode_step(cfg: ModelConfig, params, cache, token, *, unroll=False):
    """token (B, 1) -> (logits (B, 1, V), new cache)."""
    pos = cache["idx"]
    C = cache["slot_pos"].shape[0]
    slot_pos = cache["slot_pos"].at[pos % C].set(pos)

    x = params["embed"][token]
    if not cfg.use_rope:
        x = x + sinusoidal_positions(pos[None], cfg.d_model).astype(x.dtype)

    has_cross = cfg.enc_dec

    def body(x, scanned):
        lp, lc, *ckv = scanned
        h = norm_apply(cfg, lp["ln1"], x)
        y, new_lc = _mixer_decode(cfg, lp, lc, h, pos, slot_pos)
        x = x + y
        if has_cross:
            hc = norm_apply(cfg, lp["ln_cross"], x)
            x = x + attn.cross_decode(cfg, lp["cross"], hc, ckv[0], ckv[1])
        if "mlp" in lp:
            h2 = norm_apply(cfg, lp["ln2"], x)
            if cfg.moe_experts:
                y2, _ = moe_mod.moe_apply(cfg, lp["mlp"], h2)
            else:
                y2 = mlp_apply(cfg, lp["mlp"], h2)
            x = x + y2
        return x, new_lc

    scanned = (params["layers"], cache["layers"])
    if has_cross:
        scanned = scanned + (cache["cross_k"], cache["cross_v"])
    x, new_layer_caches = _scan_layers(body, x, scanned, unroll)

    x = norm_apply(cfg, params["final_norm"], x)
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    new_cache = dict(cache)
    new_cache["idx"] = pos + 1
    new_cache["slot_pos"] = slot_pos
    new_cache["layers"] = new_layer_caches
    return logits, new_cache


def decode_step_slots(cfg: ModelConfig, params, cache, tokens, *,
                      unroll=False):
    """Continuous-batching decode: every batch slot advances its OWN
    position.  Same cache layout as :func:`init_cache` except ``idx``
    is ``(B,)`` and ``slot_pos`` is ``(B, C)`` — each slot is an
    independent request at its own depth, so a finished slot can be
    re-prefilled while its neighbours keep decoding.

    Implemented as a vmap of the single-sequence :func:`decode_step`
    over the slot axis (params broadcast, cache layers mapped on their
    batch axis), so the per-slot math is *definitionally* the B=1
    decode path.  tokens (B, 1) -> (logits (B, 1, V), new cache).
    """
    if cfg.enc_dec:
        raise ValueError("decode_step_slots serves decoder-only archs; "
                         f"{cfg.name} is enc-dec (cross caches have no "
                         "per-slot position)")

    def one(idx, slot_pos, layers):
        return {"idx": idx, "slot_pos": slot_pos,
                "layers": jax.tree.map(lambda a: a[:, None], layers)}

    def step(idx, slot_pos, layers, tok):
        logits, nc = decode_step(cfg, params, one(idx, slot_pos, layers),
                                 tok[None], unroll=unroll)
        return logits[0], nc["idx"], nc["slot_pos"], \
            jax.tree.map(lambda a: a[:, 0], nc["layers"])

    logits, idx, slot_pos, layers = jax.vmap(
        step, in_axes=(0, 0, 1, 0), out_axes=(0, 0, 0, 1))(
        cache["idx"], cache["slot_pos"], cache["layers"], tokens)
    return logits, {"idx": idx, "slot_pos": slot_pos, "layers": layers}


def prefill_rows(cfg: ModelConfig, params, tokens, true_len, capacity: int,
                 dtype=jnp.float32):
    """Bucketized prefill for ONE serving slot: tokens (B, Sb) are
    right-padded to a bucket length and ``true_len`` (traced scalar,
    1 <= true_len <= Sb) marks the valid prefix.

    Causality makes the padding inert where it matters: position i's KV
    row depends only on tokens <= i, so rows at positions < true_len are
    bit-identical to an unpadded prefill, and the contaminated tail
    (>= true_len) is never selected below.  Because ``true_len`` is
    traced, every prompt length inside a bucket reuses ONE compiled
    executable — the serving engine's cache is keyed by (arch, B, Sb,
    C), never by the actual prompt length.

    Returns ``(ring_layers, slot_pos (C,), logits (B, V))``:
    ``ring_layers`` leaves are ``(L, B, C, ...)`` decode-cache rows
    (the last min(true_len, C) valid positions at slots pos % C,
    zeros elsewhere), ``slot_pos`` the per-slot absolute positions
    (-1 = empty), and ``logits`` the next-token logits at position
    true_len - 1.
    """
    if cfg.mixer != "attn":
        raise ValueError(
            f"prefill_rows requires an attention mixer; {cfg.name} is "
            f"{cfg.mixer!r} — an SSM carry absorbs the pad tail, so "
            "bucketized prefill cannot recover the true_len state")
    if cfg.enc_dec or cfg.frontend:
        raise ValueError("prefill_rows serves decoder-only text archs; "
                         f"{cfg.name} has enc_dec/frontend stages")
    x = params["embed"][tokens]
    B, S, _ = x.shape
    positions = jnp.arange(S)
    if not cfg.use_rope:
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq", "embed")
    C = capacity

    def body(xc, lp):
        h = norm_apply(cfg, lp["ln1"], xc)
        if cfg.attention == "mla":
            a, (c, kr) = attn.mla_apply(cfg, lp["attn"], h, positions,
                                        window=cfg.attn_window,
                                        return_kv=True)
            kv = {"c": c, "kr": kr}
        else:
            a, (k, v) = attn.gqa_apply(cfg, lp["attn"], h, positions,
                                       window=cfg.attn_window,
                                       return_kv=True)
            kv = {"k": k, "v": v}
        xc = xc + a
        if "mlp" in lp:
            h2 = norm_apply(cfg, lp["ln2"], xc)
            if cfg.moe_experts:
                y2, _ = moe_mod.moe_apply(cfg, lp["mlp"], h2)
            else:
                y2 = mlp_apply(cfg, lp["mlp"], h2)
            xc = xc + y2
        return shard(xc, "batch", "seq", "embed"), kv

    x, layer_kv = jax.lax.scan(body, x, params["layers"])
    x = norm_apply(cfg, params["final_norm"], x)
    last = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
    head = params.get("lm_head")
    logits = (last @ head if head is not None
              else last @ params["embed"].T)[:, 0]

    # ring slot c holds the largest position p < true_len with
    # p % C == c (and p > true_len-1-C): p_c = q - ((q - c) mod C),
    # q = true_len - 1.  Out-of-range residues resolve to p_c < 0.
    q = true_len - 1
    p_c = q - ((q - jnp.arange(C, dtype=jnp.int32)) % C)
    valid = p_c >= 0
    slot_pos = jnp.where(valid, p_c, -1).astype(jnp.int32)

    def ring(kv):
        rows = jnp.take(kv, jnp.clip(p_c, 0, S - 1), axis=2)  # (L,B,C,...)
        mask = valid.reshape((1, 1, C) + (1,) * (kv.ndim - 3))
        return jnp.where(mask, rows, 0).astype(dtype)

    ring_layers = {"attn": jax.tree.map(ring, layer_kv)}
    return ring_layers, slot_pos, logits


def prefill(cfg: ModelConfig, params, cache, tokens):
    """Token-by-token prefill (test helper; production would batch this)."""
    def step(cache, tok):
        logits, cache = decode_step(cfg, params, cache, tok[:, None])
        return cache, logits[:, 0]
    cache, logits = jax.lax.scan(step, cache, jnp.moveaxis(tokens, 1, 0))
    return cache, jnp.moveaxis(logits, 0, 1)


def prefill_cache(cfg: ModelConfig, params, tokens, max_len: int,
                  dtype=jnp.float32, frontend=None):
    """Batched prefill: ONE full forward fills the decode cache.

    Returns (cache with idx = S_total, last-position logits (B, 1, V)).
    Equivalent to token-by-token ``prefill`` (tested) at full-sequence
    throughput — what a real serving system runs before decode.
    """
    x = params["embed"][tokens]
    enc = None
    if cfg.frontend and not cfg.enc_dec and frontend is not None:
        fx = frontend @ params["frontend_proj"]
        x = jnp.concatenate([fx.astype(x.dtype), x], axis=1)
    if cfg.enc_dec:
        enc = _run_encoder(cfg, params, frontend, False)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    if not cfg.use_rope:
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq", "embed")
    C = cache_capacity(cfg, max_len)

    def mixer_contrib(lp, h):
        lc = {}
        if cfg.mixer == "ssm":
            y, lc["ssm"] = ssm_mod.ssm_apply(cfg, lp["ssm"], h,
                                             return_state=True)
            return y, lc
        if cfg.attention == "mla":
            a, (c, kr) = attn.mla_apply(cfg, lp["attn"], h, positions,
                                        window=cfg.attn_window,
                                        return_kv=True)
            lc["attn"] = {"c": _to_ring(c, C, dtype),
                          "kr": _to_ring(kr, C, dtype)}
        else:
            a, (k, v) = attn.gqa_apply(cfg, lp["attn"], h, positions,
                                       window=cfg.attn_window,
                                       return_kv=True)
            lc["attn"] = {"k": _to_ring(k, C, dtype),
                          "v": _to_ring(v, C, dtype)}
        if cfg.mixer == "hybrid":
            sy, lc["ssm"] = ssm_mod.ssm_apply(cfg, lp["ssm"], h,
                                              return_state=True)
            a = 0.5 * (a + sy)
        return a, lc

    def body(xc, lp):
        h = norm_apply(cfg, lp["ln1"], xc)
        y, lc = mixer_contrib(lp, h)
        xc = xc + y
        if cfg.enc_dec:
            hc = norm_apply(cfg, lp["ln_cross"], xc)
            k, v = attn.cross_kv(cfg, lp["cross"], enc)
            xc = xc + attn.cross_apply(cfg, lp["cross"], hc, k, v)
        if "mlp" in lp:
            h2 = norm_apply(cfg, lp["ln2"], xc)
            if cfg.moe_experts:
                y2, _ = moe_mod.moe_apply(cfg, lp["mlp"], h2)
            else:
                y2 = mlp_apply(cfg, lp["mlp"], h2)
            xc = xc + y2
        return shard(xc, "batch", "seq", "embed"), lc

    x, layer_caches = jax.lax.scan(body, x, params["layers"])
    x = norm_apply(cfg, params["final_norm"], x)[:, -1:]
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T

    slot_pos = jnp.full((C,), -1, jnp.int32)
    n_fill = min(S, C)
    filled = jnp.arange(S - n_fill, S, dtype=jnp.int32)
    slot_pos = slot_pos.at[filled % C].set(filled)
    cache = {"idx": jnp.asarray(S, jnp.int32), "slot_pos": slot_pos,
             "layers": layer_caches}
    if cfg.enc_dec:
        ck = jax.vmap(lambda lp: attn.cross_kv(cfg, lp, enc),
                      in_axes=(0,))(params["layers"]["cross"])
        cache["cross_k"], cache["cross_v"] = ck
    return cache, logits


def _to_ring(t, C: int, dtype):
    """Place the last min(S, C) positions of t (B, S, ...) into a C-slot
    ring buffer at slots pos % C (matching decode's write pattern)."""
    B, S = t.shape[0], t.shape[1]
    n = min(S, C)
    tail = t[:, S - n:].astype(dtype)                # positions S-n .. S-1
    buf = jnp.zeros((B, C) + t.shape[2:], dtype)
    slots = (jnp.arange(S - n, S) % C).astype(jnp.int32)
    return buf.at[:, slots].set(tail)
