"""Diagnostic model shared by both analysis passes.

Every check emits :class:`Diagnostic` records with a *stable* code from
the RF1xx (plan) / RF2xx (jaxpr) namespaces.  Codes are append-only:
tools and CI parse them, so a code's meaning never changes once shipped.
The catalog below is the source of truth mirrored in DESIGN.md §12.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass(frozen=True)
class CodeInfo:
    """Catalog entry: the invariant, the shipped bug that motivated it,
    and the pass that owns it."""

    code: str
    owner: str          # "planlint" | "jaxlint"
    title: str
    invariant: str
    motivation: str     # which shipped bug class this guards against


CODES: dict[str, CodeInfo] = {c.code: c for c in [
    CodeInfo(
        "RF101", "planlint", "write-write race inside a wave",
        "Within one wave every non-sentinel agent id appears at most "
        "once, and every non-sentinel rho row index appears at most "
        "once — concurrent lanes never scatter to the same node or "
        "rho/rho-tilde row.",
        "Greedy wave grouping must break on a repeated agent; a dropped "
        "break silently merges two activations of one node into a "
        "single parallel commit."),
    CodeInfo(
        "RF102", "planlint", "history-ring slot alias / stale read",
        "Every ring-slot read resolves to the write count implied by "
        "the realized schedule (searchsorted over the sender's "
        "activation stamps), the payload precedes the reader's wave "
        "start, and the realized delay stays within H-1 slots so no "
        "in-flight write aliases an unread slot.",
        "The AD-PSGD bug class: PR 3 shipped a v_hist ring whose slot "
        "arithmetic let a delayed read see a *newer* overwrite of the "
        "slot under D close to H."),
    CodeInfo(
        "RF103", "planlint", "sentinel / index-range leak",
        "Every table index is in range or *exactly* its documented "
        "sentinel (agent==n, rho_gidx==2*e_a, kidx==K, fleet-scaled "
        "variants), sentinel lanes carry zero weight and validity, and "
        "per-wave sizes count exactly the non-sentinel lanes.",
        "PR 6's fleet padding leaked a sentinel into a gather table "
        "where clamping turned it into a silent read of row 0."),
    CodeInfo(
        "RF104", "planlint", "lane-offset bijection after flatten",
        "flatten_plans is invertible: every flat entry lies in its "
        "lane's offset block (or is the fleet sentinel) and un-offsets "
        "bit-for-bit to the stacked per-lane plan; event_start/sizes "
        "are the documented min/sum aggregates.",
        "A wrong lane offset makes lane s read lane s±1's state — the "
        "exact hazard of the PR 5/6 fleet-flattening rewrite."),
    CodeInfo(
        "RF105", "planlint", "Lemma-3 mass-conservation structure",
        "CommPlan weights satisfy Assumption 1 as *tables*: w_diag plus "
        "incoming w_edge mass is 1 per row, a_diag plus outgoing "
        "a_edge mass is 1 per column, diagonals are positive, every "
        "real edge is covered by exactly one receiver (and one sender "
        "for A) table slot, and pad slots are zero.",
        "Lemma 3's sum(z) + sum(rho - rho_buf) == sum(g_prev) "
        "conservation only holds if no edge mass is dropped or double "
        "counted by the gather tables (PR 2's donated-buffer alias "
        "corrupted exactly this ledger)."),
    CodeInfo(
        "RF106", "planlint", "epoch-boundary migration coverage",
        "EpochTrace epochs tile [0, K) contiguously; joined/departed "
        "masks are exactly the membership delta; each epoch's root is "
        "active and a common root of its topology; joiners always have "
        "an active donor; every prev-epoch edge connects nodes that "
        "were active, so migrate_state's settle pass covers all "
        "in-flight mass.",
        "PR 7's migrate_state settles in-flight rho at prev-epoch "
        "receivers — a row map missing an edge strands mass and breaks "
        "the conservation argument across the epoch boundary."),
    CodeInfo(
        "RF201", "jaxlint", "host callback inside a scan",
        "No pure_callback/io_callback/debug_callback primitive appears "
        "inside a scan or while body of an engine jaxpr.",
        "A host round-trip per wave serializes the wavefront loop and "
        "silently destroys the one-launch-per-wave design of PR 6."),
    CodeInfo(
        "RF202", "jaxlint", "silent f64/weak-type promotion",
        "No float64/complex128 intermediate appears in an engine jaxpr "
        "under the default f32 policy.",
        "A stray Python float or np.float64 constant upcasts a whole "
        "chain, doubling memory and splitting the dispatch cache key."),
    CodeInfo(
        "RF203", "jaxlint", "materialized neighbour-stack broadcast",
        "No gather/broadcast in an engine jaxpr materializes a rank>=3 "
        "(B, k, p)-shaped intermediate above the size threshold.",
        "The exact pattern PR 6 removed: stacking k neighbour vectors "
        "per lane before reducing, instead of fusing the reduction "
        "into the commit kernel."),
    CodeInfo(
        "RF204", "jaxlint", "donation declared but not honored",
        "Every donated input leaf can alias some distinct output leaf "
        "of identical shape and dtype, so the runtime can actually "
        "reuse the buffer.",
        "PR 2 donated packed state whose layout change made XLA copy "
        "instead of alias — donation became a silent no-op plus a "
        "use-after-donate hazard."),
    CodeInfo(
        "RF205", "jaxlint", "dispatch-cache churn",
        "Replaying an engine step with unchanged shapes adds no "
        "dispatch-cache entries and no misses beyond the expected "
        "one-entry steady state.",
        "PR 6's shape-specialized dispatch relies on ONE compile per "
        "fleet shape; a key that includes a varying component "
        "recompiles every chunk."),
    CodeInfo(
        "RF206", "jaxlint", "state-sized collective in the mesh body",
        "No collective inside the mesh-mapped sweep body materializes "
        "output at or above one lane group's full-width node state "
        "(S_loc*n*4*p_pad bytes) — inside a fully-manual shard_map "
        "region beyond-shard data can only arrive via a collective, so "
        "this bounds every path to accidental replication.  The "
        "designed per-wave gradient all_gather reconstructs at most "
        "the mixed iterates (<= threshold/4).",
        "The 'accidentally replicated' failure mode of PR 9's "
        "sharded parameter axis: an all_gather of the packed "
        "(S_loc*n,4,p) state (or a state-sized psum) makes every "
        "device hold the full 100M-parameter fleet again, silently "
        "undoing the model-axis sharding the mesh exists for."),
]}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a stable code, the artifact it was found in, a
    human message, and machine-readable locators."""

    code: str
    subject: str
    message: str
    data: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict[str, Any]:
        info = CODES.get(self.code)
        return {
            "code": self.code,
            "title": info.title if info else "",
            "owner": info.owner if info else "",
            "subject": self.subject,
            "message": self.message,
            "data": _jsonable(self.data),
        }

    def __str__(self) -> str:
        return f"{self.code} [{self.subject}] {self.message}"


class PlanInvariantError(AssertionError):
    """Raised by the engine `verify_plans=` hooks when any diagnostic
    fires; carries the offending diagnostics."""

    def __init__(self, diagnostics: list[Diagnostic], context: str = ""):
        self.diagnostics = list(diagnostics)
        head = f"{context}: " if context else ""
        lines = "\n  ".join(str(d) for d in self.diagnostics)
        super().__init__(
            f"{head}{len(self.diagnostics)} plan invariant violation(s)"
            f"\n  {lines}")


def _jsonable(obj):
    """Best-effort conversion of numpy scalars/arrays for json.dumps."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "tolist"):
        return obj.tolist()
    if hasattr(obj, "item"):
        return obj.item()
    return obj


def report_json(diagnostics: list[Diagnostic], **extra) -> str:
    doc = dict(extra)
    doc["diagnostics"] = [d.to_json() for d in diagnostics]
    return json.dumps(doc, indent=2, sort_keys=False)
