"""Host-side race/alias/sentinel linting over plan objects (RF101–RF106).

All checks run on numpy arrays before anything is traced or compiled:
the point is to reject a corrupt ``CommPlan`` / ``WavefrontPlan`` /
``EpochTrace`` *before* it becomes a silently-wrong XLA program.  Every
function returns ``list[Diagnostic]`` and never raises on bad plans
(use :func:`check_or_raise` for the engines' assert-on-diagnostic mode).

Code ownership (mutation tests rely on each pass emitting only its own
codes):

* :func:`lint_comm_plan`      — RF105
* :func:`lint_wavefront_plan` — RF101, RF102, RF103
* :func:`lint_flatten`        — RF104
* :func:`lint_epoch_trace`    — RF106
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.schedule import _WAVE_FIELDS, WavefrontPlan
from .diagnostics import Diagnostic, PlanInvariantError

__all__ = [
    "lint_comm_plan", "lint_wavefront_plan", "lint_flatten",
    "lint_epoch_trace", "lint_grid_tables", "unflatten_plans",
    "lane_views", "check_or_raise",
]

_MAX_SITES = 5   # locator entries kept per diagnostic


def _d(code, subject, message, **data):
    return Diagnostic(code=code, subject=subject, message=message,
                      data=data)


def _sites(*idx_arrays):
    """First few offending index tuples, for the diagnostic locator."""
    return [tuple(int(a[i]) for a in idx_arrays)
            for i in range(min(len(idx_arrays[0]), _MAX_SITES))]


# ------------------------------------------------------------------ #
# RF105: CommPlan mass-conservation structure
# ------------------------------------------------------------------ #
def lint_comm_plan(plan, topo=None, *, subject="comm_plan",
                   atol=1e-5) -> list[Diagnostic]:
    """Lemma-3 structural audit of a :class:`~repro.core.plan.CommPlan`.

    Mass conservation (sum z + sum(rho - rho_buf) == sum g_prev) holds
    iff the *tables* the kernels actually gather through carry exactly
    the Assumption-1 weights: each row of W sums to 1 through w_diag +
    incoming w_edge, each column of A sums to 1 through a_diag +
    outgoing a_edge, and every real edge appears in exactly one
    receiver (and, for A, one sender) table slot.
    """
    diags = []
    n = int(plan.n)
    new, nea = int(plan.n_edges_w), int(plan.n_edges_a)

    def rf(message, **data):
        diags.append(_d("RF105", subject, message, **data))

    for name in ("w_diag", "a_diag"):
        bad = np.nonzero(np.asarray(getattr(plan, name)) <= 0)[0]
        if bad.size:
            rf(f"{name} must be strictly positive (Assumption 1), "
               f"found {bad.size} non-positive entries",
               nodes=bad[:_MAX_SITES])

    # dense-edge stochasticity through the edge arrays
    row = np.asarray(plan.w_diag, np.float64).copy()
    np.add.at(row, np.asarray(plan.dst_w[:new]),
              np.asarray(plan.w_edge[:new], np.float64))
    bad = np.nonzero(np.abs(row - 1.0) > atol)[0]
    if bad.size:
        rf("W rows do not sum to 1 through w_diag + incoming w_edge "
           f"mass (max err {np.abs(row - 1.0).max():.3g})",
           nodes=bad[:_MAX_SITES], sums=row[bad[:_MAX_SITES]])
    col = np.asarray(plan.a_diag, np.float64).copy()
    np.add.at(col, np.asarray(plan.src_a[:nea]),
              np.asarray(plan.a_edge[:nea], np.float64))
    bad = np.nonzero(np.abs(col - 1.0) > atol)[0]
    if bad.size:
        rf("A columns do not sum to 1 through a_diag + outgoing a_edge "
           f"mass (max err {np.abs(col - 1.0).max():.3g})",
           nodes=bad[:_MAX_SITES], sums=col[bad[:_MAX_SITES]])

    # pad tails of the edge arrays must be inert
    for arr, k in (("src_w", new), ("dst_w", new), ("w_edge", new),
                   ("src_a", nea), ("dst_a", nea), ("a_edge", nea)):
        tail = np.asarray(getattr(plan, arr))[k:]
        if tail.size and np.any(tail != 0):
            rf(f"{arr} pad tail (rows >= {k}) must be zero",
               entries=np.nonzero(tail != 0)[0][:_MAX_SITES] + k)

    nodes = np.arange(n)[:, None]

    # receiver W table: every used slot points at a real in-edge of the
    # node with the dense edge weight, and the real edges are covered
    # exactly once across all nodes
    use = np.asarray(plan.in_w_wt) != 0
    epos = np.asarray(plan.in_w_epos)
    if np.any(use & (epos >= new)):
        rf("in_w_epos points past the real W-edge range on a weighted "
           "slot", sites=_sites(*np.nonzero(use & (epos >= new))))
        use = use & (epos < new)
    owned = np.broadcast_to(nodes, epos.shape)
    bad = use & (np.asarray(plan.dst_w)[epos] != owned)
    if np.any(bad):
        rf("in_w table slot names an edge whose dst is another node",
           sites=_sites(*np.nonzero(bad)))
    bad = use & (np.asarray(plan.in_w_src)
                 != np.asarray(plan.src_w)[epos])
    if np.any(bad):
        rf("in_w_src disagrees with src_w[in_w_epos]",
           sites=_sites(*np.nonzero(bad)))
    bad = use & ~np.isclose(np.asarray(plan.in_w_wt),
                            np.asarray(plan.w_edge)[epos], atol=atol)
    if np.any(bad):
        rf("in_w_wt disagrees with w_edge[in_w_epos]",
           sites=_sites(*np.nonzero(bad)))
    cover = np.bincount(epos[use].ravel(), minlength=max(new, 1))[:new]
    if np.any(cover != 1):
        rf("every real W edge must be claimed by exactly one receiver "
           "slot (missing edges strand mass; duplicates double it)",
           edges=np.nonzero(cover != 1)[0][:_MAX_SITES],
           counts=cover[cover != 1][:_MAX_SITES])

    # receiver/sender A tables: same shape of argument on the rho ledger
    use = np.asarray(plan.in_a_val) > 0
    epos = np.asarray(plan.in_a_epos)
    if np.any(use & (epos >= nea)):
        rf("in_a_epos points past the real A-edge range on a valid "
           "slot", sites=_sites(*np.nonzero(use & (epos >= nea))))
        use = use & (epos < nea)
    bad = use & (np.asarray(plan.dst_a)[epos]
                 != np.broadcast_to(nodes, epos.shape))
    if np.any(bad):
        rf("in_a table slot names an edge whose dst is another node",
           sites=_sites(*np.nonzero(bad)))
    cover = np.bincount(epos[use].ravel(), minlength=max(nea, 1))[:nea]
    if np.any(cover != 1):
        rf("every real A edge must be claimed by exactly one receiver "
           "slot", edges=np.nonzero(cover != 1)[0][:_MAX_SITES],
           counts=cover[cover != 1][:_MAX_SITES])

    use = np.asarray(plan.out_a_val) > 0
    epos = np.asarray(plan.out_a_epos)
    if np.any(use & (epos >= nea)):
        rf("out_a_epos points past the real A-edge range on a valid "
           "slot", sites=_sites(*np.nonzero(use & (epos >= nea))))
        use = use & (epos < nea)
    bad = use & (np.asarray(plan.src_a)[epos]
                 != np.broadcast_to(nodes, epos.shape))
    if np.any(bad):
        rf("out_a table slot names an edge whose src is another node",
           sites=_sites(*np.nonzero(bad)))
    bad = use & ~np.isclose(np.asarray(plan.out_a_wt),
                            np.asarray(plan.a_edge)[epos], atol=atol)
    if np.any(bad):
        rf("out_a_wt disagrees with a_edge[out_a_epos]",
           sites=_sites(*np.nonzero(bad)))
    cover = np.bincount(epos[use].ravel(), minlength=max(nea, 1))[:nea]
    if np.any(cover != 1):
        rf("every real A edge must be claimed by exactly one sender "
           "slot", edges=np.nonzero(cover != 1)[0][:_MAX_SITES],
           counts=cover[cover != 1][:_MAX_SITES])

    # pad table slots must be fully inert
    bad = (np.asarray(plan.out_a_val) <= 0) \
        & (np.asarray(plan.out_a_wt) != 0)
    if np.any(bad):
        rf("out_a_wt must be zero on slots with out_a_val == 0",
           sites=_sites(*np.nonzero(bad)))

    # against the topology itself (same check validate_weights makes on
    # the dense matrices, here confirmed to survive table extraction)
    if topo is not None:
        W = np.asarray(topo.W, np.float64)
        A = np.asarray(topo.A, np.float64)
        if not np.allclose(np.asarray(plan.w_diag), np.diag(W),
                           atol=atol):
            rf("w_diag disagrees with diag(W) of the source topology")
        if not np.allclose(np.asarray(plan.a_diag), np.diag(A),
                           atol=atol):
            rf("a_diag disagrees with diag(A) of the source topology")
    return diags


# ------------------------------------------------------------------ #
# RF101/RF102/RF103: WavefrontPlan races, ring slots, sentinels
# ------------------------------------------------------------------ #
def lane_views(wf: WavefrontPlan):
    """Per-lane 2D views of a stacked (leading-S-axis) plan."""
    for s in range(wf.n_lanes):
        yield s, dataclasses.replace(
            wf, **{f: getattr(wf, f)[s] for f in _WAVE_FIELDS})


def lint_wavefront_plan(wf: WavefrontPlan, *, comm=None, schedule=None,
                        H=None, subject="wavefront"
                        ) -> list[Diagnostic]:
    """RF101 (in-wave write-write races), RF102 (history-ring slot
    resolution and staleness, needs ``comm`` + ``schedule`` + ``H``),
    RF103 (index ranges and sentinel hygiene).

    Accepts single plans (2D lane axes) and stacked fleet plans (3D);
    stacked plans are linted lane-by-lane, with ``comm``/``schedule``
    given as per-lane sequences (or one shared object).
    """
    if np.asarray(wf.agent).ndim == 3:
        per = lambda o, s: (o[s] if isinstance(o, (list, tuple)) else o)
        out = []
        for s, lane in lane_views(wf):
            out.extend(lint_wavefront_plan(
                lane, comm=per(comm, s), schedule=per(schedule, s),
                H=H, subject=f"{subject}/lane{s}"))
        return out

    diags = []
    diags.extend(_lint_wf_sentinels(wf, H=H, subject=subject))
    diags.extend(_lint_wf_races(wf, subject=subject))
    if comm is not None and schedule is not None and H is not None:
        diags.extend(_lint_wf_ring(wf, comm, schedule, int(H),
                                   subject=subject))
    return diags


def _lint_wf_sentinels(wf, *, H, subject):
    """RF103: every index in-range or exactly its documented sentinel,
    with zero weight/validity on sentinel rows."""
    diags = []
    n, e_a, K = int(wf.n), int(wf.e_a), int(wf.K)
    ko = wf.out_wt.shape[-1]
    ag = np.asarray(wf.agent)
    kidx = np.asarray(wf.kidx)
    pad = ag == n

    def rf(message, **data):
        diags.append(_d("RF103", subject, message, **data))

    bad = (ag < 0) | (ag > n)
    if np.any(bad):
        rf(f"agent entries outside [0, n={n}] and not the sentinel",
           sites=_sites(*np.nonzero(bad)),
           values=ag[bad][:_MAX_SITES])
    bad = pad != (kidx == K)
    if np.any(bad):
        rf(f"kidx sentinel ({K}) must coincide exactly with the agent "
           f"sentinel ({n})", sites=_sites(*np.nonzero(bad)))
    bad = ~pad & ((kidx < 0) | (kidx >= K))
    if np.any(bad):
        rf(f"live-lane kidx outside [0, K={K})",
           sites=_sites(*np.nonzero(bad)))

    # sentinel lanes carry no weight or validity anywhere
    for f in ("w_self", "a_self", "w_in", "a_val", "out_wt"):
        a = np.asarray(getattr(wf, f))
        m = pad if a.ndim == 2 else pad[..., None]
        bad = (a != 0) & m
        if np.any(bad):
            rf(f"sentinel lanes must carry zero {f}",
               sites=_sites(*np.nonzero(bad)))
    g = np.asarray(wf.rho_gidx)
    if np.any(g[pad] != 2 * e_a):
        rf(f"sentinel lanes must carry all-sentinel rho_gidx "
           f"(== {2 * e_a})", sites=_sites(np.nonzero(
               np.any(g[pad] != 2 * e_a, axis=-1))[0]))

    bad = (g < 0) | (g > 2 * e_a)
    if np.any(bad):
        rf(f"rho_gidx outside [0, 2*e_a={2 * e_a}]",
           sites=_sites(*np.nonzero(bad)), values=g[bad][:_MAX_SITES])
    # sentinel rho rows must have zero weight/validity, and live in-A
    # rows must point at exactly e_a + hist_epos (the flat rho-tilde
    # block the history scatters use)
    out_wt = np.asarray(wf.out_wt)
    bad = (g[..., :ko] == 2 * e_a) & (out_wt != 0)
    if np.any(bad):
        rf("sentinel rho-out rows must carry zero out_wt",
           sites=_sites(*np.nonzero(bad)))
    a_val = np.asarray(wf.a_val)
    he = np.asarray(wf.hist_epos)
    gin = g[..., ko:]
    bad = (gin == 2 * e_a) != (a_val <= 0)
    if np.any(bad):
        rf("in-A rho_gidx sentinel must coincide exactly with zero "
           "a_val", sites=_sites(*np.nonzero(bad)))
    live = a_val > 0
    bad = live & (gin != e_a + he)
    if np.any(bad):
        rf("live in-A rho_gidx must equal e_a + hist_epos "
           "(the flat rho-tilde row)", sites=_sites(*np.nonzero(bad)))

    bad = (np.asarray(wf.src_v) < 0) | (np.asarray(wf.src_v) >= n)
    if np.any(bad):
        rf(f"src_v outside [0, n={n})", sites=_sites(*np.nonzero(bad)))
    bad = (he < 0) | (he >= e_a)
    if np.any(bad):
        rf(f"hist_epos outside [0, e_a={e_a})",
           sites=_sites(*np.nonzero(bad)))
    if H is not None:
        for f in ("wslot", "rslot_v", "rslot_rho"):
            a = np.asarray(getattr(wf, f))
            bad = (a < 0) | (a >= int(H))
            if np.any(bad):
                rf(f"{f} outside the history ring [0, H={int(H)})",
                   sites=_sites(*np.nonzero(bad)))

    sizes = np.asarray(wf.sizes)
    live_count = np.sum(~pad, axis=-1)
    bad = np.nonzero(sizes != live_count)[0]
    if bad.size:
        rf("sizes must count exactly the non-sentinel lanes per wave",
           waves=bad[:_MAX_SITES], sizes=sizes[bad][:_MAX_SITES],
           live=live_count[bad][:_MAX_SITES])
    es = np.asarray(wf.event_start)
    bad = np.nonzero((es < 0) | (es > K))[0]
    if bad.size:
        rf(f"event_start outside [0, K={K}]", waves=bad[:_MAX_SITES])
    kmin = np.where(pad, K, kidx).min(axis=-1)
    bad = np.nonzero((live_count > 0) & (es > kmin))[0]
    if bad.size:
        rf("event_start must not exceed the wave's earliest live kidx",
           waves=bad[:_MAX_SITES])
    return diags


def _lint_wf_races(wf, *, subject):
    """RF101: no two lanes of one wave scatter to the same node row or
    the same live rho/rho-tilde row."""
    diags = []
    n, e_a = int(wf.n), int(wf.e_a)
    ag = np.asarray(wf.agent)

    live = np.where((ag >= 0) & (ag < n), ag, n)
    srt = np.sort(live, axis=-1)
    dup = (srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] < n)
    if np.any(dup):
        w = np.nonzero(np.any(dup, axis=-1))[0]
        diags.append(_d(
            "RF101", subject,
            "two lanes of one wave write the same node's rows "
            "(write-write race on x/v/z/g_prev and the history ring)",
            waves=w[:_MAX_SITES],
            agents=[int(srt[i][1:][dup[i]][0]) for i in w[:_MAX_SITES]]))

    g = np.asarray(wf.rho_gidx).reshape(ag.shape[0], -1)
    gs = np.sort(g, axis=-1)
    dup = (gs[:, 1:] == gs[:, :-1]) & (gs[:, 1:] < 2 * e_a)
    if np.any(dup):
        w = np.nonzero(np.any(dup, axis=-1))[0]
        diags.append(_d(
            "RF101", subject,
            "two lane slots of one wave commit to the same flat "
            "rho/rho-tilde row (write-write race on the mass ledger)",
            waves=w[:_MAX_SITES],
            rows=[int(gs[i][1:][dup[i]][0]) for i in w[:_MAX_SITES]]))
    return diags


def _lint_wf_ring(wf, comm, schedule, H, *, subject):
    """RF102: re-derive every ring-slot read from the realized schedule
    and reject aliasing/staleness the ring cannot represent.

    The sender's w-th write lands in slot ``w % H`` (write counters
    start at 1; slot 0 doubles as the zero-init "no write yet" row).  A
    read in a wave starting at event ``s0`` sees write ``w`` intact iff
    the payload was emitted before the wave (``w <= c_pre``) and at most
    ``H - 1`` further writes happened before the wave
    (``c_pre - w <= H - 1``) — otherwise an in-flight write has aliased
    the slot (the AD-PSGD ring bug).
    """
    diags = []
    n, K = int(wf.n), int(wf.K)
    ag = np.asarray(wf.agent)
    kidx = np.asarray(wf.kidx)
    sched_agent = np.asarray(schedule.agent)
    if K != sched_agent.shape[0]:
        return [_d("RF102", subject,
                   f"schedule has {sched_agent.shape[0]} events but the "
                   f"plan claims K={K}; ring checks need the realized "
                   "schedule of this exact plan")]
    emit = [np.nonzero(sched_agent == j)[0] + 1 for j in range(n)]

    vw, vl = np.nonzero((ag >= 0) & (ag < n) & (kidx >= 0) & (kidx < K))
    agents = ag[vw, vl]
    ks = kidx[vw, vl]
    s0s = np.asarray(wf.event_start)[vw]

    def rf(message, **data):
        diags.append(_d("RF102", subject, message, **data))

    def check_half(stamp_table, epos_tab, owner_of, rslot, wt, kind):
        kk = np.asarray(getattr(wf, rslot))[vw, vl]     # (V, k)
        ww = np.asarray(getattr(wf, wt))[vw, vl]
        for c in range(kk.shape[-1]):
            use = ww[:, c] > 0 if kind == "rho" else ww[:, c] != 0
            if not np.any(use):
                continue
            epos = np.asarray(epos_tab)[agents[use], c]
            owners = np.asarray(owner_of)[epos]
            stamps = np.asarray(stamp_table)[ks[use], epos]
            slot_have = kk[use, c]
            starts = s0s[use]
            for j in np.unique(owners):
                m = owners == j
                em = emit[int(j)]
                w = np.searchsorted(em, stamps[m], side="right")
                c_pre = np.searchsorted(em, starts[m], side="right")
                bad = slot_have[m] != (w % H)
                if np.any(bad):
                    rf(f"{kind} ring-slot reads disagree with the "
                       f"schedule-resolved write count (sender {int(j)})",
                       column=c, count=int(bad.sum()),
                       events=ks[use][m][bad][:_MAX_SITES])
                bad = w > c_pre
                if np.any(bad):
                    rf(f"{kind} read consumes a payload written at or "
                       f"after its own wave start (sender {int(j)})",
                       column=c, events=ks[use][m][bad][:_MAX_SITES])
                bad = (c_pre - w) > (H - 1)
                if np.any(bad):
                    rf(f"{kind} read outlives the ring: sender "
                       f"{int(j)} rewrote the slot before the read "
                       f"(realized staleness > H-1 = {H - 1})",
                       column=c, events=ks[use][m][bad][:_MAX_SITES],
                       staleness=(c_pre - w)[bad][:_MAX_SITES])

    check_half(schedule.stamp_v, comm.in_w_epos, comm.src_w,
               "rslot_v", "w_in", "v")
    check_half(schedule.stamp_rho, comm.in_a_epos, comm.src_a,
               "rslot_rho", "a_val", "rho")

    # in-wave write vs read aliasing on the ring: for each wave, no
    # lane's (writer, wslot) pair may equal a (sender, rslot) pair some
    # lane in the same wave reads — the write is concurrent with the
    # read inside one launch.
    wsl = np.asarray(wf.wslot)[vw, vl]
    writer_key = agents.astype(np.int64) * H + wsl
    for name, srcf, wtf, kind in (
            ("rslot_v", "src_v", "w_in", "v"),
            ("rslot_rho", None, "a_val", "rho")):
        kk = np.asarray(getattr(wf, name))[vw, vl]
        ww = np.asarray(getattr(wf, wtf))[vw, vl]
        if kind == "v":
            senders = np.asarray(wf.src_v)[vw, vl]
        else:
            epos = np.asarray(comm.in_a_epos)[agents[:, None],
                                              np.arange(kk.shape[-1])]
            senders = np.asarray(comm.src_a)[epos]
        use = ww > 0 if kind == "rho" else ww != 0
        read_key = senders.astype(np.int64) * H + kk
        for wave in np.unique(vw):
            m = vw == wave
            writes = set(writer_key[m].tolist())
            reads = read_key[m][use[m]]
            hit = np.asarray([r in writes for r in reads.tolist()])
            if np.any(hit):
                rf(f"in-flight {kind} write aliases a slot read inside "
                   "the same wave (ring slot written and read in one "
                   "launch)", wave=int(wave),
                   slots=reads[hit][:_MAX_SITES] % H)
    return diags


# ------------------------------------------------------------------ #
# RF103 over the grid gather tables
# ------------------------------------------------------------------ #
def lint_grid_tables(tables, *, agent, n, e_a, H,
                     subject="grid_tables") -> list[Diagnostic]:
    """Range/sentinel audit of :func:`grid_gather_tables` outputs
    (RF103): live lanes must index real flat rows, sentinel lanes must
    carry exactly the untranslated sentinels the kernel clamps."""
    idx_z, idx_g, idx_ri, idx_ro, idx_rb = [np.asarray(t)
                                            for t in tables]
    ag = np.asarray(agent)
    live = ag != n
    diags = []

    def rf(message, **data):
        diags.append(_d("RF103", subject, message, **data))

    if np.any(idx_z[live] != 4 * ag[live] + 2) or \
            np.any(idx_g[live] != 4 * ag[live] + 3):
        rf("idx_z/idx_g must address rows 4*agent+2 / 4*agent+3 of the "
           "flat node state")
    if np.any((idx_z[live] < 0) | (idx_z[live] >= 4 * n)):
        rf(f"live idx_z outside the flat node state [0, 4n={4 * n})")
    bad = (idx_ri < 0) | (idx_ri >= H * e_a)
    if np.any(bad[live]):
        rf(f"live idx_ri outside the flat rho history "
           f"[0, H*e_a={H * e_a})", sites=_sites(*np.nonzero(bad)))
    for name, t in (("idx_ro", idx_ro), ("idx_rb", idx_rb)):
        bad = (t < 0) | (t > 2 * e_a)
        if np.any(bad):
            rf(f"{name} outside [0, 2*e_a={2 * e_a}]",
               sites=_sites(*np.nonzero(bad)))
    pad = ~live
    if np.any(pad):
        if np.any(idx_ro[pad] != 2 * e_a) or \
                np.any(idx_rb[pad] != 2 * e_a):
            rf("sentinel lanes must carry the untranslated rho "
               f"sentinel {2 * e_a} in idx_ro/idx_rb")
    return diags


# ------------------------------------------------------------------ #
# RF104: flatten_plans lane-offset bijection
# ------------------------------------------------------------------ #
def unflatten_plans(flat: WavefrontPlan, S: int) -> WavefrontPlan:
    """Exact inverse of :func:`flatten_plans` for an ``S``-lane fleet:
    splits the lane axis back into blocks and subtracts each block's
    offsets.  Raises ``ValueError`` when any entry falls outside its
    lane's offset block (the bijection is broken)."""
    if S <= 0 or flat.width % S or flat.n % S or flat.e_a % S \
            or flat.K % S:
        raise ValueError(f"flat plan dims not divisible by S={S}")
    B, n = flat.width // S, flat.n // S
    e_a, K = flat.e_a // S, flat.K // S
    NW = flat.n_waves

    def blocks(a):
        """(NW, S*B, ...) -> (S, NW, B, ...)"""
        return np.moveaxis(
            np.asarray(a).reshape((NW, S, B) + a.shape[2:]), 1, 0)

    s_off = np.arange(S, dtype=np.int64)[:, None, None]
    out = {}
    ag = blocks(flat.agent)
    lo = s_off * n
    bad = ~(((ag >= lo) & (ag < lo + n)) | (ag == S * n))
    if np.any(bad):
        raise ValueError(f"agent entries outside their lane block at "
                         f"(lane, wave, slot) {_sites(*np.nonzero(bad))}")
    out["agent"] = np.where(ag == S * n, n, ag - lo).astype(np.int32)
    sv = blocks(flat.src_v)
    lo = s_off[..., None] * n
    if np.any((sv < lo) | (sv >= lo + n)):
        raise ValueError("src_v entries outside their lane block")
    out["src_v"] = (sv - lo).astype(np.int32)
    he = blocks(flat.hist_epos)
    lo = s_off[..., None] * e_a
    if np.any((he < lo) | (he >= lo + e_a)):
        raise ValueError("hist_epos entries outside their lane block")
    out["hist_epos"] = (he - lo).astype(np.int32)
    g = blocks(flat.rho_gidx)
    rho_lo = s_off[..., None] * e_a
    buf_lo = (S + s_off[..., None]) * e_a
    is_rho = (g >= rho_lo) & (g < rho_lo + e_a)
    is_buf = (g >= buf_lo) & (g < buf_lo + e_a)
    is_sen = g == 2 * S * e_a
    if not np.all(is_rho | is_buf | is_sen):
        raise ValueError("rho_gidx entries outside their lane's rho, "
                         "rho-tilde, or sentinel rows")
    out["rho_gidx"] = np.where(
        is_sen, 2 * e_a,
        np.where(is_rho, g - rho_lo, g - buf_lo + e_a)).astype(np.int32)
    ki = blocks(flat.kidx)
    lo = s_off * K
    bad = ~(((ki >= lo) & (ki < lo + K)) | (ki == S * K))
    if np.any(bad):
        raise ValueError("kidx entries outside their lane block")
    out["kidx"] = np.where(ki == S * K, K, ki - lo)
    for f in ("wslot", "w_self", "a_self", "rslot_v", "w_in",
              "rslot_rho", "a_val", "out_wt"):
        out[f] = blocks(getattr(flat, f))
    # per-lane event_start/sizes are NOT recoverable from the flat
    # aggregates; carry the aggregates so lint_flatten can check them.
    out["event_start"] = np.broadcast_to(flat.event_start, (S, NW))
    out["sizes"] = np.broadcast_to(flat.sizes, (S, NW))
    return dataclasses.replace(flat, width=B, n=n, e_a=e_a, K=K, **out)


def lint_flatten(stacked: WavefrontPlan, flat: WavefrontPlan, *,
                 subject="flatten") -> list[Diagnostic]:
    """RF104: the flat plan is the stacked plan under the documented
    lane-offset bijection — block containment, bit-for-bit inverse, and
    the min/sum ``event_start``/``sizes`` aggregates."""
    diags = []

    def rf(message, **data):
        diags.append(_d("RF104", subject, message, **data))

    if np.asarray(stacked.agent).ndim != 3:
        return [_d("RF104", subject,
                   "reference plan is not a stack_plans output")]
    S = stacked.n_lanes
    want = (S * stacked.width, S * stacked.n, S * stacked.e_a,
            S * stacked.K)
    have = (flat.width, flat.n, flat.e_a, flat.K)
    if want != have or flat.n_waves != stacked.n_waves:
        rf(f"flat scalars (width, n, e_a, K) = {have} do not match "
           f"S x stacked = {want}")
        return diags
    try:
        rec = unflatten_plans(flat, S)
    except ValueError as e:
        rf(f"lane-offset bijection broken: {e}")
        return diags
    for f in _WAVE_FIELDS:
        if f in ("event_start", "sizes"):
            continue
        a, b = np.asarray(getattr(stacked, f)), \
            np.asarray(getattr(rec, f))
        if not np.array_equal(a, b):
            bad = np.nonzero(a != b)
            rf(f"{f} does not round-trip bit-for-bit through the lane "
               "offsets", sites=_sites(*bad),
               want=a[bad][:_MAX_SITES], got=b[bad][:_MAX_SITES])
    want_es = (np.asarray(stacked.event_start)
               + np.arange(S)[:, None] * stacked.K).min(0)
    if not np.array_equal(np.asarray(flat.event_start), want_es):
        rf("event_start is not the per-wave minimum of the offset "
           "lane starts")
    want_sz = np.asarray(stacked.sizes).sum(0)
    if not np.array_equal(np.asarray(flat.sizes), want_sz):
        rf("sizes is not the per-wave sum of the lane sizes")
    return diags


# ------------------------------------------------------------------ #
# RF106: epoch-boundary migration coverage
# ------------------------------------------------------------------ #
def lint_epoch_trace(et, *, subject="epoch_trace") -> list[Diagnostic]:
    """RF106: the epochs tile the event range contiguously, membership
    deltas are exactly the active-mask differences, each root is an
    active common root, joiners always have a donor, and every
    prev-epoch edge joins then-active nodes (so ``migrate_state``'s
    settle pass covers all in-flight mass)."""
    diags = []

    def rf(i, message, **data):
        diags.append(_d("RF106", f"{subject}/epoch{i}", message, **data))

    eps = list(et.epochs)
    if not eps:
        return [_d("RF106", subject, "EpochTrace has no epochs")]
    if int(eps[0].k0) != 0:
        rf(0, f"first epoch must start at k0=0, got {eps[0].k0}")
    total = 0
    for i, ep in enumerate(eps):
        if int(ep.k0) != total:
            rf(i, f"epochs must tile events contiguously: k0={ep.k0} "
               f"but the previous epochs cover [0, {total})")
        total = int(ep.k0) + int(ep.K)
    if total != int(et.K):
        rf(len(eps) - 1, f"epochs cover [0, {total}) but the trace "
           f"claims K={et.K} events")

    prev_act = None
    for i, ep in enumerate(eps):
        act = np.asarray(ep.topology.active_mask(), bool)
        joined = np.asarray(ep.joined, bool)
        departed = np.asarray(ep.departed, bool)
        if i == 0:
            if joined.any() or departed.any():
                rf(i, "the first epoch has no previous membership to "
                   "delta against; joined/departed must be all-false")
        else:
            want_j = act & ~prev_act
            want_d = prev_act & ~act
            if not np.array_equal(joined, want_j):
                rf(i, "joined mask is not exactly (active now) & "
                   "(inactive before)",
                   joined=np.nonzero(joined)[0],
                   expected=np.nonzero(want_j)[0])
            if not np.array_equal(departed, want_d):
                rf(i, "departed mask is not exactly (inactive now) & "
                   "(active before)",
                   departed=np.nonzero(departed)[0],
                   expected=np.nonzero(want_d)[0])
            # migrate_state settles in-flight rho at *previous*-epoch
            # receivers: every prev edge must join then-active nodes
            from ..core.plan import as_comm_plan
            prev_plan = as_comm_plan(eps[i - 1].topology)
            ea = int(prev_plan.n_edges_a)
            src = np.asarray(prev_plan.src_a[:ea])
            dst = np.asarray(prev_plan.dst_a[:ea])
            bad = ~(prev_act[src] & prev_act[dst])
            if np.any(bad):
                rf(i, "previous epoch carries A-edges touching "
                   "inactive nodes; migrate_state's settle pass would "
                   "strand their in-flight mass",
                   edges=np.nonzero(bad)[0][:_MAX_SITES])
            if joined.any() and not np.any(act & ~joined):
                rf(i, "every active node just joined — no donor "
                   "carries state across the boundary")
            if float(ep.t0) < float(eps[i - 1].t0):
                rf(i, "epoch t0 offsets must be nondecreasing")
        root = int(ep.root)
        if not (0 <= root < act.shape[0]) or not act[root]:
            rf(i, f"epoch root {root} is not an active node")
        elif root not in ep.topology.roots():
            rf(i, f"epoch root {root} is not a common root of the "
               "epoch topology (Assumption 2)")
        if int(ep.K) <= 0:
            rf(i, "epoch has an empty schedule")
        prev_act = act
    return diags


# ------------------------------------------------------------------ #
# engine hook
# ------------------------------------------------------------------ #
def check_or_raise(diagnostics: list[Diagnostic], context: str = ""):
    """Raise :class:`PlanInvariantError` when any diagnostic fired."""
    if diagnostics:
        raise PlanInvariantError(diagnostics, context)
