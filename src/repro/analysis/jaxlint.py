"""Jaxpr auditing of the traced engines (RF201–RF206).

The plan linter rejects bad *inputs*; this pass rejects bad *programs*:
it walks the jaxprs that :func:`~repro.core.simulator.rfast_scan`,
:func:`~repro.core.simulator.rfast_wavefront_scan`,
:func:`~repro.core.simulator.rfast_sweep_scan` (the ``run_epochs``
body) and the :func:`~repro.kernels.rfast_update.grid.commit_grid`
call site actually trace to, plus the runtime contracts tracing cannot
see (donation aliasing, dispatch-cache steady state).

Everything here is trace-only: nothing is compiled or executed except
:func:`audit_dispatch`, which replays a caller-provided thunk against
the dispatch counters.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from .diagnostics import Diagnostic

__all__ = ["iter_eqns", "audit_jaxpr", "audit_donation",
           "audit_dispatch", "audit_serve_cache",
           "audit_mesh_collectives", "audit_engines"]

# host round-trip primitives (RF201) and loop primitives they must not
# appear inside
_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "python_callback",
    "callback", "host_callback_call", "outside_call"})
_LOOP_PRIMS = frozenset({"scan", "while"})
_WIDE_DTYPES = ("float64", "complex128")
# default RF203 threshold: a materialized rank>=3 intermediate of 16M
# elements (64 MiB at f32) is never the fused path
DEFAULT_BROADCAST_THRESHOLD = 1 << 24
# RF206: collectives whose OUTPUT can materialize beyond-shard data
# inside a fully-manual shard_map region (ppermute is excluded — it only
# moves shard-sized data, it cannot grow it)
_COLLECTIVE_PRIMS = frozenset({"all_gather", "all_to_all", "psum",
                               "pmax", "pmin"})


def _sub_jaxprs(params: dict):
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if isinstance(x, jax.core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jax.core.Jaxpr):
                yield x


def iter_eqns(jaxpr, *, in_loop=False):
    """Yield ``(eqn, inside_loop_body)`` over a jaxpr and every nested
    sub-jaxpr (pjit/scan/while/cond bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn, in_loop
        nested = in_loop or eqn.primitive.name in _LOOP_PRIMS
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, in_loop=nested)


def audit_jaxpr(closed, *, subject,
                broadcast_elems_threshold=DEFAULT_BROADCAST_THRESHOLD
                ) -> list[Diagnostic]:
    """RF201 (host callbacks in loop bodies), RF202 (f64/c128
    intermediates), RF203 (materialized rank>=3 broadcast/gather blowups
    above the element threshold) over one traced jaxpr."""
    jaxpr = closed.jaxpr if isinstance(closed, jax.core.ClosedJaxpr) \
        else closed
    diags = []
    wide_seen = collections.Counter()
    for eqn, in_loop in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in _CALLBACK_PRIMS and in_loop:
            diags.append(Diagnostic(
                "RF201", subject,
                f"host callback primitive {name!r} inside a scan/while "
                "body: one host round-trip per iteration",
                {"primitive": name}))
        for v in eqn.outvars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and str(dt) in _WIDE_DTYPES:
                wide_seen[(str(dt), name)] += 1
        if name in ("broadcast_in_dim", "gather"):
            out = eqn.outvars[0].aval
            if getattr(out, "ndim", 0) < 3:
                continue
            out_sz = int(np.prod(out.shape))
            in_sz = max((int(np.prod(v.aval.shape))
                         for v in eqn.invars
                         if getattr(v, "aval", None) is not None
                         and getattr(v.aval, "shape", None) is not None),
                        default=0)
            if out_sz >= broadcast_elems_threshold and out_sz > in_sz:
                diags.append(Diagnostic(
                    "RF203", subject,
                    f"{name} materializes a rank-{out.ndim} "
                    f"intermediate of {out_sz} elements "
                    f"(shape {tuple(out.shape)}) — the neighbour-stack "
                    "pattern the fused commit removed",
                    {"primitive": name, "shape": tuple(out.shape),
                     "elements": out_sz}))
    for (dt, name), count in sorted(wide_seen.items()):
        diags.append(Diagnostic(
            "RF202", subject,
            f"{count} {dt} intermediate(s) (first producer: {name}) "
            "under the f32 policy — a weak-typed constant or np.float64 "
            "leaked into the trace",
            {"dtype": dt, "primitive": name, "count": count}))
    return diags


def audit_donation(fn, args, donate_argnums, *, subject
                   ) -> list[Diagnostic]:
    """RF204: donation is only honored when each donated input leaf can
    alias a *distinct* output leaf of identical shape and dtype; any
    unmatched donated leaf silently degrades to a copy (and the caller
    has still lost the buffer)."""
    out = jax.eval_shape(fn, *args)
    avail = collections.Counter(
        (tuple(leaf.shape), np.dtype(leaf.dtype).name)
        for leaf in jax.tree_util.tree_leaves(out))
    diags = []
    for i in donate_argnums:
        for leaf in jax.tree_util.tree_leaves(args[i]):
            key = (tuple(leaf.shape), np.dtype(leaf.dtype).name)
            if avail[key] > 0:
                avail[key] -= 1
            else:
                diags.append(Diagnostic(
                    "RF204", subject,
                    f"donated leaf of arg {i} (shape {key[0]}, dtype "
                    f"{key[1]}) has no matching output buffer to alias "
                    "— donation is declared but cannot be honored",
                    {"arg": i, "shape": key[0], "dtype": key[1]}))
    return diags


def audit_dispatch(run_once, *, subject, expect_entries=1, repeats=2,
                   cache=None) -> list[Diagnostic]:
    """RF205: ``run_once()`` must settle the compiled-plan cache at
    ``expect_entries`` entries, and replays must be pure cache hits.

    ``cache`` is any module/object with the ``stats()``/``clear()``
    contract — the commit-grid dispatch cache by default, or
    ``repro.serve.cache`` (the serving executables) via
    :func:`audit_serve_cache`."""
    if cache is None:
        from ..kernels.rfast_update import dispatch as cache
    cache.clear()
    diags = []
    try:
        run_once()
        first = dict(cache.stats())
        if first["entries"] > expect_entries:
            diags.append(Diagnostic(
                "RF205", subject,
                f"first run created {first['entries']} cache entries "
                f"(expected <= {expect_entries}): the cache key varies "
                "within one fleet shape", dict(first)))
        for _ in range(max(0, repeats - 1)):
            run_once()
        after = dict(cache.stats())
        if after["misses"] > first["misses"]:
            diags.append(Diagnostic(
                "RF205", subject,
                f"replaying with unchanged shapes missed the cache "
                f"{after['misses'] - first['misses']} more time(s) — "
                "recompilation in steady state", dict(after)))
    finally:
        cache.clear()
    return diags


def audit_serve_cache(*, seed=0, buckets=(4, 8)) -> tuple[list[Diagnostic],
                                                          list[str]]:
    """RF205 over the SERVING executable cache (``repro.serve.cache``).

    Runs a tiny engine over a fixed mixed-length workload — prompts
    spanning every configured bucket — and requires the cache to settle
    at exactly ``1 + len(buckets)`` entries (one fused decode executable
    plus one prefill executable per prompt-length bucket) with replays
    hitting only.  Passing ``buckets=None`` disables bucketing, so every
    distinct prompt length builds its own executable and the audit
    fires — the mutation ``tests/test_analysis.py`` pins.
    """
    from ..models.config import ModelConfig
    from ..models.transformer import init_params
    from ..serve import Request, ServeEngine, WeightStore
    from ..serve import cache as serve_cache

    cfg = ModelConfig(name="serve-audit", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    lengths = [1, 2, 3, 5, 7, 8]          # spans both default buckets
    max_b = max(buckets) if buckets else max(lengths)
    lengths = [min(l, max_b) for l in lengths]

    def run_once():
        eng = ServeEngine(cfg, WeightStore(params), batch=2, max_len=16,
                          buckets=buckets)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, size=l,
                                            ).astype(np.int32),
                        gen=2, arrive_s=0.0)
                for i, l in enumerate(lengths)]
        eng.run(reqs)

    expect = 1 + (len(buckets) if buckets else 0)
    if buckets is None:
        expect = 1 + 1          # the tightest defensible floor: decode
        #                         + ONE prefill; every extra length fires
    diags = audit_dispatch(run_once, subject="serve_engine[cache]",
                           expect_entries=expect, cache=serve_cache)
    return diags, ["serve_engine[cache]"]


def audit_mesh_collectives(closed, *, subject, state_bytes_threshold
                           ) -> list[Diagnostic]:
    """RF206: no collective inside the mesh-mapped wave body materializes
    (or reduces over) state-sized data.

    Inside a fully-manual shard_map region the ONLY way a device can
    obtain data beyond its own shard is a collective, so auditing the
    collectives' output sizes is a complete check for the "accidentally
    replicated" failure mode: an ``all_gather`` of the packed
    ``(S_loc·n, 4, p)`` state (or a state-sized ``psum``) means the
    parameter sharding silently degenerated to replication.

    ``state_bytes_threshold`` is one lane group's node state at FULL
    parameter width (``S_loc · n · 4 · p_pad · itemsize``).  The
    legitimate per-wave gradient gather reconstructs only the mixed
    iterates — at most ``S_loc·n`` rows of ONE of the four node slots,
    i.e. <= threshold/4 — so a collective at or above the threshold is
    never the designed data flow.
    """
    jaxpr = closed.jaxpr if isinstance(closed, jax.core.ClosedJaxpr) \
        else closed
    diags = []
    for eqn, _ in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in _COLLECTIVE_PRIMS:
            continue
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape is None:
                continue
            nbytes = int(np.prod(shape, dtype=np.int64)
                         * np.dtype(aval.dtype).itemsize)
            if nbytes >= state_bytes_threshold:
                diags.append(Diagnostic(
                    "RF206", subject,
                    f"collective {name!r} materializes {nbytes} bytes "
                    f"(shape {tuple(shape)}) inside the mesh-mapped wave "
                    f"body — >= the {state_bytes_threshold}-byte "
                    "full-width state threshold: the shard layout has "
                    "degenerated to replication",
                    {"primitive": name, "shape": tuple(shape),
                     "bytes": nbytes,
                     "threshold": state_bytes_threshold}))
    return diags


# ------------------------------------------------------------------ #
# the standard engine audit the CLI runs
# ------------------------------------------------------------------ #
def audit_engines(*, n=5, p=8, K=48, seed=0,
                  broadcast_elems_threshold=DEFAULT_BROADCAST_THRESHOLD
                  ) -> tuple[list[Diagnostic], list[str]]:
    """Trace every engine at a small size and run all RF2xx checks.

    Returns ``(diagnostics, audited_subjects)``.  Sizes are tiny on
    purpose: the properties audited (callbacks, dtypes, donation
    structure, materialization *pattern*, cache-key stability) are
    shape-generic, so a small trace certifies the program family.
    """
    from ..core.plan import build_comm_plan, pad_comm_plan
    from ..core.scenario import get_scenario
    from ..core.schedule import (build_wavefront_plan, flatten_plans,
                                 stack_plans)
    from ..core.simulator import (PackedState, init_state, pack_state,
                                  rfast_scan, rfast_sweep_scan,
                                  rfast_wavefront_scan, wave_inputs)
    from ..core.topology import get_topology
    from ..kernels.rfast_update.grid import commit_grid

    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)
    gfn = lambda i, x, key: x - C[i]
    gamma = 1e-2

    topo = get_topology("binary_tree", n)
    sched = get_scenario("uniform", n).realize(topo, K, seed=seed).schedule
    plan = build_comm_plan(topo)
    H = int(sched.D) + 2
    st = init_state(plan, jnp.zeros((n, p), jnp.float32), gfn,
                    jax.random.PRNGKey(seed), H)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), K)
    diags, audited = [], []
    kw = dict(broadcast_elems_threshold=broadcast_elems_threshold)

    # event-serial engine
    eng = rfast_scan(plan, gfn, gamma, H, donate=False)
    cj = jax.make_jaxpr(eng)(st, jnp.asarray(sched.agent),
                             jnp.asarray(sched.stamp_v),
                             jnp.asarray(sched.stamp_rho), keys)
    diags += audit_jaxpr(cj, subject="rfast_scan", **kw)
    audited.append("rfast_scan")
    diags += audit_donation(rfast_scan(plan, gfn, gamma, H, donate=True),
                            (st, jnp.asarray(sched.agent),
                             jnp.asarray(sched.stamp_v),
                             jnp.asarray(sched.stamp_rho), keys), (0,),
                            subject="rfast_scan[donate]")
    audited.append("rfast_scan[donate]")

    # wavefront engine, both impls (pallas resolves to the emulate
    # dispatch path off-TPU; the audited scan structure is the same)
    wf = build_wavefront_plan(sched, plan, H)
    packed = pack_state(st)
    waves = wave_inputs(wf, keys)
    for impl in ("jnp", "pallas"):
        runner = rfast_wavefront_scan(plan, gfn, gamma, donate=False,
                                      impl=impl)
        cj = jax.make_jaxpr(runner)(packed, waves)
        diags += audit_jaxpr(cj, subject=f"rfast_wavefront_scan[{impl}]",
                             **kw)
        audited.append(f"rfast_wavefront_scan[{impl}]")
    diags += audit_donation(
        rfast_wavefront_scan(plan, gfn, gamma, donate=True),
        (packed, waves), (0,), subject="rfast_wavefront_scan[donate]")
    audited.append("rfast_wavefront_scan[donate]")

    # fleet (run_sweep / run_epochs) engine over a flattened 2-lane plan
    topo_b = get_topology("line", n)
    plan_b = build_comm_plan(topo_b)
    kw_max = max(plan.kw, plan_b.kw)
    ka_max = max(plan.ka, plan_b.ka)
    ko_max = max(plan.ko, plan_b.ko)
    pads = [pad_comm_plan(c, kw=kw_max, ka=ka_max, ko=ko_max)
            for c in (plan, plan_b)]
    sched_b = get_scenario("straggler", n).realize(topo_b, K,
                                                   seed=seed).schedule
    H_f = max(H, int(sched_b.D) + 2)
    e_a = max(max(1, c.n_edges_a) for c in pads)
    wfs = [build_wavefront_plan(s, c, H_f, e_a=e_a)
           for s, c in zip((sched, sched_b), pads)]
    fleet = flatten_plans(stack_plans(wfs))
    S = 2
    fpacked = PackedState(
        nodes=jnp.zeros((S * n, 4, p), jnp.float32),
        rho2=jnp.zeros((2 * S * e_a, p), jnp.float32),
        v_hist=jnp.zeros((H_f, S * n, p), jnp.float32),
        rho_hist=jnp.zeros((H_f, S * e_a, p), jnp.float32))
    fwaves = wave_inputs(fleet, jnp.zeros((S * K, 2), jnp.uint32))
    for impl in ("jnp", "pallas"):
        sweep = rfast_sweep_scan(gfn, gamma, ko=ko_max, n_per_lane=n,
                                 donate=False, impl=impl)
        cj = jax.make_jaxpr(sweep)(fpacked, fwaves)
        diags += audit_jaxpr(cj, subject=f"rfast_sweep_scan[{impl}]",
                             **kw)
        audited.append(f"rfast_sweep_scan[{impl}]")
    diags += audit_donation(
        rfast_sweep_scan(gfn, gamma, ko=ko_max, n_per_lane=n,
                         donate=True), (fpacked, fwaves), (0,),
        subject="rfast_sweep_scan[donate]")
    audited.append("rfast_sweep_scan[donate]")

    # mesh-mapped sweep engine (RF206 + the standard RF2xx checks) on a
    # single-device (1,1) mesh — shard_map bodies are reachable through
    # iter_eqns, and the collective/size audit is shape-generic
    from ..core.simulator import _mesh_sweep_scan
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    mpacked = jax.tree.map(lambda a: a[None], fpacked)
    mwaves = jax.tree.map(lambda a: a[None], fwaves)
    state_bytes = S * n * 4 * p * np.dtype(np.float32).itemsize
    for impl in ("jnp", "pallas"):
        mrunner = _mesh_sweep_scan(gfn, gamma, ko=ko_max, n_per_lane=n,
                                   mesh=mesh, donate=False, impl=impl)
        cj = jax.make_jaxpr(mrunner)(mpacked, mwaves)
        diags += audit_jaxpr(cj, subject=f"mesh_sweep_scan[{impl}]", **kw)
        diags += audit_mesh_collectives(
            cj, subject=f"mesh_sweep_scan[{impl}]",
            state_bytes_threshold=state_bytes)
        audited.append(f"mesh_sweep_scan[{impl}]")
    diags += audit_donation(
        _mesh_sweep_scan(gfn, gamma, ko=ko_max, n_per_lane=n, mesh=mesh,
                         donate=True), (mpacked, mwaves), (0,),
        subject="mesh_sweep_scan[donate]")
    audited.append("mesh_sweep_scan[donate]")

    # run_epochs body: the same sweep engine over an epoch topology
    # with an active mask (isolated nodes exercise the sentinel paths)
    sc = get_scenario("churn", max(n, 7))
    topo_e = get_topology("robust_tree", max(n, 7))
    try:
        et = sc.realize_epochs(topo_e, 40 * max(n, 7), seed=seed)
    except ValueError:
        et = None
    if et is not None and len(et.epochs) > 1:
        ep = et.epochs[1]
        plan_e = build_comm_plan(ep.topology)
        sched_e = ep.trace.schedule
        H_e = int(sched_e.D) + 2
        wf_e = build_wavefront_plan(sched_e, plan_e, H_e)
        n_e = plan_e.n
        st_e = init_state(plan_e, jnp.zeros((n_e, p), jnp.float32),
                          lambda i, x, key: x,
                          jax.random.PRNGKey(seed), H_e)
        runner_e = rfast_wavefront_scan(plan_e, lambda i, x, key: x,
                                        gamma, donate=False)
        cj = jax.make_jaxpr(runner_e)(
            pack_state(st_e),
            wave_inputs(wf_e, jax.random.split(jax.random.PRNGKey(0),
                                               wf_e.K)))
        diags += audit_jaxpr(cj, subject="run_epochs[wave body]", **kw)
        audited.append("run_epochs[wave body]")

    # commit_grid call site: traced program + dispatch steady state
    B, ka_g, ko_g, rows, Pf = 4, 2, 2, 8, 16
    r2 = np.random.default_rng(seed + 2)
    f = lambda s: jnp.asarray(r2.normal(0, 1, s), jnp.float32)
    i = lambda s, hi: jnp.asarray(r2.integers(0, hi, s), jnp.int32)
    grid_args = (i((B,), rows), i((B,), rows), i((B, ka_g), rows),
                 i((B, ka_g), rows), i((B, ko_g), rows),
                 f((B,)), jnp.ones((B, ka_g), jnp.float32),
                 f((B, ko_g)), f((rows, Pf)), f((B, Pf)), f((rows, Pf)),
                 f((rows, Pf)), f((rows, Pf)), f((rows, Pf)))
    cj = jax.make_jaxpr(
        lambda *a: commit_grid(*a, mode="emulate"))(*grid_args)
    diags += audit_jaxpr(cj, subject="commit_grid[emulate]", **kw)
    audited.append("commit_grid[emulate]")
    diags += audit_dispatch(
        lambda: jax.block_until_ready(
            commit_grid(*grid_args, mode="emulate")),
        subject="commit_grid[dispatch]", expect_entries=1)
    audited.append("commit_grid[dispatch]")
    return diags, audited
