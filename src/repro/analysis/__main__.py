"""CLI: ``python -m repro.analysis [--all|--plans|--jaxprs] [--json F]``.

Exit status 0 means every pass ran clean; 1 means at least one
diagnostic fired.  The JSON report goes to stdout (or ``--json FILE``);
the human summary goes to stderr so pipelines can consume stdout raw.
"""
from __future__ import annotations

import argparse
import json
import sys

from .diagnostics import CODES
from .runner import catalog, run_all


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Plan-invariant linter (RF1xx) and jaxpr auditor "
                    "(RF2xx) for the R-FAST engines.")
    scope = ap.add_mutually_exclusive_group()
    scope.add_argument("--all", action="store_true",
                       help="run both passes over the full registry "
                            "matrix (default)")
    scope.add_argument("--plans", action="store_true",
                       help="planlint only (RF101-RF106)")
    scope.add_argument("--jaxprs", action="store_true",
                       help="jaxlint only (RF201-RF205)")
    scope.add_argument("--codes", action="store_true",
                       help="print the diagnostic-code catalog and exit")
    ap.add_argument("--quick", action="store_true",
                    help="reduced matrix (3 scenarios x 3 topologies)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the JSON report here instead of stdout")
    ap.add_argument("--n", type=int, default=7,
                    help="nodes per topology (default 7)")
    ap.add_argument("--events", type=int, default=96,
                    help="schedule length K per realization (default 96)")
    ap.add_argument("--epoch-events", type=int, default=1200,
                    help="K for dynamic-membership epoch traces "
                         "(default 1200)")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated realization seeds (default 0)")
    ap.add_argument("--verbose", action="store_true",
                    help="progress lines on stderr")
    args = ap.parse_args(argv)

    if args.codes:
        print(json.dumps(catalog(), indent=2))
        return 0

    say = (lambda m: print(f"[analysis] {m}", file=sys.stderr)) \
        if args.verbose else None
    seeds = tuple(int(s) for s in args.seeds.split(",") if s != "")
    run_plans = not args.jaxprs
    run_jaxprs = not args.plans
    report = run_all(n=args.n, K=args.events,
                     K_epochs=args.epoch_events, seeds=seeds,
                     quick=args.quick, plans=run_plans,
                     jaxprs=run_jaxprs, progress=say)

    doc = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(doc + "\n")
    else:
        print(doc)

    n_diag = report["summary"]["diagnostics"]
    checked = report["summary"]["checked"]
    passes = "+".join(report["config"]["passes"])
    print(f"[analysis] {passes}: {n_diag} diagnostic(s); "
          f"checked {checked.get('comm_plans', 0)} comm plans, "
          f"{checked.get('wavefront_plans', 0)} wavefront plans, "
          f"{checked.get('transform_plans', 0)} transformed plans, "
          f"{checked.get('fleets', 0)} fleets, "
          f"{checked.get('epoch_traces', 0)} epoch traces; "
          f"audited {len(report['summary']['audited_jaxprs'])} jaxprs; "
          f"skipped {len(checked.get('skipped', []))} "
          "incompatible combos", file=sys.stderr)
    for d in report["diagnostics"]:
        info = CODES.get(d["code"])
        title = f" ({info.title})" if info else ""
        print(f"[analysis] {d['code']}{title} [{d['subject']}] "
              f"{d['message']}", file=sys.stderr)
    return 1 if n_diag else 0


if __name__ == "__main__":
    raise SystemExit(main())
