"""Static analysis for the R-FAST engines: plan-invariant linting and
jaxpr auditing.

Two passes over two artifact families:

* :mod:`.planlint` — host-side race/alias/sentinel checks (RF101–RF106)
  over ``CommPlan`` / ``WavefrontPlan`` / ``EpochTrace`` objects and
  every transform composition (``pad_plan`` / ``slice_plan`` /
  ``stack_plans`` / ``flatten_plans``).
* :mod:`.jaxlint` — jaxpr-level checks (RF201–RF205) over the traced
  engine bodies and the ``commit_grid`` dispatch site.

Run everything with ``python -m repro.analysis --all`` or
``benchmarks/run.py --lint``; both emit the JSON report documented in
DESIGN.md §12.
"""
from .diagnostics import CODES, Diagnostic, PlanInvariantError

__all__ = ["CODES", "Diagnostic", "PlanInvariantError"]
