"""Registry-wide analysis driver: SCENARIOS x topology builders x the
plan-transform matrix, plus the engine jaxpr audits.

This is the machine behind ``python -m repro.analysis --all`` and
``benchmarks/run.py --lint``.  It mirrors the exact plan plumbing the
engines use (shared fleet maxima, ``pad_comm_plan`` -> per-lane
``build_wavefront_plan(e_a=...)`` -> ``pad_plan``/``slice_plan`` ->
``stack_plans`` -> ``flatten_plans``) so a diagnostic here means the
real engines would consume the same broken tables.
"""
from __future__ import annotations

from .diagnostics import CODES, Diagnostic
from . import jaxlint, planlint

_QUICK_SCENARIOS = ("uniform", "packet_loss", "churn")
_QUICK_TOPOLOGIES = ("binary_tree", "line", "robust_tree")


def run_plan_matrix(*, n=7, K=96, K_epochs=1200, seeds=(0,),
                    scenarios=None, topologies=None,
                    progress=None) -> tuple[list[Diagnostic], dict]:
    """All RF1xx passes over every (scenario, topology, seed) triple and
    every transform composition; returns ``(diagnostics, stats)``."""
    from ..core.plan import build_comm_plan, pad_comm_plan
    from ..core.scenario import SCENARIOS, get_scenario
    from ..core.schedule import (build_wavefront_plan, concat_plans,
                                 flatten_plans, grid_gather_tables,
                                 pad_plan, slice_plan, stack_plans)
    from ..core.topology import TOPOLOGIES, get_topology

    scenarios = list(scenarios or SCENARIOS)
    topologies = list(topologies or TOPOLOGIES)
    say = progress or (lambda msg: None)
    diags: list[Diagnostic] = []
    stats = {"scenarios": len(scenarios), "topologies": len(topologies),
             "seeds": len(seeds), "comm_plans": 0, "wavefront_plans": 0,
             "transform_plans": 0, "fleets": 0, "epoch_traces": 0,
             "skipped": []}

    topos = {t: get_topology(t, n) for t in topologies}
    comms = {t: build_comm_plan(topo) for t, topo in topos.items()}
    kw = max(c.kw for c in comms.values())
    ka = max(c.ka for c in comms.values())
    ko = max(c.ko for c in comms.values())
    padded = {t: pad_comm_plan(c, kw=kw, ka=ka, ko=ko)
              for t, c in comms.items()}
    e_a = max(max(1, c.n_edges_a) for c in padded.values())
    for t in topologies:
        diags += planlint.lint_comm_plan(comms[t], topos[t],
                                         subject=f"comm_plan/{t}")
        diags += planlint.lint_comm_plan(padded[t], topos[t],
                                         subject=f"comm_plan/{t}/padded")
        stats["comm_plans"] += 2

    for sc_name in scenarios:
        sc = get_scenario(sc_name, n)
        for seed in seeds:
            say(f"planlint: {sc_name} seed {seed}")
            scheds, wfs = [], []
            H = 0
            for t in topologies:
                sched = sc.realize(topos[t], K, seed=seed).schedule
                H = max(H, int(sched.D) + 2)
                scheds.append(sched)
            for t, sched in zip(topologies, scheds):
                sub = f"{sc_name}/{t}/seed{seed}"
                wf = build_wavefront_plan(sched, padded[t], H, e_a=e_a)
                wfs.append(wf)
                diags += planlint.lint_wavefront_plan(
                    wf, comm=padded[t], schedule=sched, H=H, subject=sub)
                stats["wavefront_plans"] += 1
                # transform compositions stay clean and schedule-true
                pp = pad_plan(wf, width=wf.width + 2,
                              n_waves=wf.n_waves + 3, e_a=e_a + 4)
                diags += planlint.lint_wavefront_plan(
                    pp, comm=padded[t], schedule=sched, H=H,
                    subject=f"{sub}/padded")
                mid = max(1, pp.n_waves // 2)
                rejoined = concat_plans([slice_plan(pp, 0, mid),
                                         slice_plan(pp, mid, pp.n_waves)])
                diags += planlint.lint_wavefront_plan(
                    rejoined, comm=padded[t], schedule=sched, H=H,
                    subject=f"{sub}/sliced+concat")
                stats["transform_plans"] += 2

            stacked = stack_plans(wfs)
            fleet = flatten_plans(stacked)
            sub = f"{sc_name}/fleet/seed{seed}"
            diags += planlint.lint_wavefront_plan(
                stacked, comm=[padded[t] for t in topologies],
                schedule=scheds, H=H, subject=f"{sub}/stacked")
            diags += planlint.lint_flatten(stacked, fleet, subject=sub)
            diags += planlint.lint_wavefront_plan(fleet, H=H,
                                                  subject=f"{sub}/flat")
            tables = grid_gather_tables(
                fleet.agent, fleet.rslot_rho, fleet.hist_epos,
                fleet.rho_gidx, e_a_flat=fleet.e_a,
                ko=fleet.out_wt.shape[-1])
            diags += planlint.lint_grid_tables(
                tables, agent=fleet.agent, n=fleet.n, e_a=fleet.e_a,
                H=H, subject=f"{sub}/grid_tables")
            stats["fleets"] += 1

        if not getattr(sc, "dynamic", False):
            continue
        for t in topologies:
            for seed in seeds:
                sub = f"{sc_name}/{t}/seed{seed}/epochs"
                say(f"planlint: {sub}")
                try:
                    et = sc.realize_epochs(topos[t], K_epochs, seed=seed)
                except ValueError as e:
                    stats["skipped"].append(
                        {"subject": sub, "reason": str(e)})
                    continue
                diags += planlint.lint_epoch_trace(et, subject=sub)
                stats["epoch_traces"] += 1
                for i, ep in enumerate(et.epochs):
                    eplan = build_comm_plan(ep.topology)
                    esched = ep.trace.schedule
                    eH = int(esched.D) + 2
                    ewf = build_wavefront_plan(esched, eplan, eH)
                    diags += planlint.lint_comm_plan(
                        eplan, ep.topology, subject=f"{sub}/ep{i}/comm")
                    diags += planlint.lint_wavefront_plan(
                        ewf, comm=eplan, schedule=esched, H=eH,
                        subject=f"{sub}/ep{i}")
                    stats["comm_plans"] += 1
                    stats["wavefront_plans"] += 1
    return diags, stats


def run_all(*, n=7, K=96, K_epochs=1200, seeds=(0,), quick=False,
            plans=True, jaxprs=True, progress=None) -> dict:
    """The full ``--all`` sweep; returns the JSON-ready report dict
    (schema in DESIGN.md §12)."""
    say = progress or (lambda msg: None)
    scenarios = topologies = None
    if quick:
        scenarios, topologies = _QUICK_SCENARIOS, _QUICK_TOPOLOGIES
        K, K_epochs, seeds = min(K, 64), min(K_epochs, 600), seeds[:1]
    diags: list[Diagnostic] = []
    stats: dict = {}
    audited: list[str] = []
    if plans:
        d, stats = run_plan_matrix(
            n=n, K=K, K_epochs=K_epochs, seeds=tuple(seeds),
            scenarios=scenarios, topologies=topologies, progress=say)
        diags += d
    if jaxprs:
        say("jaxlint: tracing engines")
        d, audited = jaxlint.audit_engines(seed=min(seeds, default=0))
        diags += d
        say("jaxlint: serving executable cache")
        d, a = jaxlint.audit_serve_cache(seed=min(seeds, default=0))
        diags += d
        audited += a
    return {
        "version": 1,
        "tool": "repro.analysis",
        "config": {"n": n, "K": K, "K_epochs": K_epochs,
                   "seeds": list(seeds), "quick": bool(quick),
                   "passes": (["planlint"] if plans else [])
                   + (["jaxlint"] if jaxprs else [])},
        "summary": {
            "diagnostics": len(diags),
            "by_code": _count_by_code(diags),
            "checked": stats,
            "audited_jaxprs": audited,
        },
        "diagnostics": [d.to_json() for d in diags],
    }


def _count_by_code(diags):
    out = {}
    for d in diags:
        out[d.code] = out.get(d.code, 0) + 1
    return out


def catalog() -> list[dict]:
    """The RF code catalog, JSON-ready (mirrors DESIGN.md §12)."""
    return [{"code": c.code, "owner": c.owner, "title": c.title,
             "invariant": c.invariant, "motivation": c.motivation}
            for c in CODES.values()]
