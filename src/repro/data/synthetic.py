"""Synthetic data generators (offline container — no real MNIST/ImageNet).

* ``logistic_dataset`` — a two-class "handwritten digit"-like dataset for
  the paper's §VI-A experiment (regularized logistic regression, smooth and
  strongly convex).  Samples are drawn from two anisotropic Gaussian
  prototypes in 784-D, mimicking the MNIST 0-vs-1 task.
* ``partition`` — splits a dataset over ``n`` nodes either IID or fully
  heterogeneous (label-sorted), controlling the ς of Definition 2.
* ``token_stream`` — deterministic synthetic token batches for LM training.
"""
from __future__ import annotations

import numpy as np

__all__ = ["logistic_dataset", "partition", "token_stream"]


def logistic_dataset(
    m: int = 12_000, d: int = 784, *, seed: int = 0, margin: float = 2.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Two-class Gaussian-prototype dataset: returns (X, y), y ∈ {0, 1}."""
    rng = np.random.default_rng(seed)
    proto0 = rng.normal(0.0, 1.0, d)
    proto1 = rng.normal(0.0, 1.0, d)
    proto0 *= margin / np.linalg.norm(proto0) * np.sqrt(d)
    proto1 *= margin / np.linalg.norm(proto1) * np.sqrt(d)
    y = (rng.uniform(size=m) < 0.5).astype(np.int32)
    scales = rng.uniform(0.5, 1.5, d)
    X = np.where(y[:, None] == 1, proto1[None], proto0[None])
    X = X + rng.normal(0.0, 1.0, (m, d)) * scales[None, :] * margin
    X = X / np.sqrt(d)
    return X.astype(np.float32), y


def partition(
    X: np.ndarray, y: np.ndarray, n: int, *, heterogeneous: bool = False,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Split (X, y) into n equal shards: returns (n, m_i, d), (n, m_i).

    ``heterogeneous=True`` sorts by label first, giving each node a highly
    non-IID shard (large ς in Definition 2) — the regime where gradient
    tracking separates from D-PSGD/AD-PSGD.
    """
    rng = np.random.default_rng(seed)
    m = X.shape[0]
    order = np.argsort(y, kind="stable") if heterogeneous else rng.permutation(m)
    m_i = m // n
    order = order[: m_i * n]
    Xs = X[order].reshape(n, m_i, -1)
    ys = y[order].reshape(n, m_i)
    return Xs, ys


def token_stream(
    vocab: int, batch: int, seq: int, *, n_batches: int, seed: int = 0,
):
    """Deterministic synthetic LM batches: (tokens, labels) pairs."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
        yield toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
