from .synthetic import logistic_dataset, partition, token_stream  # noqa: F401
from .objectives import LogisticProblem, make_logistic_problem  # noqa: F401
