from .synthetic import logistic_dataset, partition, token_stream  # noqa: F401
from .objectives import (  # noqa: F401
    LogisticProblem, make_logistic_problem, LMProblem, make_lm_problem,
)
