"""Per-node sharded data pipeline for LM training.

Each R-FAST node owns a disjoint shard of the (synthetic) corpus — problem
(1)'s local distributions D_i.  The iterator yields host numpy batches;
``device_put_sharded``-style placement is handled by the launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["LMShardConfig", "lm_batch_iterator", "node_batch"]


@dataclasses.dataclass(frozen=True)
class LMShardConfig:
    vocab: int
    batch_per_node: int
    seq_len: int
    n_nodes: int
    seed: int = 0


def node_batch(cfg: LMShardConfig, node: int, step: int):
    """Deterministic batch for (node, step): tokens, labels (next-token)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, node, step]))
    toks = rng.integers(0, cfg.vocab, (cfg.batch_per_node, cfg.seq_len + 1),
                        dtype=np.int64)
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)


def lm_batch_iterator(cfg: LMShardConfig, node: int,
                      start_step: int = 0) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield node_batch(cfg, node, step)
        step += 1
