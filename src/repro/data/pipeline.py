"""Per-node sharded data pipeline for LM training.

Each R-FAST node owns a disjoint shard of the (synthetic) corpus — problem
(1)'s local distributions D_i.  The iterator yields host numpy batches;
``device_put_sharded``-style placement is handled by the launcher.

Tokens are drawn from a Zipfian marginal (``zipf`` exponent; 0 = the old
uniform stream): a learnable unigram structure, so training losses have
real headroom below the ``log(vocab)`` uniform floor and "loss goes
down" is a meaningful end-to-end assertion.  The async engines sample
the same marginal device-side (``objectives.LMProblem``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["LMShardConfig", "lm_batch_iterator", "node_batch", "zipf_probs"]


@dataclasses.dataclass(frozen=True)
class LMShardConfig:
    vocab: int
    batch_per_node: int
    seq_len: int
    n_nodes: int
    seed: int = 0
    zipf: float = 1.2     # token marginal ∝ (rank+1)^-zipf; 0 = uniform


def zipf_probs(vocab: int, s: float) -> np.ndarray:
    """Zipfian unigram marginal p(t) ∝ (t+1)^-s over token ids."""
    w = np.arange(1, vocab + 1, dtype=np.float64) ** (-s)
    return w / w.sum()


def node_batch(cfg: LMShardConfig, node: int, step: int):
    """Deterministic batch for (node, step): tokens, labels (next-token)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, node, step]))
    shape = (cfg.batch_per_node, cfg.seq_len + 1)
    if cfg.zipf > 0:
        toks = rng.choice(cfg.vocab, size=shape,
                          p=zipf_probs(cfg.vocab, cfg.zipf))
    else:
        toks = rng.integers(0, cfg.vocab, shape, dtype=np.int64)
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)


def lm_batch_iterator(cfg: LMShardConfig, node: int,
                      start_step: int = 0) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield node_batch(cfg, node, step)
        step += 1
