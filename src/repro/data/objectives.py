"""Objectives with the simulator's ``grad_fn(node, x, key)`` interface.

Every objective here is a :class:`~repro.core.paramvec.GradProvider`:
``n`` nodes, flat dimension ``p``, and ``grad_fn()`` returning the
traced ``(i, x_flat, key) -> g_flat`` the engines consume.

* :class:`LogisticProblem` — the paper's §VI-A regularized logistic
  regression (smooth and strongly convex thanks to the L2 term).
* :class:`LMProblem` — a real (reduced) transformer LM on the flat
  substrate: parameters travel through the engines as one padded
  ``(p,)`` lane (``paramvec.ravel``/``unravel`` rebuild the pytree
  inside the traced gradient), batches are sampled device-side from
  the shard's Zipfian token marginal, so the same asynchronous engines
  that run the hand-written objectives train the model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.paramvec import (ModelGradProvider, RavelSpec, make_ravel_spec,
                             ravel, unravel)
from .pipeline import LMShardConfig, zipf_probs

__all__ = ["LogisticProblem", "make_logistic_problem",
           "LMProblem", "make_lm_problem"]


@dataclasses.dataclass(frozen=True)
class LogisticProblem:
    """Regularized logistic regression over n node-local shards.

    Parameter layout: x = [w (d,), b ()] -> p = d + 1.
    Local objective:  f_i(x) = Σ_{s∈shard_i} log(1+exp(-ŷ s)) + (λ/2)|x|²
    (sum, not mean — matches problem (1)'s Σ_i f_i structure; the λ term is
    split evenly so F keeps a single global λ).
    """

    X: jnp.ndarray          # (n, m_i, d)
    y: jnp.ndarray          # (n, m_i) in {0,1}
    lam: float
    batch: int              # minibatch size per gradient sample (0 = full)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[2] + 1

    # -- losses --------------------------------------------------------- #
    def _margins(self, Xb, yb, x):
        w, b = x[:-1], x[-1]
        logits = Xb @ w + b
        s = 2.0 * yb.astype(jnp.float32) - 1.0
        return logits * s

    def local_loss(self, i: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        m = self._margins(self.X[i], self.y[i], x)
        return jnp.sum(jax.nn.softplus(-m)) + 0.5 * self.lam * jnp.sum(x * x)

    def global_loss(self, x: jnp.ndarray) -> jnp.ndarray:
        """F(x) = Σ_i f_i(x), evaluated on the full data."""
        losses = jax.vmap(lambda i: self.local_loss(i, x))(jnp.arange(self.n))
        return jnp.sum(losses)

    def mean_loss(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.global_loss(x) / (self.X.shape[0] * self.X.shape[1])

    def accuracy(self, x: jnp.ndarray) -> jnp.ndarray:
        w, b = x[:-1], x[-1]
        logits = self.X.reshape(-1, self.X.shape[-1]) @ w + b
        pred = (logits > 0).astype(jnp.int32)
        return jnp.mean((pred == self.y.reshape(-1)).astype(jnp.float32))

    # -- gradients ------------------------------------------------------ #
    def grad_fn(self) -> Callable:
        """Stochastic grad_fn(node, x, key): minibatch ∇f_i, unbiased."""
        m_i = self.X.shape[1]
        full = self.batch <= 0 or self.batch >= m_i

        if full:
            def gfn(i, x, key):
                del key
                return jax.grad(lambda xx: self.local_loss(i, xx))(x)
            return gfn

        scale = m_i / self.batch  # rescale minibatch sum to unbiased f_i grad

        def gfn(i, x, key):
            idx = jax.random.randint(key, (self.batch,), 0, m_i)
            Xb, yb = self.X[i][idx], self.y[i][idx]

            def loss(xx):
                mg = self._margins(Xb, yb, xx)
                data = jnp.sum(jax.nn.softplus(-mg)) * scale
                return data + 0.5 * self.lam * jnp.sum(xx * xx)

            return jax.grad(loss)(x)
        return gfn

    def optimum(self, iters: int = 2000, lr: float = 0.5) -> jnp.ndarray:
        """Reference x* by full-batch gradient descent on F (for gap plots)."""
        x = jnp.zeros(self.p, jnp.float32)
        g = jax.jit(jax.grad(lambda xx: self.mean_loss(xx)))

        def body(x, _):
            return x - lr * g(x), None
        x, _ = jax.lax.scan(body, x, None, length=iters)
        return x


# --------------------------------------------------------------------- #
# the reduced-LM objective on the flat substrate
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class LMProblem:
    """A transformer LM as a flat-substrate GradProvider.

    Each node owns a Zipfian synthetic shard (problem (1)'s D_i);
    ``grad_fn`` unravels the flat iterate to the parameter pytree,
    samples the node's batch device-side from the per-event key, runs
    ``models.transformer.loss_fn``, and ravels the gradient back to the
    ``(p,)`` lane (zero tail padding — invisible to the protocol,
    which is linear in the lane).  ``mean_loss``/``accuracy`` evaluate
    a fixed held-out batch, so the benchmark harness's
    ``eval_fn_for``/``time_to_loss`` work unchanged.
    """

    cfg: Any                    # models.config.ModelConfig
    shard: LMShardConfig
    spec: RavelSpec
    params0: Any                # init pytree (the x0 everyone broadcasts)
    eval_tokens: jnp.ndarray    # (Be, S) held-out eval batch
    eval_labels: jnp.ndarray    # (Be, S)

    @property
    def n(self) -> int:
        return self.shard.n_nodes

    @property
    def p(self) -> int:
        return self.spec.p

    @property
    def x0_flat(self) -> jnp.ndarray:
        return ravel(self.spec, self.params0)

    def _token_cdf(self) -> jnp.ndarray | None:
        if self.shard.zipf <= 0:
            return None
        return jnp.asarray(
            np.cumsum(zipf_probs(self.shard.vocab, self.shard.zipf)),
            jnp.float32)

    def grad_fn(self):
        from ..models.transformer import loss_fn
        cfg, shard = self.cfg, self.shard
        B, S, V = shard.batch_per_node, shard.seq_len, shard.vocab
        cdf = self._token_cdf()
        vg = jax.value_and_grad(
            lambda prms, t, lbl: loss_fn(cfg, prms, t, lbl))

        def sample(_i, key):
            if cdf is None:
                return jax.random.randint(key, (B, S + 1), 0, V,
                                          dtype=jnp.int32)
            u = jax.random.uniform(key, (B, S + 1))
            return jnp.clip(jnp.searchsorted(cdf, u), 0, V - 1) \
                .astype(jnp.int32)

        # the generic adapter owns the flat recipe (unravel / key split /
        # node-folded batch key / ravel); the model has no per-step
        # stochasticity, so the gkey the adapter passes is unused
        return ModelGradProvider(
            spec=self.spec, n_nodes=self.n,
            value_and_grad=lambda prms, toks, _k: vg(prms, toks[:, :-1],
                                                     toks[:, 1:]),
            batch_fn=sample,
        ).grad_fn()

    # -- evaluation (host-callable, cached jit) ------------------------- #
    @functools.cached_property
    def _eval(self):
        from ..models.transformer import forward
        cfg, spec = self.cfg, self.spec

        @jax.jit
        def ev(x_flat, toks, labels):
            params = unravel(spec, x_flat)
            logits, aux = forward(cfg, params, toks)
            lse = jax.scipy.special.logsumexp(
                logits.astype(jnp.float32), axis=-1)
            tgt = jnp.take_along_axis(logits, labels[..., None],
                                      axis=-1)[..., 0].astype(jnp.float32)
            loss = (lse - tgt).mean() + aux
            acc = jnp.mean((logits.argmax(-1) == labels)
                           .astype(jnp.float32))
            return loss, acc

        return ev

    def mean_loss(self, x_flat: jnp.ndarray) -> jnp.ndarray:
        return self._eval(jnp.asarray(x_flat, jnp.float32),
                          self.eval_tokens, self.eval_labels)[0]

    def accuracy(self, x_flat: jnp.ndarray) -> jnp.ndarray:
        return self._eval(jnp.asarray(x_flat, jnp.float32),
                          self.eval_tokens, self.eval_labels)[1]


def make_lm_problem(
    cfg: Any, n_nodes: int, *, batch_per_node: int = 4, seq_len: int = 32,
    eval_batch: int = 16, zipf: float = 1.2, seed: int = 0,
    pad_to: int = 128,
) -> LMProblem:
    """Build an :class:`LMProblem` from a ``ModelConfig`` (pass a
    ``cfg.reduced(...)`` variant for CPU/CI scale).  ``pad_to=128``
    aligns the flat lane with the fused commit kernel's block layout."""
    from ..models.transformer import init_params
    shard = LMShardConfig(vocab=cfg.vocab, batch_per_node=batch_per_node,
                          seq_len=seq_len, n_nodes=n_nodes, seed=seed,
                          zipf=zipf)
    params0 = init_params(cfg, jax.random.PRNGKey(seed))
    spec = make_ravel_spec(params0, pad_to=pad_to)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x0E7A1]))
    shape = (eval_batch, seq_len + 1)
    if zipf > 0:
        toks = rng.choice(cfg.vocab, size=shape,
                          p=zipf_probs(cfg.vocab, zipf))
    else:
        toks = rng.integers(0, cfg.vocab, shape)
    return LMProblem(
        cfg=cfg, shard=shard, spec=spec, params0=params0,
        eval_tokens=jnp.asarray(toks[:, :-1], jnp.int32),
        eval_labels=jnp.asarray(toks[:, 1:], jnp.int32),
    )


def make_logistic_problem(
    n: int, *, m: int = 12_000, d: int = 784, lam: float = 1e-3,
    batch: int = 32, heterogeneous: bool = False, seed: int = 0,
) -> LogisticProblem:
    from .synthetic import logistic_dataset, partition

    X, y = logistic_dataset(m, d, seed=seed)
    Xs, ys = partition(X, y, n, heterogeneous=heterogeneous, seed=seed)
    # λ split evenly across nodes so Σ_i f_i carries a single global λ
    return LogisticProblem(
        X=jnp.asarray(Xs), y=jnp.asarray(ys), lam=lam / n, batch=batch,
    )
