"""Objectives with the simulator's ``grad_fn(node, x, key)`` interface.

The primary one is the paper's §VI-A regularized logistic regression
(smooth and strongly convex thanks to the L2 term).  A generic adapter
wraps any flat-parameter model loss.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LogisticProblem", "make_logistic_problem"]


@dataclasses.dataclass(frozen=True)
class LogisticProblem:
    """Regularized logistic regression over n node-local shards.

    Parameter layout: x = [w (d,), b ()] -> p = d + 1.
    Local objective:  f_i(x) = Σ_{s∈shard_i} log(1+exp(-ŷ s)) + (λ/2)|x|²
    (sum, not mean — matches problem (1)'s Σ_i f_i structure; the λ term is
    split evenly so F keeps a single global λ).
    """

    X: jnp.ndarray          # (n, m_i, d)
    y: jnp.ndarray          # (n, m_i) in {0,1}
    lam: float
    batch: int              # minibatch size per gradient sample (0 = full)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[2] + 1

    # -- losses --------------------------------------------------------- #
    def _margins(self, Xb, yb, x):
        w, b = x[:-1], x[-1]
        logits = Xb @ w + b
        s = 2.0 * yb.astype(jnp.float32) - 1.0
        return logits * s

    def local_loss(self, i: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        m = self._margins(self.X[i], self.y[i], x)
        return jnp.sum(jax.nn.softplus(-m)) + 0.5 * self.lam * jnp.sum(x * x)

    def global_loss(self, x: jnp.ndarray) -> jnp.ndarray:
        """F(x) = Σ_i f_i(x), evaluated on the full data."""
        losses = jax.vmap(lambda i: self.local_loss(i, x))(jnp.arange(self.n))
        return jnp.sum(losses)

    def mean_loss(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.global_loss(x) / (self.X.shape[0] * self.X.shape[1])

    def accuracy(self, x: jnp.ndarray) -> jnp.ndarray:
        w, b = x[:-1], x[-1]
        logits = self.X.reshape(-1, self.X.shape[-1]) @ w + b
        pred = (logits > 0).astype(jnp.int32)
        return jnp.mean((pred == self.y.reshape(-1)).astype(jnp.float32))

    # -- gradients ------------------------------------------------------ #
    def grad_fn(self) -> Callable:
        """Stochastic grad_fn(node, x, key): minibatch ∇f_i, unbiased."""
        m_i = self.X.shape[1]
        full = self.batch <= 0 or self.batch >= m_i

        if full:
            def gfn(i, x, key):
                del key
                return jax.grad(lambda xx: self.local_loss(i, xx))(x)
            return gfn

        scale = m_i / self.batch  # rescale minibatch sum to unbiased f_i grad

        def gfn(i, x, key):
            idx = jax.random.randint(key, (self.batch,), 0, m_i)
            Xb, yb = self.X[i][idx], self.y[i][idx]

            def loss(xx):
                mg = self._margins(Xb, yb, xx)
                data = jnp.sum(jax.nn.softplus(-mg)) * scale
                return data + 0.5 * self.lam * jnp.sum(xx * xx)

            return jax.grad(loss)(x)
        return gfn

    def optimum(self, iters: int = 2000, lr: float = 0.5) -> jnp.ndarray:
        """Reference x* by full-batch gradient descent on F (for gap plots)."""
        x = jnp.zeros(self.p, jnp.float32)
        g = jax.jit(jax.grad(lambda xx: self.mean_loss(xx)))

        def body(x, _):
            return x - lr * g(x), None
        x, _ = jax.lax.scan(body, x, None, length=iters)
        return x


def make_logistic_problem(
    n: int, *, m: int = 12_000, d: int = 784, lam: float = 1e-3,
    batch: int = 32, heterogeneous: bool = False, seed: int = 0,
) -> LogisticProblem:
    from .synthetic import logistic_dataset, partition

    X, y = logistic_dataset(m, d, seed=seed)
    Xs, ys = partition(X, y, n, heterogeneous=heterogeneous, seed=seed)
    # λ split evenly across nodes so Σ_i f_i carries a single global λ
    return LogisticProblem(
        X=jnp.asarray(Xs), y=jnp.asarray(ys), lam=lam / n, batch=batch,
    )
