"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone (ViT is a STUB)
[hf:mistralai/Pixtral-12B-2409].

40L, d_model=5120, 32 heads (GQA kv=8), d_ff=14336, vocab=131072.
``input_specs`` provides (B, 256, 1024) patch embeddings (the ViT stub);
they are projected and prepended to the text tokens.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=131072,
        mixer="attn",
        mlp="swiglu",
        norm="rmsnorm",
        rope_theta=1e6,
        frontend="vision",
        frontend_seq=256,        # 1024px/64 patches -> 256 tokens (stub)
        frontend_dim=1024,
    )
