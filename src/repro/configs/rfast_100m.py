"""rfast-100m — the ~100M-param LM used by the end-to-end R-FAST training
driver (examples/train_rfast.py).  Llama-style dense decoder.

At full scale the flat parameter vector (~134M fp32, ~0.5 GiB — and the
wavefront engine carries 4 node slots plus the ρ/history rings of it per
node) does not fit a single small device: train through the mesh-mapped
sweep with the flat axis sharded over ``model`` —
``launch.train --scenario <name> --param-shards M`` or
``run_sweep(mesh=make_sweep_mesh(lanes=1, param_shards=M), ...)``; the
``lm100m/*`` rows in benchmarks/bench_scaling.py pin this path.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="rfast-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32000,
        mixer="attn",
        mlp="swiglu",
        norm="rmsnorm",
    )
