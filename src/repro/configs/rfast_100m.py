"""rfast-100m — the ~100M-param LM used by the end-to-end R-FAST training
driver (examples/train_rfast.py).  Llama-style dense decoder.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="rfast-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32000,
        mixer="attn",
        mlp="swiglu",
        norm="rmsnorm",
    )
