"""Assigned-architecture registry: ``get_config(arch_id)``.

Every config cites its source in its module docstring and carries the
exact dimensions from the assignment table.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "whisper-large-v3",
    "olmo-1b",
    "phi3.5-moe-42b-a6.6b",
    "pixtral-12b",
    "falcon-mamba-7b",
    "qwen2.5-3b",
    "llama3-8b",
    "hymba-1.5b",
    "deepseek-7b",
    "deepseek-v2-236b",
    "rfast-100m",          # the paper-scale LM used by the e2e example
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.get_config()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
