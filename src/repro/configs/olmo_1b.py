"""olmo-1b [dense] — non-parametric LayerNorm [arXiv:2402.00838].

16L, d_model=2048, 16 heads (MHA: kv=16), d_ff=8192, vocab=50304.
OLMo: no-bias projections, non-parametric LN, SwiGLU, RoPE, tied embeddings.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=50304,
        mixer="attn",
        norm="nonparam_ln",
        mlp="swiglu",
        tie_embeddings=True,
    )
