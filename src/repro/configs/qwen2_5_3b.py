"""qwen2.5-3b [dense] — GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B family].

36L, d_model=2048, 16 heads (GQA kv=2), d_ff=11008, vocab=151936.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_ff=11008,
        vocab=151936,
        mixer="attn",
        qkv_bias=True,
        mlp="swiglu",
        norm="rmsnorm",
        rope_theta=1e6,
        tie_embeddings=True,
    )
