"""falcon-mamba-7b [ssm] — mamba-1, attention-free [arXiv:2410.05355].

64L, d_model=4096, no attention heads, d_ff=0 (no MLP: the mamba block IS
the layer), vocab=65024, ssm_state=16, d_inner=2*d_model, conv=4.
Sub-quadratic: runs the long_500k shape.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=65024,
        mixer="ssm",
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        norm="rmsnorm",
    )
