"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), per-expert d_ff=6400, vocab=32064,
MoE 16e top-2 every layer.  SwiGLU experts, RoPE, RMSNorm.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab=32064,
        mixer="attn",
        moe_experts=16,
        moe_top_k=2,
        mlp="swiglu",
        norm="layernorm",        # phi-3.5 uses LayerNorm-style (ls) norms
    )
