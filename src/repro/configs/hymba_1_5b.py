"""hymba-1.5b [hybrid] — parallel attention + mamba heads [arXiv:2411.13676].

32L, d_model=1600, 25 heads (GQA kv=5), d_ff=5504, vocab=32001,
ssm_state=16.  Every layer runs attention and an SSM head in parallel on
the same input and averages the outputs (hymba's fused-head design).
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        mixer="hybrid",
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        mlp="swiglu",
        norm="rmsnorm",
        attn_window=1024,      # hymba uses SWA in most layers
    )
