"""whisper-large-v3 [audio] — enc-dec, conv frontend (STUB) [arXiv:2212.04356].

32L decoder + 32L encoder, d_model=1280, 20 heads (MHA: kv=20), d_ff=5120,
vocab=51866.  The mel-spectrogram + conv feature extractor is a stub:
``input_specs`` provides (B, 1500, 1280) frame embeddings.
Whisper uses absolute sinusoidal positions and LayerNorm + GELU + biases.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        mixer="attn",
        attention="gqa",
        use_rope=False,
        qkv_bias=True,
        mlp="gelu",
        mlp_bias=True,
        norm="layernorm",
        enc_dec=True,
        n_enc_layers=32,
        frontend="audio",
        frontend_seq=1500,       # 30 s of audio at 50 Hz after conv stride
        frontend_dim=1280,
    )
