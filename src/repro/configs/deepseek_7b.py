"""deepseek-7b [dense] — llama-arch, MHA [arXiv:2401.02954].

30L, d_model=4096, 32 heads (kv=32: MHA), d_ff=11008, vocab=102400.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab=102400,
        mixer="attn",
        mlp="swiglu",
        norm="rmsnorm",
    )
