"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed
experts, top-6 [arXiv:2405.04434].

60L, d_model=5120, 128 heads, per-expert d_ff=1536, vocab=102400.
MLA: kv_lora_rank=512, q_lora_rank=1536, qk_nope=128, qk_rope=64,
v_head=128.  The compressed KV cache (B, S, 512+64) is the whole point.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=1536,
        vocab=102400,
        mixer="attn",
        attention="mla",
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_rope_dim=64,
        v_head_dim=128,
        moe_experts=160,
        moe_top_k=6,
        moe_shared=2,
        mlp="swiglu",
        norm="rmsnorm",
    )
