"""Global-view (Algorithm 2) R-FAST simulator.

Executes the *exact* R-FAST recursion under an arbitrary realized
asynchronous schedule (activations + per-edge payload stamps produced by
``schedule.py``), entirely in JAX with a ``lax.scan`` over global
iterations.  The simulator is the faithful-reproduction engine: every
update is S.1–S.5 of Algorithm 2 verbatim — the formulas themselves live
in :mod:`repro.core.protocol`; this engine owns only the *delayed-read*
realization (history buffers indexed by payload stamps) over the dense
edge arrays of a :class:`repro.core.plan.CommPlan`.

State representation (flat parameter vectors, ``p`` = dimension):

* ``x, v, z, g_prev`` — ``(n, p)`` per-node model / intermediate / tracking /
  last-sampled-gradient variables.
* ``rho``       — ``(E_A, p)`` running sums ρ_{ji} held at the *sender* of
  each A-edge; ``rho_buf`` — the receiver's buffers ρ̃_{ij}.
* ``v_hist`` / ``rho_hist`` — rolling snapshots indexed by global stamp mod
  ``H`` (``H ≥ D+2``) realizing the delayed reads ``v_j^{k-d}``, ``ρ^{k-d}``.

Mass-conservation invariant (Lemma 3), checked in tests under arbitrary
delay/loss schedules::

    Σ_i z_i + Σ_e (ρ_e − ρ̃_e)  ==  Σ_i ∇f_i(x_i^k; ζ_i^k)
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .plan import CommPlan, as_comm_plan
from .protocol import consensus_mix, descent_step, mailbox_merge, tracking_step
from .schedule import Schedule
from .topology import Topology

__all__ = ["RFASTState", "init_state", "rfast_scan", "run_rfast", "tracked_mass"]

GradFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]
# grad_fn(node_id, x_node, rng_key) -> gradient, all traced.


class RFASTState(NamedTuple):
    k: jnp.ndarray        # () int32 global iteration
    x: jnp.ndarray        # (n, p)
    v: jnp.ndarray        # (n, p)
    z: jnp.ndarray        # (n, p)
    g_prev: jnp.ndarray   # (n, p)
    rho: jnp.ndarray      # (E_A, p)
    rho_buf: jnp.ndarray  # (E_A, p)
    v_hist: jnp.ndarray   # (H, n, p)
    rho_hist: jnp.ndarray # (H, E_A, p)


def _sim_edges(plan: CommPlan):
    """Unpadded leading slices of the dense edge arrays (the schedule's
    per-edge stamp arrays are sized (K, max(1, E)) — match them)."""
    ew = max(1, plan.n_edges_w)
    ea = max(1, plan.n_edges_a)
    return (plan.src_w[:ew], plan.dst_w[:ew], plan.w_edge[:ew],
            plan.src_a[:ea], plan.dst_a[:ea], plan.a_edge[:ea])


def init_state(
    topo: Topology | CommPlan,
    x0: jnp.ndarray,
    grad_fn: GradFn,
    key: jax.Array,
    H: int,
) -> RFASTState:
    """Paper init: z_i^0 = ∇f_i(x_i^0; ζ_i^0); v = ρ = ρ̃ = 0."""
    plan = as_comm_plan(topo)
    n = plan.n
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None, :], (n, 1))
    p = x0.shape[1]
    e_a = max(1, plan.n_edges_a)
    keys = jax.random.split(key, n)
    g0 = jax.vmap(grad_fn)(jnp.arange(n), x0, keys)
    zeros_np = jnp.zeros((n, p), jnp.float32)
    return RFASTState(
        k=jnp.zeros((), jnp.int32),
        x=x0,
        v=zeros_np,
        z=g0,
        g_prev=g0,
        rho=jnp.zeros((e_a, p), jnp.float32),
        rho_buf=jnp.zeros((e_a, p), jnp.float32),
        v_hist=jnp.zeros((H, n, p), jnp.float32),
        rho_hist=jnp.zeros((H, e_a, p), jnp.float32),
    )


def _step(
    state: RFASTState,
    inputs,
    *,
    plan: CommPlan,
    grad_fn: GradFn,
    gamma: float,
    H: int,
) -> tuple[RFASTState, None]:
    agent, stamp_v, stamp_rho, key = inputs
    a = agent
    k = state.k

    sw, dw, we, sa, da, ae = _sim_edges(plan)
    diag_w = jnp.asarray(plan.w_diag)
    diag_a = jnp.asarray(plan.a_diag)
    src_w = jnp.asarray(sw); dst_w = jnp.asarray(dw)
    src_a = jnp.asarray(sa); dst_a = jnp.asarray(da)
    w_edge = jnp.asarray(we); a_edge = jnp.asarray(ae)

    # (S.1) local descent ------------------------------------------------
    v_new = descent_step(state.x[a], state.z[a], gamma)

    # (S.2a) consensus pull over G(W) with stale payloads ------------------
    vals_v = state.v_hist[stamp_v % H, src_w, :]          # (E_W, p)
    mask_w = (dst_w == a).astype(vals_v.dtype)[:, None]
    x_a = consensus_mix(diag_w[a], v_new, mask_w * w_edge[:, None], vals_v)

    # (S.2b) robust gradient tracking -------------------------------------
    g_new = grad_fn(a, x_a, key)
    vals_rho = state.rho_hist[stamp_rho % H, jnp.arange(src_a.shape[0]), :]
    mask_a_in = (dst_a == a).astype(vals_rho.dtype)[:, None]
    recv = jnp.sum(mask_a_in * (vals_rho - state.rho_buf), axis=0)
    z_half = tracking_step(state.z[a], recv, g_new, state.g_prev[a])

    # (S.2c) keep own share; push mass onto out-edges ----------------------
    z_a = diag_a[a] * z_half
    mask_a_out = (src_a == a).astype(vals_rho.dtype)[:, None]
    rho = state.rho + mask_a_out * a_edge[:, None] * z_half[None, :]

    # (S.4) buffers take the consumed values -------------------------------
    rho_buf = mailbox_merge(vals_rho, state.rho_buf, mask_a_in)

    # commit --------------------------------------------------------------
    x = state.x.at[a].set(x_a)
    v = state.v.at[a].set(v_new)
    z = state.z.at[a].set(z_a)
    g_prev = state.g_prev.at[a].set(g_new)
    v_hist = state.v_hist.at[(k + 1) % H].set(v)
    rho_hist = state.rho_hist.at[(k + 1) % H].set(rho)

    return RFASTState(k + 1, x, v, z, g_prev, rho, rho_buf, v_hist, rho_hist), None


def rfast_scan(
    topo: Topology | CommPlan,
    grad_fn: GradFn,
    gamma: float,
    H: int,
):
    """Returns a jitted ``(state, agent, stamp_v, stamp_rho, keys) -> state``."""
    plan = as_comm_plan(topo)
    step = partial(_step, plan=plan, grad_fn=grad_fn, gamma=gamma, H=H)

    @jax.jit
    def run_chunk(state: RFASTState, agent, stamp_v, stamp_rho, keys):
        state, _ = jax.lax.scan(step, state, (agent, stamp_v, stamp_rho, keys))
        return state

    return run_chunk


def tracked_mass(state: RFASTState) -> jnp.ndarray:
    """LHS of the Lemma-3 invariant: Σ_i z_i + Σ_e (ρ_e − ρ̃_e)."""
    return state.z.sum(axis=0) + (state.rho - state.rho_buf).sum(axis=0)


def run_rfast(
    topo: Topology,
    schedule: Schedule,
    grad_fn: GradFn,
    x0: jnp.ndarray,
    gamma: float,
    *,
    seed: int = 0,
    eval_every: int = 0,
    eval_fn: Callable[[RFASTState, float], dict] | None = None,
) -> tuple[RFASTState, list[dict]]:
    """Run the full schedule; optionally evaluate every ``eval_every`` events."""
    plan = as_comm_plan(topo)
    H = int(schedule.D) + 2
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    state = init_state(plan, x0, grad_fn, init_key, H)
    chunk = rfast_scan(plan, grad_fn, gamma, H)

    K = schedule.K
    step_keys = jax.random.split(key, K)
    agent = jnp.asarray(schedule.agent)
    stamp_v = jnp.asarray(schedule.stamp_v)
    stamp_rho = jnp.asarray(schedule.stamp_rho)

    metrics: list[dict] = []
    if eval_every <= 0:
        eval_every = K
    for s in range(0, K, eval_every):
        e = min(K, s + eval_every)
        state = chunk(state, agent[s:e], stamp_v[s:e], stamp_rho[s:e],
                      step_keys[s:e])
        if eval_fn is not None:
            m = eval_fn(state, float(schedule.times[e - 1]))
            m["k"] = e
            metrics.append(m)
    return state, metrics
