"""Global-view (Algorithm 2) R-FAST simulator.

Executes the *exact* R-FAST recursion under an arbitrary realized
asynchronous schedule (activations + per-edge payload stamps produced by
``schedule.py``), entirely in JAX with a ``lax.scan``.  The simulator is
the faithful-reproduction engine: every update is S.1–S.5 of Algorithm 2
verbatim — the formulas themselves live in :mod:`repro.core.protocol`;
this engine owns only the *delayed-read* realization (history buffers
indexed by payload stamps) over a :class:`repro.core.plan.CommPlan`.

Two execution modes share one state layout:

* ``mode="wavefront"`` (default) — the schedule is compiled host-side
  (:func:`repro.core.schedule.build_wavefront_plan`) into groups of
  events with distinct agents whose payload stamps predate the group;
  each scan step vmaps the per-agent update across one group and commits
  **O(p) delta rows** into the histories (``v_hist[slot, agent]`` /
  ``rho_hist[slot, out-edge]``) instead of full-array snapshots.  Stale
  reads are pre-resolved to ring slots by the host pass, so the device
  never materializes an O(n·p) snapshot per event.
* ``mode="event"`` — the original one-event-per-step engine with full
  ``(H, n, p)`` / ``(H, E_A, p)`` snapshot commits; kept as the oracle
  the wavefront path is tested against.

A third entry point batches at the *experiment* level: :func:`run_sweep`
runs a fleet of S independent (topology, schedule, seed) experiments as
ONE compiled program — per-lane plans are degree-normalized, padded to
shared wave maxima, stacked into dense ``(S, ...)`` arrays
(``schedule.pad_plan`` / ``stack_plans``), and then *flattened*
(``schedule.flatten_plans``) into one wider single-experiment program:
the fleet state is the ``(S, n, 4, p)`` lane stack realized as
block-concatenated ``(S·n, 4, p)`` rows, and the scan body is the
ordinary wave step at width S·B — so the fleet pays ONE compile, not S.
Each lane reproduces its individual :func:`run_rfast` trajectory to fp32
tolerance.

State representation (flat parameter vectors, ``p`` = dimension):

* ``x, v, z, g_prev`` — ``(n, p)`` per-node model / intermediate / tracking /
  last-sampled-gradient variables.
* ``rho``       — ``(E_A, p)`` running sums ρ_{ji} held at the *sender* of
  each A-edge; ``rho_buf`` — the receiver's buffers ρ̃_{ij}.
* ``v_hist`` / ``rho_hist`` — history rings (``H ≥ D+2``) realizing the
  delayed reads ``v_j^{k-d}``, ``ρ^{k-d}``; snapshot-indexed in event
  mode, per-writer-counter delta-indexed in wavefront mode.

Mass-conservation invariant (Lemma 3), checked in tests under arbitrary
delay/loss schedules::

    Σ_i z_i + Σ_e (ρ_e − ρ̃_e)  ==  Σ_i ∇f_i(x_i^k; ζ_i^k)
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.rfast_update import dispatch
from ..kernels.rfast_update.grid import block_pad_width, commit_grid
from ..kernels.rfast_update.ops import rfast_commit
from .paramvec import GradProvider, as_grad_fn
from .plan import CommPlan, as_comm_plan, pad_comm_plan
from .runtime_sharded import _shard_map, packed_sweep_specs
from .protocol import consensus_mix, descent_step, mailbox_merge, tracking_step
from .schedule import (Schedule, build_wavefront_plan, concat_plans,
                       flatten_plans, grid_gather_tables, pad_plan,
                       slice_plan, stack_plans)
from .topology import Topology

__all__ = ["RFASTState", "PackedState", "init_state", "zeros_state",
           "pack_state", "unpack_state", "wave_inputs", "rfast_scan",
           "rfast_wavefront_scan", "rfast_sweep_scan", "run_rfast",
           "run_sweep", "migrate_state", "run_epochs", "run_sweep_epochs",
           "tracked_mass"]

GradFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]
# grad_fn(node_id, x_node, rng_key) -> gradient, all traced.
# Every engine entry point also accepts a paramvec.GradProvider (e.g.
# LogisticProblem, LMProblem): the objective is resolved ONCE through
# paramvec.as_grad_fn, so the engines are objective-agnostic — a bare
# callable (the pre-substrate API) passes through bit-exact.
Objective = GradFn | GradProvider


class RFASTState(NamedTuple):
    k: jnp.ndarray        # () int32 global iteration
    x: jnp.ndarray        # (n, p)
    v: jnp.ndarray        # (n, p)
    z: jnp.ndarray        # (n, p)
    g_prev: jnp.ndarray   # (n, p)
    rho: jnp.ndarray      # (E_A, p)
    rho_buf: jnp.ndarray  # (E_A, p)
    v_hist: jnp.ndarray   # (H, n, p)
    rho_hist: jnp.ndarray # (H, E_A, p)


class _Prepared(NamedTuple):
    """CommPlan slices as device constants, converted once per engine
    build (not once per trace)."""

    w_diag: jnp.ndarray
    a_diag: jnp.ndarray
    src_w: jnp.ndarray; dst_w: jnp.ndarray; w_edge: jnp.ndarray
    src_a: jnp.ndarray; dst_a: jnp.ndarray; a_edge: jnp.ndarray
    in_w_src: jnp.ndarray; in_w_wt: jnp.ndarray
    in_a_epos: jnp.ndarray; in_a_val: jnp.ndarray
    out_a_epos: jnp.ndarray; out_a_wt: jnp.ndarray; out_a_val: jnp.ndarray


def _prepare(plan: CommPlan) -> _Prepared:
    ew = max(1, plan.n_edges_w)
    ea = max(1, plan.n_edges_a)
    # the schedule's per-edge stamp arrays are sized (K, max(1, E)) — the
    # dense edge slices must match them, hence the unpadded leading cut
    return _Prepared(
        w_diag=jnp.asarray(plan.w_diag), a_diag=jnp.asarray(plan.a_diag),
        src_w=jnp.asarray(plan.src_w[:ew]), dst_w=jnp.asarray(plan.dst_w[:ew]),
        w_edge=jnp.asarray(plan.w_edge[:ew]),
        src_a=jnp.asarray(plan.src_a[:ea]), dst_a=jnp.asarray(plan.dst_a[:ea]),
        a_edge=jnp.asarray(plan.a_edge[:ea]),
        in_w_src=jnp.asarray(plan.in_w_src), in_w_wt=jnp.asarray(plan.in_w_wt),
        in_a_epos=jnp.asarray(plan.in_a_epos),
        in_a_val=jnp.asarray(plan.in_a_val),
        out_a_epos=jnp.asarray(plan.out_a_epos),
        out_a_wt=jnp.asarray(plan.out_a_wt),
        out_a_val=jnp.asarray(plan.out_a_val),
    )


def init_state(
    topo: Topology | CommPlan,
    x0: jnp.ndarray,
    grad_fn: Objective,
    key: jax.Array,
    H: int,
) -> RFASTState:
    """Paper init: z_i^0 = ∇f_i(x_i^0; ζ_i^0); v = ρ = ρ̃ = 0."""
    grad_fn = as_grad_fn(grad_fn)
    plan = as_comm_plan(topo)
    n = plan.n
    # copy (not asarray): the state may be donated by the engines, and the
    # caller's x0 buffer must survive the run
    x0 = jnp.array(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None, :], (n, 1))
    p = x0.shape[1]
    e_a = max(1, plan.n_edges_a)
    keys = jax.random.split(key, n)
    g0 = jax.vmap(grad_fn)(jnp.arange(n), x0, keys)
    zeros_np = jnp.zeros((n, p), jnp.float32)
    return RFASTState(
        k=jnp.zeros((), jnp.int32),
        x=x0,
        v=zeros_np,
        z=g0,
        g_prev=jnp.copy(g0),   # distinct buffer: donation forbids aliases
        rho=jnp.zeros((e_a, p), jnp.float32),
        rho_buf=jnp.zeros((e_a, p), jnp.float32),
        v_hist=jnp.zeros((H, n, p), jnp.float32),
        rho_hist=jnp.zeros((H, e_a, p), jnp.float32),
    )


def zeros_state(topo: Topology | CommPlan, p: int, H: int) -> RFASTState:
    """Structure-only all-zeros state: shapes/dtypes of a run over
    ``topo`` with flat dimension ``p`` and history depth ``H``.  The
    checkpoint-restore template (``load_checkpoint(dir, like=...)``) —
    no gradient evaluation, unlike :func:`init_state`."""
    plan = as_comm_plan(topo)
    n, e_a = plan.n, max(1, plan.n_edges_a)
    zn = lambda *s: jnp.zeros(s, jnp.float32)
    return RFASTState(
        k=jnp.zeros((), jnp.int32),
        x=zn(n, p), v=zn(n, p), z=zn(n, p), g_prev=zn(n, p),
        rho=zn(e_a, p), rho_buf=zn(e_a, p),
        v_hist=zn(H, n, p), rho_hist=zn(H, e_a, p),
    )


# --------------------------------------------------------------------- #
# event-serial engine (snapshot histories) — the equivalence oracle
# --------------------------------------------------------------------- #
def _step(
    state: RFASTState,
    inputs,
    *,
    pp: _Prepared,
    grad_fn: GradFn,
    gamma: float,
    H: int,
) -> tuple[RFASTState, None]:
    agent, stamp_v, stamp_rho, key = inputs
    a = agent
    k = state.k

    # (S.1) local descent ------------------------------------------------
    v_new = descent_step(state.x[a], state.z[a], gamma)

    # (S.2a) consensus pull over G(W) with stale payloads ------------------
    vals_v = state.v_hist[stamp_v % H, pp.src_w, :]       # (E_W, p)
    mask_w = (pp.dst_w == a).astype(vals_v.dtype)[:, None]
    x_a = consensus_mix(pp.w_diag[a], v_new, mask_w * pp.w_edge[:, None],
                        vals_v)

    # (S.2b) robust gradient tracking -------------------------------------
    g_new = grad_fn(a, x_a, key)
    vals_rho = state.rho_hist[stamp_rho % H,
                              jnp.arange(pp.src_a.shape[0]), :]
    mask_a_in = (pp.dst_a == a).astype(vals_rho.dtype)[:, None]
    recv = jnp.sum(mask_a_in * (vals_rho - state.rho_buf), axis=0)
    z_half = tracking_step(state.z[a], recv, g_new, state.g_prev[a])

    # (S.2c) keep own share; push mass onto out-edges ----------------------
    z_a = pp.a_diag[a] * z_half
    mask_a_out = (pp.src_a == a).astype(vals_rho.dtype)[:, None]
    rho = state.rho + mask_a_out * pp.a_edge[:, None] * z_half[None, :]

    # (S.4) buffers take the consumed values -------------------------------
    rho_buf = mailbox_merge(vals_rho, state.rho_buf, mask_a_in)

    # commit --------------------------------------------------------------
    x = state.x.at[a].set(x_a)
    v = state.v.at[a].set(v_new)
    z = state.z.at[a].set(z_a)
    g_prev = state.g_prev.at[a].set(g_new)
    v_hist = state.v_hist.at[(k + 1) % H].set(v)
    rho_hist = state.rho_hist.at[(k + 1) % H].set(rho)

    return RFASTState(k + 1, x, v, z, g_prev, rho, rho_buf, v_hist, rho_hist), None


def rfast_scan(
    topo: Topology | CommPlan,
    grad_fn: Objective,
    gamma: float,
    H: int,
    *,
    donate: bool = False,
):
    """Event-serial engine: a jitted
    ``(state, agent, stamp_v, stamp_rho, keys) -> state``.

    ``donate=True`` donates the state argument (in-place update of the
    history rings) — the caller must not reuse the passed-in state.
    """
    grad_fn = as_grad_fn(grad_fn)
    plan = as_comm_plan(topo)
    pp = _prepare(plan)
    step = partial(_step, pp=pp, grad_fn=grad_fn, gamma=gamma, H=H)

    def run_chunk(state: RFASTState, agent, stamp_v, stamp_rho, keys):
        state, _ = jax.lax.scan(step, state, (agent, stamp_v, stamp_rho, keys))
        return state

    return jax.jit(run_chunk, donate_argnums=(0,) if donate else ())


# --------------------------------------------------------------------- #
# wavefront-batched engine (delta histories, vmapped lanes)
# --------------------------------------------------------------------- #
class PackedState(NamedTuple):
    """Device layout of the wavefront engine: node variables fused into
    one array and ρ/ρ̃ stacked, so a wavefront commits with four scatters.

    * ``nodes``  — (n, 4, p): rows x, v, z, g_prev per node.
    * ``rho2``   — (2·E_A, p): ρ rows then ρ̃ rows.
    * ``v_hist`` — (H, n, p) delta rows indexed (writer count mod H, node).
    * ``rho_hist`` — (H, E_A, p) delta rows (sender count mod H, edge).
    """

    nodes: jnp.ndarray
    rho2: jnp.ndarray
    v_hist: jnp.ndarray
    rho_hist: jnp.ndarray


class _WaveInputs(NamedTuple):
    """Per-wavefront lane tables (one scan-step slice of a WavefrontPlan)."""

    agent: jnp.ndarray      # (B,)
    wslot: jnp.ndarray      # (B,)
    w_self: jnp.ndarray     # (B,)
    a_self: jnp.ndarray     # (B,)
    rslot_v: jnp.ndarray    # (B, kw)
    src_v: jnp.ndarray      # (B, kw)
    w_in: jnp.ndarray       # (B, kw)
    rslot_rho: jnp.ndarray  # (B, ka)
    hist_epos: jnp.ndarray  # (B, ka)
    a_val: jnp.ndarray      # (B, ka)
    rho_gidx: jnp.ndarray   # (B, ko+ka)
    out_wt: jnp.ndarray     # (B, ko)
    keys: jnp.ndarray       # (B, 2)


def pack_state(state: RFASTState, *, e_a: int | None = None,
               p_pad: int | None = None) -> PackedState:
    """Device layout for the wavefront/sweep engines.

    ``e_a`` pads the ρ state to a larger flat layout (fleet sweeps
    normalize every lane to the fleet-wide max A-edge count; the extra
    zero rows are never referenced by a real lane and the matching
    WavefrontPlan must be built/padded against the same ``e_a``).

    ``p_pad`` zero-pads the flat parameter axis (the compiled grid
    kernel needs block-multiple widths; the zero tail is inert under the
    linear protocol — pass the real ``p`` back via the engines'
    ``p_real`` / :func:`unpack_state`'s ``p``).
    """
    rho, rho_buf, rho_hist = state.rho, state.rho_buf, state.rho_hist
    if e_a is not None and e_a != rho.shape[0]:
        if e_a < rho.shape[0]:
            raise ValueError(f"e_a={e_a} < state's A-edge count "
                             f"{rho.shape[0]}")
        pad = e_a - rho.shape[0]
        rho = jnp.pad(rho, ((0, pad), (0, 0)))
        rho_buf = jnp.pad(rho_buf, ((0, pad), (0, 0)))
        rho_hist = jnp.pad(rho_hist, ((0, 0), (0, pad), (0, 0)))
    packed = PackedState(
        nodes=jnp.stack([state.x, state.v, state.z, state.g_prev], axis=1),
        rho2=jnp.concatenate([rho, rho_buf], axis=0),
        v_hist=state.v_hist,
        rho_hist=rho_hist,
    )
    p = packed.nodes.shape[-1]
    if p_pad is not None and p_pad != p:
        if p_pad < p:
            raise ValueError(f"p_pad={p_pad} < state's p={p}")
        wpad = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1)
                                 + [(0, p_pad - p)])
        packed = PackedState(*(wpad(a) for a in packed))
    return packed


def unpack_state(packed: PackedState, k, *, p: int | None = None
                 ) -> RFASTState:
    e_a = packed.rho_hist.shape[1]
    if p is not None and p != packed.nodes.shape[-1]:
        packed = PackedState(*(a[..., :p] for a in packed))
    return RFASTState(
        k=jnp.asarray(k, jnp.int32),
        x=packed.nodes[:, 0], v=packed.nodes[:, 1],
        z=packed.nodes[:, 2], g_prev=packed.nodes[:, 3],
        rho=packed.rho2[:e_a], rho_buf=packed.rho2[e_a:],
        v_hist=packed.v_hist, rho_hist=packed.rho_hist,
    )


def _wave_step(
    state: PackedState,
    w: _WaveInputs,
    *,
    grad_fn: GradFn,
    gamma: float,
    ko: int,
    impl: str = "jnp",
    mode: str = "emulate",
    p_real: int | None = None,
) -> tuple[PackedState, None]:
    """One wavefront: B independent per-agent updates (distinct agents,
    pre-wavefront reads only — see build_wavefront_plan), committed as
    disjoint O(p) row scatters.  Padding lanes carry sentinel indices:
    their gathers clamp and their commits drop.  All plan-derived tables
    arrive pre-gathered per lane, so the body reads only the four state
    arrays.

    ``impl="pallas"`` routes the S.2b/c + S.4 commit math (the
    bandwidth-bound tail) through ONE fused :func:`commit_grid` launch
    for the whole wave — the lane tables become flat-row gather indices
    into the packed state (``nodes.reshape(N·4, p)``,
    ``rho_hist.reshape(H·E, p)``, ``rho2``), so no per-lane neighbour
    stacks are materialized and no per-lane kernel is dispatched.
    ``mode`` is the resolved dispatch mode: ``interpret`` keeps the
    original vmapped per-node kernel as the bit-faithful oracle;
    ``compiled``/``emulate`` take the grid.  The consensus pull stays in
    jnp either way: the gradient must be sampled at the mixed point x⁺
    before the commit runs.

    ``p_real`` (< p only when the flat axis was block-padded for the
    compiled grid) slices the parameter tail off before ``grad_fn`` and
    zero-pads the gradient back — the pad tail stays exactly zero under
    the linear protocol.
    """
    node_rows = state.nodes[w.agent]                       # (B, 4, p)
    x_l, z_l, gp_l = node_rows[:, 0], node_rows[:, 2], node_rows[:, 3]

    # (S.1) local descent -------------------------------------------------
    v_new = descent_step(x_l, z_l, gamma)                  # (B, p)

    # (S.2a) consensus pull, reads resolved to delta-history rows ----------
    vals_v = state.v_hist[w.rslot_v, w.src_v]              # (B, kw, p)
    x_a = consensus_mix(w.w_self[:, None], v_new,
                        w.w_in.swapaxes(0, 1)[..., None],
                        vals_v.swapaxes(0, 1))             # sum over kw

    # (S.2b) robust gradient tracking -------------------------------------
    p = x_a.shape[-1]
    if p_real is not None and p_real != p:
        g_new = jax.vmap(grad_fn)(w.agent, x_a[:, :p_real], w.keys)
        g_new = jnp.pad(g_new, ((0, 0), (0, p - p_real)))
    else:
        g_new = jax.vmap(grad_fn)(w.agent, x_a, w.keys)

    if impl == "pallas" and mode != "interpret":
        # one fused launch for the whole wave: gather tables over the
        # flat state rows.  The kernel's masked ρ̃ blend equals the jnp
        # path's unconditional vals_rho commit: a_val is a 0/1 indicator
        # and zero-mask rows scatter to the drop sentinel anyway.
        # Sentinel lanes clamp inside commit_grid; their commits drop.
        nodes_flat = state.nodes.reshape(-1, p)            # (N·4, p)
        hist_flat = state.rho_hist.reshape(-1, p)          # (H·E, p)
        idx_z, idx_g, idx_ri, idx_rb, idx_ro = grid_gather_tables(
            w.agent, w.rslot_rho, w.hist_epos, w.rho_gidx,
            e_a_flat=state.rho_hist.shape[1], ko=ko)
        z_a, rho_new, buf_new = commit_grid(
            idx_z, idx_g, idx_ri, idx_rb, idx_ro,
            w.a_self, w.a_val, w.out_wt,
            nodes_flat, g_new, nodes_flat, hist_flat,
            state.rho2, state.rho2, mode=mode)
        rho_commit = jnp.concatenate([rho_new, buf_new], axis=1)
    elif impl == "pallas":
        # interpret-mode oracle: the original vmapped per-node kernel.
        vals_rho = state.rho_hist[w.rslot_rho, w.hist_epos]  # (B, ka, p)
        rho_rows = state.rho2[w.rho_gidx]                    # (B, ko+ka, p)

        def one_lane(z_, gn_, go_, ri_, rb_, mk_, ro_, ao_, as_):
            return rfast_commit(z_, gn_, go_, ri_, rb_, mk_, ro_, ao_,
                                a_self=as_, impl="pallas",
                                interpret=True)
        z_a, rho_new, buf_new = jax.vmap(one_lane)(
            z_l, g_new, gp_l, vals_rho, rho_rows[:, ko:], w.a_val,
            rho_rows[:, :ko], w.out_wt, w.a_self)
        rho_commit = jnp.concatenate([rho_new, buf_new], axis=1)
    else:
        vals_rho = state.rho_hist[w.rslot_rho, w.hist_epos]  # (B, ka, p)
        rho_rows = state.rho2[w.rho_gidx]                    # (B, ko+ka, p)
        recv = jnp.sum(w.a_val[..., None]
                       * (vals_rho - rho_rows[:, ko:]), axis=1)
        z_half = tracking_step(z_l, recv, g_new, gp_l)

        # (S.2c) keep own share; push mass onto out-edges ------------------
        z_a = w.a_self[:, None] * z_half
        rho_new = rho_rows[:, :ko] \
            + w.out_wt[..., None] * z_half[:, None, :]     # (B, ko, p)
        rho_commit = jnp.concatenate([rho_new, vals_rho], axis=1)

    # commit: disjoint row scatters; (S.4) ρ̃ rows take the consumed values
    node_new = jnp.stack([x_a, v_new, z_a, g_new], axis=1)
    return PackedState(
        nodes=state.nodes.at[w.agent].set(node_new, mode="drop"),
        rho2=state.rho2.at[w.rho_gidx].set(rho_commit, mode="drop"),
        v_hist=state.v_hist.at[w.wslot, w.agent].set(v_new, mode="drop"),
        rho_hist=state.rho_hist.at[w.wslot[:, None], w.rho_gidx[:, :ko]]
        .set(rho_new, mode="drop"),
    ), None


def rfast_wavefront_scan(
    topo: Topology | CommPlan,
    grad_fn: Objective,
    gamma: float,
    *,
    donate: bool = True,
    impl: str = "jnp",
    interpret: bool | None = None,
    p_real: int | None = None,
):
    """Wavefront engine: a jitted ``(packed, wave_inputs) -> packed`` where
    ``wave_inputs`` is a :class:`_WaveInputs` of ``(n_waves, B, ...)``
    lane arrays from a :class:`~repro.core.schedule.WavefrontPlan`.  The
    state is donated by default (the histories update in place; callers
    rebind).

    ``impl="pallas"`` commits every wave through ONE fused grid launch
    (:func:`repro.kernels.rfast_update.grid.commit_grid`); ``interpret``
    is the tri-state dispatch override (None = autodetect: compiled on
    TPU, jnp emulation elsewhere; True = the vmapped per-node kernel in
    the Pallas interpreter, the tests-only oracle).  ``impl="jnp"`` is
    the scatter/gather path.  ``p_real`` marks a block-padded flat axis
    (see :func:`_wave_step`).
    """
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"impl must be 'jnp' or 'pallas', got {impl!r}")
    mode = dispatch.resolve_mode(interpret) if impl == "pallas" else "emulate"
    grad_fn = as_grad_fn(grad_fn)
    plan = as_comm_plan(topo)
    step = partial(_wave_step, grad_fn=grad_fn, gamma=gamma, ko=plan.ko,
                   impl=impl, mode=mode, p_real=p_real)

    def run_waves(state: PackedState, waves: _WaveInputs):
        state, _ = jax.lax.scan(step, state, waves)
        return state

    return jax.jit(run_waves, donate_argnums=(0,) if donate else ())


def wave_inputs(wf, step_keys: jnp.ndarray) -> _WaveInputs:
    """Device lane tables for a WavefrontPlan (kidx == K selects the zero
    padding key row)."""
    lane_keys = jnp.concatenate(
        [step_keys, jnp.zeros((1, 2), step_keys.dtype)])[jnp.asarray(wf.kidx)]
    return _WaveInputs(
        agent=jnp.asarray(wf.agent), wslot=jnp.asarray(wf.wslot),
        w_self=jnp.asarray(wf.w_self), a_self=jnp.asarray(wf.a_self),
        rslot_v=jnp.asarray(wf.rslot_v), src_v=jnp.asarray(wf.src_v),
        w_in=jnp.asarray(wf.w_in), rslot_rho=jnp.asarray(wf.rslot_rho),
        hist_epos=jnp.asarray(wf.hist_epos), a_val=jnp.asarray(wf.a_val),
        rho_gidx=jnp.asarray(wf.rho_gidx), out_wt=jnp.asarray(wf.out_wt),
        keys=lane_keys,
    )


def rfast_sweep_scan(
    grad_fn: Objective,
    gamma: float,
    *,
    ko: int,
    n_per_lane: int,
    donate: bool = True,
    impl: str = "jnp",
    interpret: bool | None = None,
    p_real: int | None = None,
):
    """Fleet engine: a jitted ``(packed, wave_inputs) -> packed`` over a
    fleet-FLATTENED plan (:func:`repro.core.schedule.flatten_plans`).

    The fleet program IS the single-experiment wavefront program at
    width S·B over block-concatenated state (nodes ``(S·n, 4, p)``, ρ
    ``(2·S·e_a, p)``): lanes were made disjoint by index offsetting
    host-side, so the scan body is :func:`_wave_step` itself — no fleet
    vmap, and the compile cost matches ONE run, not S.  With
    ``impl="pallas"`` the whole fleet wave therefore commits as ONE
    grid launch spanning (lane × wave-slot) × p-tiles.  ``grad_fn``
    still sees lane-local node ids (the flat agent id is
    ``s·n_per_lane + a``, reduced mod ``n_per_lane`` before the call);
    ``ko`` is the fleet-wide max A out-degree.  ``interpret``/``p_real``
    as in :func:`rfast_wavefront_scan`.
    """
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"impl must be 'jnp' or 'pallas', got {impl!r}")
    mode = dispatch.resolve_mode(interpret) if impl == "pallas" else "emulate"
    grad_fn = as_grad_fn(grad_fn)
    lane_grad = lambda i, x, key: grad_fn(i % n_per_lane, x, key)
    step = partial(_wave_step, grad_fn=lane_grad, gamma=gamma, ko=ko,
                   impl=impl, mode=mode, p_real=p_real)

    def run_waves(state: PackedState, waves: _WaveInputs):
        state, _ = jax.lax.scan(step, state, waves)
        return state

    return jax.jit(run_waves, donate_argnums=(0,) if donate else ())


def _mesh_axis_size(mesh, axis: str | None) -> int:
    if axis is None or axis not in mesh.axis_names:
        return 1
    return int(dict(mesh.shape)[axis])


def _mesh_sweep_scan(
    grad_fn: Objective,
    gamma: float,
    *,
    ko: int,
    n_per_lane: int,
    mesh,
    lane_axis: str = "data",
    param_axis: str | None = "model",
    donate: bool = True,
    impl: str = "jnp",
    interpret: bool | None = None,
    p_real: int | None = None,
):
    """Mesh-mapped fleet engine: :func:`rfast_sweep_scan` distributed over
    a device mesh via the :func:`~repro.core.runtime_sharded._shard_map`
    compat shim.

    Layout (see :func:`~repro.core.runtime_sharded.packed_sweep_specs`):
    the packed state and wave tables carry a leading *lane-group* axis —
    one block of ``S_loc`` consecutive lanes per ``lane_axis`` device —
    and the flat parameter axis is split over ``param_axis``.  Inside the
    region each device runs the unmodified :func:`_wave_step` scan over
    its own group's flattened program, so lane groups never communicate:
    lane parallelism is embarrassingly parallel by construction.

    When ``param_axis`` has size M > 1 every state array holds only its
    ``p_loc = p_pad // M`` slice of the flat axis.  The protocol math is
    linear and elementwise along p, so it runs unchanged on slices; only
    the gradient needs the full iterate, which is reconstructed per wave
    by ONE tiled ``all_gather`` over ``param_axis`` (O(p) per lane — the
    same traffic a data-parallel all-reduce would pay) and the fresh
    gradient is sliced back to the local shard.  ``p_real`` strips the
    block/shard padding around the ``grad_fn`` call exactly as in the
    unsharded engines.

    The shapes reaching :func:`commit_grid` inside the region are the
    LOCAL shard shapes (``S_loc·B`` lanes, width ``p_loc``), so the
    dispatch cache keys on the shard shape automatically and the whole
    mesh still resolves ONE launch signature per wave.  State in/out
    specs are identical and the outer jit donates the state, so donation
    survives the shard_map boundary (XLA aliases shard buffers).
    """
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"impl must be 'jnp' or 'pallas', got {impl!r}")
    if lane_axis not in mesh.axis_names:
        raise ValueError(f"mesh has no lane axis {lane_axis!r} "
                         f"(axes: {mesh.axis_names})")
    mode = dispatch.resolve_mode(interpret) if impl == "pallas" else "emulate"
    grad_fn = as_grad_fn(grad_fn)
    M = _mesh_axis_size(mesh, param_axis)
    axes = ((lane_axis, param_axis) if M > 1 else (lane_axis,))

    if M > 1:
        def lane_grad(i, x_loc, key):
            # one collective per wave: rebuild the full iterate for the
            # gradient, then keep only this device's shard of g.  The
            # zero pad tail sits at the END of the global flat axis, so
            # the tiled gather reconstructs global order directly.
            x_full = jax.lax.all_gather(x_loc, param_axis, axis=0,
                                        tiled=True)
            p_pad = x_full.shape[0]
            if p_real is not None and p_real != p_pad:
                g = grad_fn(i % n_per_lane, x_full[:p_real], key)
                g = jnp.pad(g, (0, p_pad - p_real))
            else:
                g = grad_fn(i % n_per_lane, x_full, key)
            m = jax.lax.axis_index(param_axis)
            p_loc = x_loc.shape[0]
            return jax.lax.dynamic_slice(g, (m * p_loc,), (p_loc,))
        step = partial(_wave_step, grad_fn=lane_grad, gamma=gamma, ko=ko,
                       impl=impl, mode=mode, p_real=None)
    else:
        lane_grad = lambda i, x, key: grad_fn(i % n_per_lane, x, key)
        step = partial(_wave_step, grad_fn=lane_grad, gamma=gamma, ko=ko,
                       impl=impl, mode=mode, p_real=p_real)

    def local_run(state: PackedState, waves: _WaveInputs):
        # strip this device's singleton group axis, scan, put it back
        st = jax.tree.map(lambda a: a[0], state)
        wv = jax.tree.map(lambda a: a[0], waves)
        st, _ = jax.lax.scan(step, st, wv)
        return jax.tree.map(lambda a: a[None], st)

    st_spec, wv_spec = packed_sweep_specs(
        lane_axis, param_axis if M > 1 else None)

    def run_waves(state: PackedState, waves: _WaveInputs):
        st_specs = jax.tree.map(st_spec, state)
        wv_specs = jax.tree.map(wv_spec, waves)
        fn = _shard_map(local_run, mesh, (st_specs, wv_specs), st_specs,
                        axes)
        return fn(state, waves)

    return jax.jit(run_waves, donate_argnums=(0,) if donate else ())


def sweep_mesh_shardings(mesh, lane_axis: str = "data",
                         param_axis: str | None = "model"):
    """``(state_leaf -> NamedSharding, wave_leaf -> NamedSharding)`` for
    placing the group-stacked fleet state / wave tables on ``mesh``
    before entering :func:`_mesh_sweep_scan` (avoids a first-call
    resharding transfer)."""
    from jax.sharding import NamedSharding
    M = _mesh_axis_size(mesh, param_axis)
    st_spec, wv_spec = packed_sweep_specs(
        lane_axis, param_axis if M > 1 else None)
    return (lambda l: NamedSharding(mesh, st_spec(l)),
            lambda l: NamedSharding(mesh, wv_spec(l)))


def tracked_mass(state: RFASTState) -> jnp.ndarray:
    """LHS of the Lemma-3 invariant: Σ_i z_i + Σ_e (ρ_e − ρ̃_e)."""
    return state.z.sum(axis=0) + (state.rho - state.rho_buf).sum(axis=0)


def run_rfast(
    topo: Topology,
    schedule: Schedule,
    grad_fn: Objective,
    x0: jnp.ndarray,
    gamma: float,
    *,
    seed: int = 0,
    eval_every: int = 0,
    eval_fn: Callable[[RFASTState, float], dict] | None = None,
    mode: str = "wavefront",
    impl: str = "jnp",
    interpret: bool | None = None,
    state0: RFASTState | None = None,
    chunk_cb: Callable[[RFASTState, int], None] | None = None,
    verify_plans: bool = False,
) -> tuple[RFASTState, list[dict]]:
    """Run the full schedule; optionally evaluate every ``eval_every`` events.

    ``grad_fn`` may be the raw traced callable or any
    :class:`~repro.core.paramvec.GradProvider` (``LogisticProblem``,
    ``LMProblem``, ...) — the engines are objective-agnostic over the
    flat-parameter substrate.

    ``mode="wavefront"`` (default) runs the batched engine with delta
    histories; ``mode="event"`` the one-event-per-step snapshot engine.
    Both realize identical Algorithm-2 semantics (tested to fp32
    tolerance); final ``v_hist``/``rho_hist`` *contents* differ by
    representation.  ``impl="pallas"`` (wavefront only) commits lanes
    through the fused ``rfast_commit`` kernel.

    Checkpoint/resume: ``chunk_cb(state, k)`` fires after every eval
    chunk with the (unpacked) state at event ``k`` — persist it with
    ``checkpoint.save_checkpoint`` (which copies to host; the live
    buffers are donated to the next chunk).  ``state0`` resumes from
    such a state: ``state0.k`` must sit on an eval-chunk boundary of
    the SAME schedule/seed AND the SAME ``mode`` it was saved from —
    the two engines' ``v_hist``/``rho_hist`` *representations* differ
    (wavefront: per-writer delta rows; event: full snapshots), the
    shapes do not, so a cross-mode resume is not detectable here and
    would silently realize a wrong trajectory.  The first ``state0.k``
    events are skipped (the RNG key derivation is identical to the
    fresh run, so a resumed run continues the exact trajectory).

    ``interpret`` (pallas only) is the tri-state dispatch override:
    None autodetects (compiled grid launch on TPU, jnp emulation of the
    grid elsewhere); True forces the interpreter oracle.  In compiled
    mode the flat parameter axis is transparently block-padded for the
    kernel and stripped again before ``grad_fn``/``eval_fn``/return.

    Both modes donate the running state between chunks (in-place
    updates): ``eval_fn`` must extract what it needs (floats/arrays of
    its own) rather than retain the state object it is handed.

    ``verify_plans=True`` runs the :mod:`repro.analysis.planlint` pass
    over the CommPlan and compiled WavefrontPlan before anything is
    traced, raising :class:`~repro.analysis.PlanInvariantError` on any
    diagnostic — the debug belt-and-braces mode; benches leave it off.
    """
    if mode not in ("wavefront", "event"):
        raise ValueError(f"mode must be 'wavefront' or 'event', got {mode!r}")
    if mode == "event" and impl != "jnp":
        raise ValueError("impl='pallas' requires mode='wavefront' "
                         "(the event engine is the jnp oracle)")
    grad_fn = as_grad_fn(grad_fn)
    plan = as_comm_plan(topo)
    H = int(schedule.D) + 2
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)

    K = schedule.K
    step_keys = jax.random.split(key, K)
    metrics: list[dict] = []
    if eval_every <= 0:
        eval_every = K

    if state0 is None:
        state = init_state(plan, x0, grad_fn, init_key, H)
        k0 = 0
    else:
        if state0.v_hist.shape[0] != H:
            raise ValueError(
                f"state0 has H={state0.v_hist.shape[0]} but this schedule "
                f"needs H={H} — resume only into the same schedule")
        k0 = int(state0.k)
        # k0 == K is a completed run (its K need not be chunk-aligned)
        if k0 < K and k0 % eval_every != 0:
            raise ValueError(f"state0.k={k0} is not an eval-chunk boundary "
                             f"(eval_every={eval_every})")
        # copy: the engines donate their state buffers in place
        state = jax.tree.map(jnp.array, state0)
    if k0 >= K:
        return state, metrics

    if mode == "event":
        if verify_plans:
            from ..analysis import planlint
            planlint.check_or_raise(
                planlint.lint_comm_plan(
                    plan, topo if isinstance(topo, Topology) else None),
                "run_rfast(verify_plans)")
        chunk = rfast_scan(plan, grad_fn, gamma, H, donate=True)
        agent = jnp.asarray(schedule.agent)
        stamp_v = jnp.asarray(schedule.stamp_v)
        stamp_rho = jnp.asarray(schedule.stamp_rho)
        for s in range(k0, K, eval_every):
            e = min(K, s + eval_every)
            state = chunk(state, agent[s:e], stamp_v[s:e], stamp_rho[s:e],
                          step_keys[s:e])
            if eval_fn is not None:
                m = eval_fn(state, float(schedule.times[e - 1]))
                m["k"] = e
                metrics.append(m)
            if chunk_cb is not None:
                chunk_cb(state, e)       # event engine tracks k == e itself
        return state, metrics

    # compiled grid launches need a block-multiple flat width: pad the
    # parameter axis once up front (the zero tail is provably inert) and
    # strip it at every unpack below
    p = int(state.x.shape[-1])
    p_pad = p
    if impl == "pallas" and dispatch.resolve_mode(interpret) == "compiled":
        p_pad = block_pad_width(p)

    wf = build_wavefront_plan(schedule, plan, H, break_every=eval_every)
    if verify_plans:
        from ..analysis import planlint
        planlint.check_or_raise(
            planlint.lint_comm_plan(
                plan, topo if isinstance(topo, Topology) else None)
            + planlint.lint_wavefront_plan(wf, comm=plan,
                                           schedule=schedule, H=H),
            "run_rfast(verify_plans)")
    runner = rfast_wavefront_scan(
        plan, grad_fn, gamma, donate=True, impl=impl, interpret=interpret,
        p_real=(p if p_pad != p else None))
    waves = wave_inputs(wf, step_keys)
    packed = pack_state(state, p_pad=(p_pad if p_pad != p else None))

    # chunk boundaries in wave space (waves never cross eval boundaries);
    # pad every chunk to the max wave count so the runner compiles once
    bounds = [int(np.searchsorted(wf.event_start, s))
              for s in range(0, K, eval_every)] + [wf.n_waves]
    cmax = max(b1 - b0 for b0, b1 in zip(bounds, bounds[1:]))
    n_pad = wf.n
    skip = k0 // eval_every          # chunks already realized in state0

    for ci, (w0, w1) in enumerate(zip(bounds[skip:], bounds[skip + 1:]),
                                  start=skip):
        pad = cmax - (w1 - w0)

        def sl(arr, fill):
            if not pad:
                return arr[w0:w1]
            return jnp.concatenate(
                [arr[w0:w1], jnp.full((pad,) + arr.shape[1:], fill,
                                      arr.dtype)])

        chunk_waves = _WaveInputs(
            agent=sl(waves.agent, n_pad), wslot=sl(waves.wslot, 0),
            w_self=sl(waves.w_self, 0.0), a_self=sl(waves.a_self, 0.0),
            rslot_v=sl(waves.rslot_v, 0), src_v=sl(waves.src_v, 0),
            w_in=sl(waves.w_in, 0.0), rslot_rho=sl(waves.rslot_rho, 0),
            hist_epos=sl(waves.hist_epos, 0), a_val=sl(waves.a_val, 0.0),
            rho_gidx=sl(waves.rho_gidx, 2 * wf.e_a),
            out_wt=sl(waves.out_wt, 0.0), keys=sl(waves.keys, 0))
        packed = runner(packed, chunk_waves)
        e = min(K, (ci + 1) * eval_every)
        if eval_fn is not None:
            m = eval_fn(unpack_state(packed, e, p=p),
                        float(schedule.times[e - 1]))
            m["k"] = e
            metrics.append(m)
        if chunk_cb is not None:
            chunk_cb(unpack_state(packed, e, p=p), e)
    return unpack_state(packed, K, p=p), metrics


# --------------------------------------------------------------------- #
# fleet sweeps: many experiments as one compiled wavefront program
# --------------------------------------------------------------------- #
def _lane_state(packed: PackedState, s: int, k: int, *, S: int, n: int,
                e_a: int, e_a_lane: int,
                p: int | None = None) -> RFASTState:
    """Slice fleet lane ``s`` out of the flat fleet state (lane blocks:
    nodes ``[s·n, (s+1)·n)``, ρ ``[s·e_a, ·)`` with ρ̃ at offset
    ``S·e_a``) and strip its ρ state back to the lane's real A-edge
    count (the fleet layout pads every lane to the max).  ``p`` strips a
    block-padded flat axis back to the real dimension."""
    if p is not None and p != packed.nodes.shape[-1]:
        packed = PackedState(*(a[..., :p] for a in packed))
    nd = packed.nodes[s * n:(s + 1) * n]
    rho = packed.rho2[s * e_a:s * e_a + e_a_lane]
    rho_buf = packed.rho2[(S + s) * e_a:(S + s) * e_a + e_a_lane]
    return RFASTState(
        k=jnp.asarray(k, jnp.int32),
        x=nd[:, 0], v=nd[:, 1], z=nd[:, 2], g_prev=nd[:, 3],
        rho=rho, rho_buf=rho_buf,
        v_hist=packed.v_hist[:, s * n:(s + 1) * n],
        rho_hist=packed.rho_hist[:, s * e_a:s * e_a + e_a_lane],
    )


def run_sweep(
    topos,
    schedules,
    grad_fn: Objective,
    x0: jnp.ndarray,
    gamma: float,
    *,
    seeds=None,
    eval_every: int = 0,
    eval_fn: Callable[[RFASTState, float], dict] | None = None,
    impl: str = "jnp",
    interpret: bool | None = None,
    verify_plans: bool = False,
    mesh=None,
    lane_axis: str = "data",
    param_axis: str | None = "model",
) -> tuple[list[RFASTState], list[list[dict]]]:
    """Run a fleet of S independent experiments as ONE compiled program.

    Each lane is one (topology, schedule, seed) experiment — e.g. a
    :func:`repro.core.scenario.realize_batch` sweep of one scenario over
    many seeds, or a registry sweep across scenarios and topologies.
    Per lane the realized trajectory matches an individual
    :func:`run_rfast` wavefront run of the same (schedule, seed) to fp32
    tolerance; the fleet executes as ONE flattened wavefront program
    (``schedule.flatten_plans``: lanes become index-disjoint blocks of a
    width-S·B wave), so one compile and one ``lax.scan`` serve all S
    experiments and the per-wave math is batched ``(S·B, p)`` instead of
    dispatched S separate times.

    Args:
      topos: one Topology/CommPlan shared by every lane, or a sequence of
        S of them.  All lanes must share the node count ``n`` (the packed
        fleet state is ``(S, n, 4, p)``); topologies may otherwise differ
        — CommPlans are degree-normalized (``plan.pad_comm_plan``) and
        the per-lane WavefrontPlans padded/stacked to fleet maxima, with
        padded waves/lanes provably inert.
      schedules: S realized Schedules sharing ``K`` (each its own trace).
      grad_fn: the shared objective (bare callable or GradProvider);
        gradients are sampled per (lane, event) from the lane's own RNG
        stream, exactly as the individual runs would.
      seeds: per-lane RNG seeds (defaults to 0 for every lane, matching
        ``run_rfast``'s default).
      eval_every / eval_fn: as in :func:`run_rfast`, evaluated per lane —
        the metrics come back as one list per lane, each entry stamped
        with that lane's own virtual time.
      impl: ``"pallas"`` commits every fleet wave — all lanes, all wave
        slots — through ONE fused grid launch.
      interpret: tri-state dispatch override (None = compiled on TPU /
        jnp grid emulation elsewhere; True = interpreter oracle).
      mesh: optional ``jax.sharding.Mesh`` — distribute the fleet via
        :func:`_mesh_sweep_scan`: lanes are split into contiguous groups
        over ``lane_axis`` (the fleet is padded to a multiple of the
        axis size by replicating the last lane; replica results are
        dropped) and the flat parameter axis is sharded over
        ``param_axis`` when that axis has size > 1, so p >= 100M states
        fit in per-device memory.  Per lane the results match the
        unsharded engine to fp32 tolerance (tested).  ``None`` (default)
        keeps the single-device path bit-for-bit unchanged.
      lane_axis / param_axis: mesh axis names (``"data"`` / ``"model"``,
        the :func:`repro.launch.mesh.make_sweep_mesh` convention).

    Returns:
      ``(states, metrics)`` — the final per-lane :class:`RFASTState` list
      (ρ state stripped back to each lane's real A-edge count) and the
      per-lane metrics lists.
    """
    schedules = list(schedules)
    S = len(schedules)
    if S == 0:
        raise ValueError("run_sweep needs at least one lane")
    if isinstance(topos, (Topology, CommPlan)):
        topos = [topos] * S
    plans = [as_comm_plan(t) for t in topos]
    if len(plans) != S:
        raise ValueError(f"{len(plans)} topologies for {S} schedules")
    n = plans[0].n
    if any(pl.n != n for pl in plans):
        raise ValueError("all lanes must share the node count n "
                         f"(got {[pl.n for pl in plans]})")
    K = schedules[0].K
    if any(s.K != K for s in schedules):
        raise ValueError("all lanes must share the event count K "
                         f"(got {[s.K for s in schedules]})")
    if seeds is None:
        seeds = [0] * S
    seeds = [int(s) for s in seeds]
    if len(seeds) != S:
        raise ValueError(f"{len(seeds)} seeds for {S} lanes")
    grad_fn = as_grad_fn(grad_fn)
    if eval_every <= 0:
        eval_every = K

    # mesh-mapped fleet: pad the lane list to a multiple of the lane-axis
    # size by replicating the last lane (replica outputs are dropped), so
    # every device owns one group of S_loc consecutive lanes
    D = M = 1
    if mesh is not None:
        if lane_axis not in mesh.axis_names:
            raise ValueError(f"mesh has no lane axis {lane_axis!r} "
                             f"(axes: {mesh.axis_names})")
        D = _mesh_axis_size(mesh, lane_axis)
        M = _mesh_axis_size(mesh, param_axis)
    S_pad = -(-S // D) * D
    plans = plans + [plans[-1]] * (S_pad - S)
    schedules = schedules + [schedules[-1]] * (S_pad - S)
    seeds = seeds + [seeds[-1]] * (S_pad - S)
    S_loc = S_pad // D

    # fleet-wide shape maxima: history depth, degrees, ρ layout
    H = max(int(s.D) for s in schedules) + 2
    kw = max(pl.kw for pl in plans)
    ka = max(pl.ka for pl in plans)
    ko = max(pl.ko for pl in plans)
    e_a = max(max(1, pl.n_edges_a) for pl in plans)
    padded_plans = [pad_comm_plan(pl, kw=kw, ka=ka, ko=ko) for pl in plans]

    # per-lane RNG streams, derived exactly as run_rfast does
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None, :], (n, 1))
    if x0.ndim == 3 and x0.shape[0] != S:
        raise ValueError(f"per-lane x0 has {x0.shape[0]} lanes, "
                         f"expected {S}")
    x0_lanes = (x0 if x0.ndim == 3
                else jnp.broadcast_to(x0[None], (S,) + x0.shape))
    if S_pad != S:
        x0_lanes = jnp.concatenate(
            [x0_lanes, jnp.broadcast_to(x0_lanes[-1:],
                                        (S_pad - S,) + x0_lanes.shape[1:])])
    p = int(x0_lanes.shape[-1])
    # compiled grid launches need block-multiple widths (inert zero
    # tail); a sharded param axis additionally needs p_pad % M == 0 so
    # every device holds an equal p_loc slice
    p_pad = p
    if impl == "pallas" and dispatch.resolve_mode(interpret) == "compiled":
        p_pad = block_pad_width(p, M)
    elif M > 1:
        p_pad = -(-p // M) * M
    lane_keys, init_keys = [], []
    for s in range(S_pad):
        key, init_key = jax.random.split(jax.random.PRNGKey(seeds[s]))
        lane_keys.append(jax.random.split(key, K))
        init_keys.append(init_key)
    step_keys = jnp.stack(lane_keys)                        # (S_pad, K, 2)

    # fleet init (the paper init per lane: z = g_prev = ∇f(x0; ζ0) from
    # the lane's init key, v = ρ = ρ̃ = hist = 0) — lane s's g0 is
    # op-identical to init_state's, so the trajectories match the
    # per-lane runs.  Deliberately NOT jitted: a jit here would compile
    # the gradient graph a second time (the scan body below already
    # pays for it), doubling the fleet's one-time cost.  Layout: the
    # flat fleet state of flatten_plans (lane blocks on node/edge axes).
    node_keys = jax.vmap(lambda k: jax.random.split(k, n))(
        jnp.stack(init_keys))
    g0 = jax.vmap(
        lambda x, ks: jax.vmap(grad_fn)(jnp.arange(n), x, ks)
    )(x0_lanes, node_keys)
    nodes = jnp.stack([x0_lanes, jnp.zeros_like(x0_lanes), g0, g0],
                      axis=2)
    if p_pad != p:
        nodes = jnp.pad(nodes, ((0, 0), (0, 0), (0, 0), (0, p_pad - p)))
    z = lambda *s_: jnp.zeros(s_, jnp.float32)
    if mesh is None:
        packed = PackedState(nodes=nodes.reshape(S_pad * n, 4, p_pad),
                             rho2=z(2 * S_pad * e_a, p_pad),
                             v_hist=z(H, S_pad * n, p_pad),
                             rho_hist=z(H, S_pad * e_a, p_pad))
    else:
        # group-stacked layout: each device's block is the flat fleet
        # state of ITS OWN S_loc lanes, so per-group plans flatten with
        # group-local offsets and no cross-group indices exist
        packed = PackedState(nodes=nodes.reshape(D, S_loc * n, 4, p_pad),
                             rho2=z(D, 2 * S_loc * e_a, p_pad),
                             v_hist=z(D, H, S_loc * n, p_pad),
                             rho_hist=z(D, H, S_loc * e_a, p_pad))

    # per-lane plans, then chunk-aligned fleet stacking: chunk c of every
    # lane is padded to the fleet-wide max chunk wave count, so chunk c
    # occupies waves [c*cmax, (c+1)*cmax) in EVERY lane and one compiled
    # scan body serves all chunks of all lanes
    wfs = [build_wavefront_plan(schedules[s], padded_plans[s], H,
                                break_every=eval_every, e_a=e_a)
           for s in range(S_pad)]
    chunk_starts = list(range(0, K, eval_every))
    bounds = [[int(np.searchsorted(wf.event_start, c0))
               for c0 in chunk_starts] + [wf.n_waves] for wf in wfs]
    cmax = max(b[c + 1] - b[c]
               for b in bounds for c in range(len(chunk_starts)))
    B = max(wf.width for wf in wfs)
    rechunked = []
    for wf, b in zip(wfs, bounds):
        rechunked.append(concat_plans(
            [pad_plan(slice_plan(wf, b[c], b[c + 1]),
                      width=B, n_waves=cmax, e_a=e_a)
             for c in range(len(chunk_starts))]))
    if verify_plans:
        from ..analysis import planlint
        diags = []
        for s in range(S_pad):
            diags += planlint.lint_comm_plan(
                padded_plans[s], subject=f"lane{s}/comm")
            diags += planlint.lint_wavefront_plan(
                rechunked[s], comm=padded_plans[s],
                schedule=schedules[s], H=H, subject=f"lane{s}")
    if mesh is None:
        stacked = stack_plans(rechunked)
        fleet = flatten_plans(stacked)
        if verify_plans:
            diags += planlint.lint_flatten(stacked, fleet, subject="fleet")
        waves = wave_inputs(fleet, step_keys.reshape(S_pad * K, 2))
        runner = rfast_sweep_scan(
            grad_fn, gamma, ko=ko, n_per_lane=n, donate=True, impl=impl,
            interpret=interpret, p_real=(p if p_pad != p else None))
    else:
        # one flattened program PER lane group, stacked on the leading
        # device axis: every group shares the (cmax, S_loc·B) wave shape,
        # so the shard_map body compiles once for all groups
        group_waves = []
        for g in range(D):
            stacked = stack_plans(rechunked[g * S_loc:(g + 1) * S_loc])
            fleet = flatten_plans(stacked)
            if verify_plans:
                diags += planlint.lint_flatten(stacked, fleet,
                                               subject=f"fleet/g{g}")
            group_waves.append(wave_inputs(
                fleet,
                step_keys[g * S_loc:(g + 1) * S_loc].reshape(S_loc * K,
                                                             2)))
        waves = jax.tree.map(lambda *a: jnp.stack(a), *group_waves)
        runner = _mesh_sweep_scan(
            grad_fn, gamma, ko=ko, n_per_lane=n, mesh=mesh,
            lane_axis=lane_axis, param_axis=param_axis, donate=True,
            impl=impl, interpret=interpret,
            p_real=(p if p_pad != p else None))
        st_sh, wv_sh = sweep_mesh_shardings(mesh, lane_axis, param_axis)
        packed = jax.device_put(packed, jax.tree.map(st_sh, packed))
        waves = jax.device_put(waves, jax.tree.map(wv_sh, waves))
    if verify_plans:
        planlint.check_or_raise(diags, "run_sweep(verify_plans)")

    def lane_state(pk, s, k):
        if mesh is None:
            return _lane_state(pk, s, k, S=S_pad, n=n, e_a=e_a,
                               e_a_lane=e_a_lane[s], p=p)
        g, j = divmod(s, S_loc)
        grp = jax.tree.map(lambda a: a[g], pk)
        return _lane_state(grp, j, k, S=S_loc, n=n, e_a=e_a,
                           e_a_lane=e_a_lane[s], p=p)

    metrics: list[list[dict]] = [[] for _ in range(S)]
    e_a_lane = [max(1, pl.n_edges_a) for pl in plans]
    for ci in range(len(chunk_starts)):
        sl = (lambda a: a[:, ci * cmax:(ci + 1) * cmax]) if mesh is not \
            None else (lambda a: a[ci * cmax:(ci + 1) * cmax])
        packed = runner(packed, jax.tree.map(sl, waves))
        e = min(K, (ci + 1) * eval_every)
        if eval_fn is not None:
            for s in range(S):
                m = eval_fn(lane_state(packed, s, e),
                            float(schedules[s].times[e - 1]))
                m["k"] = e
                metrics[s].append(m)
    states = [lane_state(packed, s, K) for s in range(S)]
    return states, metrics


# --------------------------------------------------------------------- #
# epochized runs: dynamic membership / time-varying topologies
# --------------------------------------------------------------------- #
def migrate_state(state: RFASTState, prev_topo, epoch, *,
                  H: int) -> RFASTState:
    """Carry an :class:`RFASTState` across a membership-epoch boundary.

    The migration preserves the Lemma-3 invariant exactly, by
    construction (DESIGN.md §11):

    1. **Settle in-flight mass.**  Every A-edge's undelivered running-sum
       difference ρ_e − ρ̃_e is added to its receiver's z (an instant
       final delivery), then ρ/ρ̃ and both history rings reset to zero —
       the new epoch's edge set need not match the old one, and a reset
       ring read (slot 0) now correctly means "nothing pushed yet".
    2. **Re-absorb departures.**  A departed node's tracked surplus
       ``z_d − g_prev_d`` moves to the new epoch's root and its z/g_prev
       zero out, so the surviving sum Σz − Σg_prev stays 0: tracking
       remains *conservative* — the fleet average still estimates the
       average gradient of the surviving members.
    3. **Adopt joiners.**  A joining node copies the donor's current
       iterate into x and v (the donor is the new root, or the first
       carried-over member when the root itself is the one joining) with
       ``z = g_prev = 0`` — a zero net contribution until its first own
       activation samples a real gradient.
    4. **v continuity.**  The new epoch's ``v_hist[0]`` is seeded with
       the carried v: slot 0 is the engines' "no write yet" read, so
       neighbours pulling a node that has not yet re-activated read its
       last published value instead of zero (no re-init transient).

    ``prev_topo`` identifies the A-edge layout the state's ρ rows belong
    to (fleet-padded tails are inert zeros).  The returned state has the
    NEW epoch's ρ layout and ``H``-deep rings, ``k = 0`` (epoch-local;
    callers track the global event count).
    """
    prev_plan = as_comm_plan(prev_topo)
    new_plan = as_comm_plan(epoch.topology)
    n, p = state.x.shape
    e_prev = max(1, prev_plan.n_edges_a)

    # (1) settle ρ − ρ̃ at each receiver
    z = state.z
    if prev_plan.n_edges_a:
        inflight = state.rho[:e_prev] - state.rho_buf[:e_prev]
        z = z.at[jnp.asarray(prev_plan.dst_a[:e_prev])].add(inflight)

    # (2) departures: move the tracked surplus to the new root
    dep = jnp.asarray(epoch.departed)
    root = int(epoch.root)
    d_mass = jnp.sum(jnp.where(dep[:, None], z - state.g_prev, 0.0),
                     axis=0)
    z = jnp.where(dep[:, None], 0.0, z).at[root].add(d_mass)
    g_prev = jnp.where(dep[:, None], 0.0, state.g_prev)

    # (3) joiners adopt a surviving donor's iterate, zero tracking
    joined_np = np.asarray(epoch.joined)
    if joined_np.any():
        carried = epoch.topology.active_mask() & ~joined_np
        if not carried.any():
            raise ValueError("epoch has no carried-over member to "
                             "donate an iterate to its joiners")
        donor = root if not joined_np[root] else int(
            np.nonzero(carried)[0][0])
        joined = jnp.asarray(joined_np)
        x = jnp.where(joined[:, None], state.x[donor], state.x)
        v = jnp.where(joined[:, None], state.x[donor], state.v)
        z = jnp.where(joined[:, None], 0.0, z)
        g_prev = jnp.where(joined[:, None], 0.0, g_prev)
    else:
        x, v = state.x, state.v

    # (4) fresh rings in the new epoch's layout; slot 0 carries v
    e_a = max(1, new_plan.n_edges_a)
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    return RFASTState(
        k=jnp.zeros((), jnp.int32), x=x, v=v, z=z, g_prev=g_prev,
        rho=zf(e_a, p), rho_buf=zf(e_a, p),
        v_hist=zf(H, n, p).at[0].set(v), rho_hist=zf(H, e_a, p))


def _epoch_lane_plans(epochs, eval_every: int, *, H: int, kw: int,
                      ka: int, ko: int, e_a: int):
    """Per-epoch padded CommPlans, WavefrontPlans (built against the
    shared shape maxima) and chunk wave bounds for one epochized lane."""
    plans = [as_comm_plan(ep.topology) for ep in epochs]
    padded = [pad_comm_plan(pl, kw=kw, ka=ka, ko=ko) for pl in plans]
    wfs = [build_wavefront_plan(ep.trace.schedule, padded[i], H,
                                break_every=eval_every, e_a=e_a)
           for i, ep in enumerate(epochs)]
    bounds = []
    for ep, wf in zip(epochs, wfs):
        starts = list(range(0, ep.K, eval_every))
        bounds.append([int(np.searchsorted(wf.event_start, s))
                       for s in starts] + [wf.n_waves])
    return plans, padded, wfs, bounds


def _scan_epochs(epochs, plans, wfs, bounds, runner, step_keys, state0,
                 *, B: int, cmax: int, e_a: int, H: int, p: int,
                 p_pad: int, eval_every: int, eval_fn, chunk_cb):
    """Drive one epochized lane through the shared jitted runner: scan
    each epoch's chunks (padded to the shared ``(cmax, B)`` wave shape),
    migrating the packed state at every epoch boundary."""
    metrics: list[dict] = []
    packed = pack_state(state0, e_a=e_a,
                        p_pad=(p_pad if p_pad != p else None))
    for i, (ep, wf, b) in enumerate(zip(epochs, wfs, bounds)):
        if i > 0:
            state = unpack_state(packed, ep.k0, p=p)
            state = migrate_state(state, epochs[i - 1].topology, ep, H=H)
            packed = pack_state(state, e_a=e_a,
                                p_pad=(p_pad if p_pad != p else None))
        rc = concat_plans(
            [pad_plan(slice_plan(wf, b[c], b[c + 1]),
                      width=B, n_waves=cmax, e_a=e_a)
             for c in range(len(b) - 1)])
        waves = wave_inputs(rc, step_keys[ep.k0:ep.k0 + ep.K])
        sched = ep.trace.schedule
        for ci in range(len(b) - 1):
            w = jax.tree.map(lambda a: a[ci * cmax:(ci + 1) * cmax],
                             waves)
            packed = runner(packed, w)
            e_loc = min(ep.K, (ci + 1) * eval_every)
            kg = ep.k0 + e_loc
            if eval_fn is not None:
                m = eval_fn(unpack_state(packed, kg, p=p),
                            ep.t0 + float(sched.times[e_loc - 1]))
                m["k"] = kg
                metrics.append(m)
            if chunk_cb is not None:
                chunk_cb(unpack_state(packed, kg, p=p), kg)
    K = epochs[-1].k0 + epochs[-1].K
    final = unpack_state(packed, K, p=p)
    # strip the fleet ρ padding back to the final epoch's real layout
    e_fin = max(1, plans[-1].n_edges_a)
    if e_fin != e_a:
        final = final._replace(rho=final.rho[:e_fin],
                               rho_buf=final.rho_buf[:e_fin],
                               rho_hist=final.rho_hist[:, :e_fin])
    return final, metrics


def run_epochs(
    epoch_trace,
    grad_fn: Objective,
    x0: jnp.ndarray,
    gamma: float,
    *,
    seed: int = 0,
    eval_every: int = 0,
    eval_fn: Callable[[RFASTState, float], dict] | None = None,
    impl: str = "jnp",
    interpret: bool | None = None,
    chunk_cb: Callable[[RFASTState, int], None] | None = None,
    verify_plans: bool = False,
) -> tuple[RFASTState, list[dict]]:
    """Run an epochized trace (:meth:`NetworkScenario.realize_epochs`)
    through the wavefront engine: one compiled scan body for ALL epochs.

    Every epoch's CommPlan is degree-normalized (``pad_comm_plan``) and
    its WavefrontPlan padded (``pad_plan``) to the trace-wide maxima —
    history depth H, in/out degrees, ρ layout ``e_a``, wave width B and
    chunk wave count — so epoch transitions change *data*, never
    compiled shapes: the jitted runner compiles once and (under
    ``impl="pallas"``) the ``commit_grid`` dispatch cache stays at one
    entry per shape across the whole run.  At each boundary the packed
    state is migrated by :func:`migrate_state` (mass settled, departures
    re-absorbed at the new root, joiners adopted, v carried through ring
    slot 0).

    RNG: one global per-event key stream derived exactly as
    :func:`run_rfast` does (``PRNGKey(seed)``), sliced per epoch at
    ``k0`` — a single-epoch (static) trace therefore reproduces
    :func:`run_rfast` on the same realized schedule.  ``eval_every``
    counts *global* events; evaluation additionally lands on every epoch
    boundary (partial final chunks), each metrics entry stamped with the
    global event count ``k`` and global virtual time ``t0 + t_local``.
    """
    epochs = list(epoch_trace.epochs)
    if not epochs:
        raise ValueError("epoch trace has no epochs")
    grad_fn = as_grad_fn(grad_fn)
    K = int(epoch_trace.K)
    if eval_every <= 0:
        eval_every = K

    H = max(int(ep.trace.schedule.D) for ep in epochs) + 2
    raw_plans = [as_comm_plan(ep.topology) for ep in epochs]
    kw = max(pl.kw for pl in raw_plans)
    ka = max(pl.ka for pl in raw_plans)
    ko = max(pl.ko for pl in raw_plans)
    e_a = max(max(1, pl.n_edges_a) for pl in raw_plans)
    plans, padded, wfs, bounds = _epoch_lane_plans(
        epochs, eval_every, H=H, kw=kw, ka=ka, ko=ko, e_a=e_a)
    if verify_plans:
        from ..analysis import planlint
        diags = planlint.lint_epoch_trace(epoch_trace)
        for i, ep in enumerate(epochs):
            diags += planlint.lint_comm_plan(padded[i],
                                             subject=f"ep{i}/comm")
            diags += planlint.lint_wavefront_plan(
                wfs[i], comm=padded[i], schedule=ep.trace.schedule,
                H=H, subject=f"ep{i}")
        planlint.check_or_raise(diags, "run_epochs(verify_plans)")
    B = max(wf.width for wf in wfs)
    cmax = max(b[c + 1] - b[c] for b in bounds for c in range(len(b) - 1))

    key, init_key = jax.random.split(jax.random.PRNGKey(seed))
    step_keys = jax.random.split(key, K)
    state0 = init_state(plans[0], x0, grad_fn, init_key, H)
    p = int(state0.x.shape[-1])
    p_pad = p
    if impl == "pallas" and dispatch.resolve_mode(interpret) == "compiled":
        p_pad = block_pad_width(p)
    runner = rfast_wavefront_scan(
        padded[0], grad_fn, gamma, donate=True, impl=impl,
        interpret=interpret, p_real=(p if p_pad != p else None))
    return _scan_epochs(epochs, plans, wfs, bounds, runner, step_keys,
                        state0, B=B, cmax=cmax, e_a=e_a, H=H, p=p,
                        p_pad=p_pad, eval_every=eval_every,
                        eval_fn=eval_fn, chunk_cb=chunk_cb)


def run_sweep_epochs(
    epoch_traces,
    grad_fn: Objective,
    x0: jnp.ndarray,
    gamma: float,
    *,
    seeds=None,
    eval_every: int = 0,
    eval_fn: Callable[[RFASTState, float], dict] | None = None,
    impl: str = "jnp",
    interpret: bool | None = None,
    verify_plans: bool = False,
    mesh=None,
    lane_axis: str = "data",
    param_axis: str | None = "model",
) -> tuple[list[RFASTState], list[list[dict]]]:
    """Fleet of epochized lanes (e.g. one scenario × many seeds from
    :func:`repro.core.scenario.realize_epochs_batch`) through ONE shared
    compiled scan body.

    Unlike :func:`run_sweep`, lanes are not flattened into a single wave
    program: membership timelines are lane-local (regional-failure draws
    and epoch cuts differ per seed), so lanes execute sequentially — but
    every epoch of every lane is padded to the fleet-wide shape maxima,
    so one jitted runner serves all lanes and all epochs (one compile,
    one ``commit_grid`` dispatch-cache entry per shape).  Per lane the
    result equals :func:`run_epochs` of that (trace, seed) — same key
    streams, same migrations.

    ``mesh`` shards the flat PARAMETER axis over ``param_axis`` via
    :func:`_mesh_sweep_scan` (large-p epochized runs); the lane axis of
    the mesh must have size 1 — lanes stay sequential here because their
    membership timelines (epoch cuts, migrations) are host-driven and
    lane-local.  Use :func:`run_sweep` for lane-parallel meshes.
    """
    traces = list(epoch_traces)
    S = len(traces)
    if S == 0:
        raise ValueError("run_sweep_epochs needs at least one lane")
    if seeds is None:
        seeds = [0] * S
    seeds = [int(s) for s in seeds]
    if len(seeds) != S:
        raise ValueError(f"{len(seeds)} seeds for {S} lanes")
    n = traces[0].n
    if any(t.n != n for t in traces):
        raise ValueError("all lanes must share the node count n")
    grad_fn = as_grad_fn(grad_fn)
    K = max(int(t.K) for t in traces)
    if eval_every <= 0:
        eval_every = K

    all_eps = [ep for t in traces for ep in t.epochs]
    H = max(int(ep.trace.schedule.D) for ep in all_eps) + 2
    raw = [as_comm_plan(ep.topology) for ep in all_eps]
    kw = max(pl.kw for pl in raw)
    ka = max(pl.ka for pl in raw)
    ko = max(pl.ko for pl in raw)
    e_a = max(max(1, pl.n_edges_a) for pl in raw)

    lanes = [_epoch_lane_plans(list(t.epochs), eval_every, H=H, kw=kw,
                               ka=ka, ko=ko, e_a=e_a) for t in traces]
    if verify_plans:
        from ..analysis import planlint
        diags = []
        for s, (trace, (_pl, padded_s, wfs_s, _b)) in enumerate(
                zip(traces, lanes)):
            diags += planlint.lint_epoch_trace(trace,
                                               subject=f"lane{s}")
            for i, ep in enumerate(trace.epochs):
                diags += planlint.lint_wavefront_plan(
                    wfs_s[i], comm=padded_s[i],
                    schedule=ep.trace.schedule, H=H,
                    subject=f"lane{s}/ep{i}")
        planlint.check_or_raise(diags, "run_sweep_epochs(verify_plans)")
    B = max(wf.width for (_pl, _pd, wfs, _b) in lanes for wf in wfs)
    cmax = max(b[c + 1] - b[c] for (_pl, _pd, _w, bs) in lanes
               for b in bs for c in range(len(b) - 1))

    x0 = jnp.asarray(x0, jnp.float32)
    x0_lanes = (x0 if x0.ndim == 3
                else jnp.broadcast_to(
                    x0[None] if x0.ndim == 2
                    else jnp.tile(x0[None, None, :], (1, n, 1)),
                    (S, n, x0.shape[-1])))
    p = int(x0_lanes.shape[-1])
    M = 1
    if mesh is not None:
        if _mesh_axis_size(mesh, lane_axis) != 1:
            raise ValueError(
                "run_sweep_epochs shards the parameter axis only; the "
                f"mesh's {lane_axis!r} axis must have size 1 "
                "(lane-parallel meshes go through run_sweep)")
        M = _mesh_axis_size(mesh, param_axis)
    p_pad = p
    if impl == "pallas" and dispatch.resolve_mode(interpret) == "compiled":
        p_pad = block_pad_width(p, M)
    elif M > 1:
        p_pad = -(-p // M) * M
    if mesh is None:
        runner = rfast_wavefront_scan(
            lanes[0][1][0], grad_fn, gamma, donate=True, impl=impl,
            interpret=interpret, p_real=(p if p_pad != p else None))
    else:
        ko_fleet = lanes[0][1][0].ko
        base = _mesh_sweep_scan(
            grad_fn, gamma, ko=ko_fleet, n_per_lane=n, mesh=mesh,
            lane_axis=lane_axis, param_axis=param_axis, donate=True,
            impl=impl, interpret=interpret,
            p_real=(p if p_pad != p else None))
        st_sh, wv_sh = sweep_mesh_shardings(mesh, lane_axis, param_axis)

        def runner(packed, w):
            # _scan_epochs drives the unsharded packed layout; bridge it
            # through the mesh engine's singleton group axis (one extra
            # device_put/copy per chunk, amortized by the wave scan)
            pk = jax.tree.map(lambda a: a[None], packed)
            wv = jax.tree.map(lambda a: a[None], w)
            pk = jax.device_put(pk, jax.tree.map(st_sh, pk))
            wv = jax.device_put(wv, jax.tree.map(wv_sh, wv))
            pk = base(pk, wv)
            return jax.tree.map(lambda a: a[0], pk)

    states: list[RFASTState] = []
    metrics: list[list[dict]] = []
    for s, (trace, (plans, _padded, wfs, bounds)) in enumerate(
            zip(traces, lanes)):
        key, init_key = jax.random.split(jax.random.PRNGKey(seeds[s]))
        step_keys = jax.random.split(key, int(trace.K))
        state0 = init_state(plans[0], x0_lanes[s], grad_fn, init_key, H)
        lane_eval = (None if eval_fn is None
                     else lambda st, t: dict(eval_fn(st, t)))
        st, ms = _scan_epochs(list(trace.epochs), plans, wfs, bounds,
                              runner, step_keys, state0, B=B, cmax=cmax,
                              e_a=e_a, H=H, p=p, p_pad=p_pad,
                              eval_every=eval_every, eval_fn=lane_eval,
                              chunk_cb=None)
        states.append(st)
        metrics.append(ms)
    return states, metrics
