"""Asynchronous event-schedule generation for the global-view simulator.

The global view (Algorithm 2) consumes, at every global iteration ``k``:

* ``agent[k]``      — the node that wakes up (``i^k``),
* ``stamp_v[k, e]`` — for every W-edge ``e=(j→i)``, the *global stamp* of the
  ``v_j`` payload available to the receiver (``k - d_{v,j}^k`` in the paper),
* ``stamp_rho[k, e]`` — ditto for ρ payloads on A-edges.

Stamps are produced by an explicit network simulation with virtual clocks:
every node has a compute-time distribution (stragglers = slower clocks),
every edge has a latency distribution and a Bernoulli loss probability.
Packets carry the sender's post-update stamp; the receiver always consumes
the *largest stamp delivered so far* (the paper's ``τ`` semantics), which
makes per-edge stamps monotone.  A hard bound ``D_max`` enforces
Assumption 3(ii): if loss/latency would push staleness beyond ``D_max``
iterations, delivery is forced (the paper's model also excludes infinitely
persistent loss).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import Topology

__all__ = ["Schedule", "generate_schedule", "round_robin_schedule"]


@dataclasses.dataclass
class Schedule:
    """Realized asynchronous schedule over K global iterations."""

    agent: np.ndarray       # (K,) int32
    stamp_v: np.ndarray     # (K, E_W) int32, payload stamp per W-edge
    stamp_rho: np.ndarray   # (K, E_A) int32, payload stamp per A-edge
    times: np.ndarray       # (K,) float64 — virtual completion time of event k
    D: int                  # realized max delay bound (for history sizing)
    T: int                  # realized activation-gap bound

    @property
    def K(self) -> int:
        return int(self.agent.shape[0])

    def local_counters(self, n: int) -> np.ndarray:
        """t_i^k for bookkeeping: number of updates of each node up to k."""
        counts = np.zeros((self.K, n), dtype=np.int64)
        c = np.zeros(n, dtype=np.int64)
        for k, a in enumerate(self.agent):
            c[a] += 1
            counts[k] = c
        return counts


def _realized_T(agent: np.ndarray, n: int) -> int:
    """Smallest T such that every window of T events touches every node."""
    last_seen = -np.ones(n, dtype=np.int64)
    gap = 0
    for k, a in enumerate(agent):
        last_seen[a] = k
        if np.all(last_seen >= 0):
            gap = max(gap, k - int(last_seen.min()))
    return int(gap + 1)


def generate_schedule(
    topo: Topology,
    K: int,
    *,
    compute_time: np.ndarray | list[float] | None = None,
    jitter: float = 0.2,
    latency: float = 0.1,
    loss_prob: float = 0.0,
    D_max: int | None = None,
    seed: int = 0,
    failures: list[tuple[int, float, float]] | None = None,
) -> Schedule:
    """Simulate virtual clocks + network to produce a Schedule.

    Args:
      compute_time: per-node mean compute time (straggler = large value);
        defaults to all-ones.
      jitter: multiplicative uniform jitter on each compute interval.
      latency: mean network latency per packet, in compute-time units.
      loss_prob: per-packet Bernoulli loss probability.
      D_max: hard staleness bound (Assumption 3ii); defaults to 4 * n + 16.
      failures: (node, t_start, t_end) downtime windows — the node does
        not wake up inside the window (crash + recovery).  Bounded
        downtime keeps Assumption 3 satisfied with a larger realized T;
        the ρ running sums deliver the accumulated mass on recovery.
    """
    rng = np.random.default_rng(seed)
    n = topo.n
    if compute_time is None:
        compute_time = np.ones(n)
    compute_time = np.asarray(compute_time, dtype=np.float64)
    if D_max is None:
        D_max = 4 * n + 16

    edges_w = topo.edges_W()
    edges_a = topo.edges_A()
    out_w = {i: [] for i in range(n)}
    out_a = {i: [] for i in range(n)}
    in_w = {i: [] for i in range(n)}
    in_a = {i: [] for i in range(n)}
    for e, (j, i) in enumerate(edges_w):
        out_w[j].append(e)
        in_w[i].append(e)
    for e, (j, i) in enumerate(edges_a):
        out_a[j].append(e)
        in_a[i].append(e)

    # per-edge arrival queues: list of (arrival_time, stamp); consumed in
    # stamp order (non-FIFO arrival is allowed — we take max stamp arrived).
    arrivals_w: list[list[tuple[float, int]]] = [[] for _ in edges_w]
    arrivals_a: list[list[tuple[float, int]]] = [[] for _ in edges_a]
    best_w = np.zeros(len(edges_w), dtype=np.int64)   # largest stamp delivered
    best_a = np.zeros(len(edges_a), dtype=np.int64)

    clocks = rng.uniform(0.0, 1.0, n) * compute_time
    # crash windows: push the node's next wake-up past the recovery time
    for (fn_, t0_, t1_) in (failures or []):
        if clocks[fn_] >= t0_:
            clocks[fn_] = max(clocks[fn_], t1_)
    agent = np.zeros(K, dtype=np.int32)
    stamp_v = np.zeros((K, max(1, len(edges_w))), dtype=np.int32)
    stamp_rho = np.zeros((K, max(1, len(edges_a))), dtype=np.int32)
    times = np.zeros(K, dtype=np.float64)
    max_delay = 0

    for k in range(K):
        a = int(np.argmin(clocks))
        now = float(clocks[a])
        agent[k] = a
        times[k] = now

        # -- consume: advance best stamp per in-edge from arrived packets --
        for e in in_w[a]:
            q = arrivals_w[e]
            keep = []
            for (t_arr, s) in q:
                if t_arr <= now:
                    if s > best_w[e]:
                        best_w[e] = s
                else:
                    keep.append((t_arr, s))
            arrivals_w[e][:] = keep
            # Assumption 3(ii) hard bound
            if k - best_w[e] > D_max:
                best_w[e] = k - D_max
        for e in in_a[a]:
            q = arrivals_a[e]
            keep = []
            for (t_arr, s) in q:
                if t_arr <= now:
                    if s > best_a[e]:
                        best_a[e] = s
                else:
                    keep.append((t_arr, s))
            arrivals_a[e][:] = keep
            if k - best_a[e] > D_max:
                best_a[e] = k - D_max

        stamp_v[k] = best_w if len(edges_w) else 0
        stamp_rho[k] = best_a if len(edges_a) else 0
        for e in in_w[a]:
            max_delay = max(max_delay, k - int(best_w[e]))
        for e in in_a[a]:
            max_delay = max(max_delay, k - int(best_a[e]))

        # -- send: node a finishes local iteration k, emits stamp k+1 ------
        for e in out_w[a] + []:
            if rng.uniform() >= loss_prob:
                arrivals_w[e].append((now + rng.exponential(latency), k + 1))
        for e in out_a[a]:
            if rng.uniform() >= loss_prob:
                arrivals_a[e].append((now + rng.exponential(latency), k + 1))

        clocks[a] = now + compute_time[a] * (1.0 + rng.uniform(-jitter, jitter))
        for (fn_, t0_, t1_) in (failures or []):
            if fn_ == a and t0_ <= clocks[a] < t1_:
                clocks[a] = t1_     # crash: sleep through the window

    return Schedule(
        agent=agent,
        stamp_v=stamp_v,
        stamp_rho=stamp_rho,
        times=times,
        D=int(max(1, max_delay)),
        T=_realized_T(agent, n),
    )


def round_robin_schedule(topo: Topology, n_rounds: int) -> Schedule:
    """Remark 2: the synchronous counterpart as a global-view schedule.

    ``i^k = k mod n``; at its local iteration ``t`` (global ``k = t·n + i``)
    node ``i`` consumes neighbour ``j``'s payload with local stamp ``t``,
    i.e. global stamp ``(t-1)·n + j + 1`` (0 for t = 0).  Realized delay is
    ``n + i - j - 1 ≤ 2n - 2`` exactly as the paper computes.
    """
    n = topo.n
    K = n_rounds * n
    edges_w = topo.edges_W()
    edges_a = topo.edges_A()
    agent = np.arange(K, dtype=np.int32) % n
    stamp_v = np.zeros((K, max(1, len(edges_w))), dtype=np.int32)
    stamp_rho = np.zeros((K, max(1, len(edges_a))), dtype=np.int32)
    for k in range(K):
        t = k // n
        for e, (j, _i) in enumerate(edges_w):
            stamp_v[k, e] = 0 if t == 0 else (t - 1) * n + j + 1
        for e, (j, _i) in enumerate(edges_a):
            stamp_rho[k, e] = 0 if t == 0 else (t - 1) * n + j + 1
    return Schedule(
        agent=agent,
        stamp_v=stamp_v,
        stamp_rho=stamp_rho,
        times=np.arange(K, dtype=np.float64) / n,
        D=max(1, 2 * n - 2),
        T=n,
    )
