"""Asynchronous event-schedule generation for the global-view simulator.

The global view (Algorithm 2) consumes, at every global iteration ``k``:

* ``agent[k]``      — the node that wakes up (``i^k``),
* ``stamp_v[k, e]`` — for every W-edge ``e=(j→i)``, the *global stamp* of the
  ``v_j`` payload available to the receiver (``k - d_{v,j}^k`` in the paper),
* ``stamp_rho[k, e]`` — ditto for ρ payloads on A-edges.

Stamps are produced by the repo-wide virtual-time engine
(:mod:`repro.core.scenario`): every node has a compute-time profile
(stragglers = slower clocks, possibly time-varying), every edge a latency
distribution and a loss channel (Bernoulli or bursty Gilbert-Elliott).
Packets carry the sender's post-update stamp; the receiver always consumes
the *largest stamp delivered so far* (the paper's ``τ`` semantics), which
makes per-edge stamps monotone.  A hard bound ``D_max`` enforces
Assumption 3(ii): if loss/latency would push staleness beyond ``D_max``
iterations, delivery is forced (the paper's model also excludes infinitely
persistent loss).  :func:`generate_schedule` here is the compatibility
shim over that engine; the baselines consume the same engine directly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import Topology

__all__ = ["Schedule", "WavefrontPlan", "build_wavefront_plan",
           "pad_plan", "stack_plans", "slice_plan", "concat_plans",
           "flatten_plans", "grid_gather_tables", "generate_schedule",
           "round_robin_schedule"]


@dataclasses.dataclass
class Schedule:
    """Realized asynchronous schedule over K global iterations."""

    agent: np.ndarray       # (K,) int32
    stamp_v: np.ndarray     # (K, E_W) int32, payload stamp per W-edge
    stamp_rho: np.ndarray   # (K, E_A) int32, payload stamp per A-edge
    times: np.ndarray       # (K,) float64 — virtual completion time of event k
    D: int                  # realized max delay bound (for history sizing)
    T: int                  # realized activation-gap bound

    @property
    def K(self) -> int:
        return int(self.agent.shape[0])

    def local_counters(self, n: int) -> np.ndarray:
        """t_i^k for bookkeeping: number of updates of each node up to k."""
        counts = np.zeros((self.K, n), dtype=np.int64)
        c = np.zeros(n, dtype=np.int64)
        for k, a in enumerate(self.agent):
            c[a] += 1
            counts[k] = c
        return counts


def _realized_T(agent: np.ndarray, n: int) -> int:
    """Smallest T such that every window of T events touches every node."""
    last_seen = -np.ones(n, dtype=np.int64)
    gap = 0
    for k, a in enumerate(agent):
        last_seen[a] = k
        if np.all(last_seen >= 0):
            gap = max(gap, k - int(last_seen.min()))
    return int(gap + 1)


def generate_schedule(
    topo: Topology,
    K: int,
    *,
    scenario=None,
    compute_time: np.ndarray | list[float] | None = None,
    jitter: float = 0.2,
    latency: float = 0.1,
    loss_prob: float = 0.0,
    D_max: int | None = None,
    seed: int = 0,
    failures: list[tuple[int, float, float]] | None = None,
) -> Schedule:
    """Realize an asynchronous Schedule under a network scenario.

    The event clock itself lives in
    :meth:`repro.core.scenario.NetworkScenario.realize` — the single
    source of virtual time shared with every baseline.  This wrapper is
    a thin compatibility shim: the historical kwargs build an equivalent
    :class:`~repro.core.scenario.NetworkScenario`, and the RNG draw
    order is bit-identical to the pre-refactor implementation (pinned by
    the golden test in ``tests/test_scenario.py``).

    Args:
      scenario: a :class:`~repro.core.scenario.NetworkScenario`; when
        given, all other model kwargs must stay at their defaults.
      compute_time: per-node mean compute time (straggler = large value);
        defaults to all-ones.
      jitter: multiplicative uniform jitter on each compute interval.
      latency: mean network latency per packet, in compute-time units.
      loss_prob: per-packet Bernoulli loss probability.
      D_max: hard staleness bound (Assumption 3ii); defaults to 4 * n + 16.
      failures: (node, t_start, t_end) downtime windows — the node does
        not wake up inside the window (crash + recovery).  Bounded
        downtime keeps Assumption 3 satisfied with a larger realized T;
        the ρ running sums deliver the accumulated mass on recovery.
    """
    from .scenario import NetworkScenario   # import here: scenario.py
    # imports Schedule from this module
    if scenario is None:
        scenario = NetworkScenario(
            compute_time=(1.0 if compute_time is None
                          else tuple(np.asarray(compute_time, np.float64))),
            jitter=jitter,
            latency=latency,
            loss=loss_prob,
            failures=tuple(failures or ()),
            D_max=D_max,
        )
    elif (compute_time is not None or failures is not None
          or (jitter, latency, loss_prob, D_max) != (0.2, 0.1, 0.0, None)):
        raise ValueError("pass either scenario= or the legacy kwargs, "
                         "not both")
    return scenario.realize(topo, K, seed=seed).schedule


# --------------------------------------------------------------------- #
# wavefront batching: host-side compilation of a Schedule into vmappable
# groups of events with pre-resolved delta-history reads
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class WavefrontPlan:
    """A Schedule compiled for the wavefront-batched simulator.

    Consecutive events are grouped into *wavefronts*: runs of DISTINCT
    agents whose payload stamps all predate the wavefront start, so every
    event in the group reads only pre-wavefront state and writes rows no
    other group member touches — the per-agent S.1–S.5 update can then be
    vmapped across the group inside one ``lax.scan`` step.

    Histories are stored as *deltas*: ``v_hist[c_j mod H, j]`` holds node
    ``j``'s v after its ``c_j``-th own update (row commit, O(p) per event
    instead of an O(n·p) full snapshot), and ``rho_hist[c_e mod H, e]``
    edge ``e``'s running sum after its sender's ``c_e``-th update.  Stale
    reads are resolved HERE, host-side: ``rslot_*`` hold, per event and
    per in-edge slot of the active agent, the history ring slot of the
    sender's last write with emitted stamp ≤ the payload stamp.  Validity
    needs the same ``H ≥ D+2`` bound as the snapshot engine: between a
    payload's write and its latest read (≤ D events later) the writer
    commits at most D+1 more rows, so the ring slot is never reused early.

    Every per-event table the device step needs is pre-gathered here by
    lane (the active agent's neighbour rows of the CommPlan), so the scan
    body touches no plan-indexed gathers — only the four state arrays.
    ρ and ρ̃ live in one ``(2·e_a, p)`` array on the device (ρ̃ rows at
    offset ``e_a``); ``rho_gidx``/``rho_tgt`` index that flat layout, and
    invalid/padded entries carry the sentinel ``2·e_a`` which drop-mode
    scatters discard (``e_a`` defaults to the plan's real A-edge count
    but may be padded up for fleet stacking).  Lane padding uses sentinel
    agent ``n`` (reads clamp, commits drop); ``kidx`` maps lanes to event
    indices (sentinel ``K``) for per-event RNG keys.

    Every per-wave array is *fixed-shape and stackable*: :func:`pad_plan`
    pads a plan to shared (width, wave-count, ρ-layout) maxima with
    provably inert waves/lanes, and :func:`stack_plans` stacks padded
    plans into one fleet plan whose arrays carry a leading ``S`` axis
    (same per-field layout, one more axis — the ``n``/``e_a``/``K``
    sentinels are shared fleet-wide).
    """

    width: int                # B = max wavefront size (<= n)
    n: int                    # node count; sentinel agent id for pad lanes
    e_a: int                  # flat ρ/ρ̃ layout half-size (>= real E_A);
                              #   pad slots carry the sentinel 2·e_a
    K: int                    # event count; kidx sentinel for pad lanes
    agent: np.ndarray         # (n_waves, B) i32, pad = n
    wslot: np.ndarray         # (n_waves, B) i32 ring slot for this write
    w_self: np.ndarray        # (n_waves, B) f32 W[a, a]
    a_self: np.ndarray        # (n_waves, B) f32 A[a, a]
    rslot_v: np.ndarray       # (n_waves, B, kw) i32 resolved v_hist slots
    src_v: np.ndarray         # (n_waves, B, kw) i32 sender node ids
    w_in: np.ndarray          # (n_waves, B, kw) f32 W[a, j] (0 = pad)
    rslot_rho: np.ndarray     # (n_waves, B, ka) i32 resolved rho_hist slots
    hist_epos: np.ndarray     # (n_waves, B, ka) i32 in-A edge rows (hist)
    a_val: np.ndarray         # (n_waves, B, ka) f32 1 = real in-A edge
    rho_gidx: np.ndarray      # (n_waves, B, ko+ka) i32 flat ρ/ρ̃ rows
                              #   (gather AND scatter: each row is owned
                              #   by exactly one lane slot)
    out_wt: np.ndarray        # (n_waves, B, ko) f32 A[dst, a] (0 = pad)
    kidx: np.ndarray          # (n_waves, B) i64 event index, pad = K
    event_start: np.ndarray   # (n_waves,) i64 first event of each wave
                              #   (pad waves carry K: they sort last)
    sizes: np.ndarray         # (n_waves,) i32 valid lanes per wave

    @property
    def n_waves(self) -> int:
        # agent is (n_waves, B) for a single plan, (S, n_waves, B) for a
        # fleet-stacked one: the wave axis is always second-to-last
        return int(self.agent.shape[-2])

    @property
    def n_lanes(self) -> int:
        """Fleet size: 1 for a single plan, S for a stacked one."""
        return 1 if self.agent.ndim == 2 else int(self.agent.shape[0])


# per-wave array fields, in declaration order; every padding/stacking
# helper below treats them uniformly (the wave axis is axis 0 of each)
_WAVE_FIELDS = ("agent", "wslot", "w_self", "a_self", "rslot_v", "src_v",
                "w_in", "rslot_rho", "hist_epos", "a_val", "rho_gidx",
                "out_wt", "kidx", "event_start", "sizes")


def _lane_fill(wf: WavefrontPlan, field: str):
    """The inert fill value of a padded *lane* of ``field``: commits drop
    (sentinel agent / ρ row), reads clamp, weights and validity are 0."""
    return {"agent": wf.n, "rho_gidx": 2 * wf.e_a, "kidx": wf.K}.get(field, 0)


def slice_plan(wf: WavefrontPlan, w0: int, w1: int) -> WavefrontPlan:
    """The sub-plan of waves ``[w0, w1)`` (any contiguous wave range of a
    valid plan is a valid plan: the grouping conditions only reference
    events at or before each wave)."""
    return dataclasses.replace(
        wf, **{f: getattr(wf, f)[w0:w1] for f in _WAVE_FIELDS})


def pad_plan(wf: WavefrontPlan, *, width: int | None = None,
             n_waves: int | None = None,
             e_a: int | None = None) -> WavefrontPlan:
    """Pad a plan to shared maxima so plans from different experiments
    stack into one fleet program.

    * ``width`` — append padded lanes to every wave.  A padded lane
      carries sentinel agent ``n`` (node-row scatters drop), sentinel ρ
      rows ``2·e_a`` (flat-ρ and ρ-history scatters drop), zero weights
      and validity (its reads contribute nothing anywhere), and kidx
      ``K`` (the zero RNG key row) — the same inertness argument as the
      engine's own chunk padding and the RavelSpec pad tail.
    * ``n_waves`` — append all-padded waves (every lane inert as above;
      ``event_start = K`` keeps the array sorted, ``sizes = 0``).
    * ``e_a`` — re-target the flat ρ/ρ̃ layout to a larger half-size:
      ρ rows keep their positions, ρ̃ rows shift by the new offset, and
      sentinels become ``2·e_a_new``.  The extra state rows are never
      referenced by any real lane.
    """
    width = wf.width if width is None else int(width)
    n_w = wf.n_waves if n_waves is None else int(n_waves)
    e_a_new = wf.e_a if e_a is None else int(e_a)
    if width < wf.width or n_w < wf.n_waves or e_a_new < wf.e_a:
        raise ValueError(
            f"cannot shrink a plan: have (width={wf.width}, "
            f"n_waves={wf.n_waves}, e_a={wf.e_a}), asked for "
            f"({width}, {n_w}, {e_a_new})")
    out = {f: getattr(wf, f) for f in _WAVE_FIELDS}
    if e_a_new != wf.e_a:
        g = out["rho_gidx"]
        out["rho_gidx"] = np.where(
            g < wf.e_a, g,
            np.where(g < 2 * wf.e_a, g + (e_a_new - wf.e_a),
                     2 * e_a_new)).astype(g.dtype)
    wf2 = dataclasses.replace(wf, e_a=e_a_new)   # fills use the new layout
    if width != wf.width:
        for f in _WAVE_FIELDS:
            a = out[f]
            if a.ndim < 2:          # event_start / sizes have no lane axis
                continue
            pad = np.full((a.shape[0], width - wf.width) + a.shape[2:],
                          _lane_fill(wf2, f), a.dtype)
            out[f] = np.concatenate([a, pad], axis=1)
    if n_w != wf.n_waves:
        extra = n_w - wf.n_waves
        for f in _WAVE_FIELDS:
            a = out[f]
            if f == "event_start":
                fill = wf.K          # padded waves sort after every event
            elif f == "sizes":
                fill = 0
            else:
                fill = _lane_fill(wf2, f)
            pad = np.full((extra,) + a.shape[1:], fill, a.dtype)
            out[f] = np.concatenate([a, pad], axis=0)
    return dataclasses.replace(wf2, width=width, **out)


def concat_plans(plans: "list[WavefrontPlan]") -> WavefrontPlan:
    """Concatenate plans along the wave axis (inverse of chunk-wise
    :func:`slice_plan`; all parts must share width and layout)."""
    first = plans[0]
    for wf in plans[1:]:
        if (wf.width, wf.n, wf.e_a, wf.K) != (first.width, first.n,
                                              first.e_a, first.K):
            raise ValueError("concat_plans needs identical width/n/e_a/K")
    return dataclasses.replace(
        first, **{f: np.concatenate([getattr(w, f) for w in plans], axis=0)
                  for f in _WAVE_FIELDS})


def flatten_plans(stacked: WavefrontPlan) -> WavefrontPlan:
    """Lower a fleet-stacked plan to ONE wider single-experiment plan.

    The S lanes of wave w become S·B lanes of one wave by offsetting
    every index into lane-private blocks: nodes of lane s live at
    ``[s·n, (s+1)·n)`` (so the fleet node state is ``(S·n, 4, p)``),
    ρ rows at ``[s·e_a, (s+1)·e_a)`` with ρ̃ at offset ``S·e_a`` (state
    ``(2·S·e_a, p)``, histories ``(H, S·n, p)``/``(H, S·e_a, p)``), and
    events at ``[s·K, (s+1)·K)`` (per-lane RNG streams concatenate).
    Sentinels map to the fleet-wide sentinels ``S·n``/``2·S·e_a``/``S·K``.

    Correctness is index disjointness: every cross-event interaction in
    a WavefrontPlan happens through these indices, lanes' blocks are
    disjoint, and padded slots still drop — so the flat program is
    exactly the S independent programs, interleaved.  The payoff is the
    compile: the scan body is the *single-experiment* wave step at width
    S·B (no fleet vmap), so the fleet compiles like one run.

    ``event_start``/``sizes`` become fleet aggregates (earliest flat
    event / total lanes per wave) — chunk alignment must be done before
    stacking (as ``run_sweep`` does).
    """
    if stacked.agent.ndim != 3:
        raise ValueError("flatten_plans expects a stack_plans output "
                         "(arrays with a leading S axis)")
    S = stacked.n_lanes
    n, e_a, K, B = stacked.n, stacked.e_a, stacked.K, stacked.width
    NW = stacked.n_waves
    s_off = np.arange(S, dtype=np.int64)[:, None, None]

    def flat(a):
        """(S, NW, B, ...) -> (NW, S*B, ...)"""
        return np.moveaxis(a, 0, 1).reshape((NW, S * a.shape[2])
                                            + a.shape[3:])

    agent = np.where(stacked.agent == n, S * n, stacked.agent + s_off * n)
    src_v = stacked.src_v + s_off[..., None] * n
    hist_epos = stacked.hist_epos + s_off[..., None] * e_a
    g = stacked.rho_gidx
    gidx = np.where(
        g < e_a, g + s_off[..., None] * e_a,
        np.where(g < 2 * e_a, g + (S - 1 + s_off[..., None]) * e_a,
                 2 * S * e_a))
    kidx = np.where(stacked.kidx == K, S * K, stacked.kidx + s_off * K)
    return dataclasses.replace(
        stacked, width=S * B, n=S * n, e_a=S * e_a, K=S * K,
        agent=flat(agent).astype(np.int32),
        wslot=flat(stacked.wslot), w_self=flat(stacked.w_self),
        a_self=flat(stacked.a_self),
        rslot_v=flat(stacked.rslot_v),
        src_v=flat(src_v).astype(np.int32),
        w_in=flat(stacked.w_in), rslot_rho=flat(stacked.rslot_rho),
        hist_epos=flat(hist_epos).astype(np.int32),
        a_val=flat(stacked.a_val),
        rho_gidx=flat(gidx).astype(np.int32),
        out_wt=flat(stacked.out_wt),
        kidx=flat(kidx),
        event_start=(stacked.event_start
                     + np.arange(S, dtype=np.int64)[:, None] * K).min(0),
        sizes=stacked.sizes.sum(0).astype(np.int32),
    )


def grid_gather_tables(agent, rslot_rho, hist_epos, rho_gidx, *,
                       e_a_flat: int, ko: int):
    """Flat-row gather tables for the fleet-grid commit kernel.

    Translates one wave's lane tables (slices of a — possibly
    fleet-flattened — :class:`WavefrontPlan`) into row indices over the
    flat device state the grid kernel reads directly:

    * ``idx_z``/``idx_g`` — rows of ``nodes.reshape(N·4, p)`` (node
      layout x, v, z, g_prev → z at ``4a+2``, g_prev at ``4a+3``),
    * ``idx_ri``          — rows of ``rho_hist.reshape(H·E, p)``
      (``slot·E + epos``),
    * ``idx_ro``/``idx_rb`` — the ρ-out / ρ̃ halves of ``rho_gidx``
      (rows of the flat ``(2E, p)`` ρ state; the split mirrors the
      plan's ko-first row ordering).

    ``e_a_flat`` is the flat ρ half-size E (``S·e_a`` after
    :func:`flatten_plans`).  Sentinel entries pass through untranslated
    (``commit_grid`` clamps reads; commits drop at the caller's
    scatters).  Works on numpy arrays and jax tracers alike.
    """
    agent = agent.astype("int32") * 4
    return (agent + 2, agent + 3,
            rslot_rho.astype("int32") * e_a_flat
            + hist_epos.astype("int32"),
            rho_gidx[..., ko:], rho_gidx[..., :ko])


def stack_plans(plans: "list[WavefrontPlan]") -> WavefrontPlan:
    """Stack per-experiment plans into one fleet plan with a leading
    ``S`` axis on every per-wave array.

    Plans are first padded (:func:`pad_plan`) to the fleet-wide
    (width, wave-count, ρ-layout) maxima; they must already share ``n``,
    ``K``, and the per-node degree maxima (kw, ka, ko) — normalize
    CommPlans from different topologies with
    :func:`repro.core.plan.pad_comm_plan` before building them.
    """
    if not plans:
        raise ValueError("stack_plans needs at least one plan")
    ns = {wf.n for wf in plans}
    Ks = {wf.K for wf in plans}
    if len(ns) != 1 or len(Ks) != 1:
        raise ValueError(f"plans must share n and K, got n={ns}, K={Ks}")
    degs = {(wf.rslot_v.shape[-1], wf.rslot_rho.shape[-1],
             wf.out_wt.shape[-1]) for wf in plans}
    if len(degs) != 1:
        raise ValueError(
            f"plans carry different (kw, ka, ko) degree maxima {degs}; "
            "pad the CommPlans with plan.pad_comm_plan first")
    width = max(wf.width for wf in plans)
    n_waves = max(wf.n_waves for wf in plans)
    e_a = max(wf.e_a for wf in plans)
    padded = [pad_plan(wf, width=width, n_waves=n_waves, e_a=e_a)
              for wf in plans]
    return dataclasses.replace(
        padded[0],
        **{f: np.stack([getattr(w, f) for w in padded]) for f in _WAVE_FIELDS})


def _write_counters(agent: np.ndarray, n: int) -> np.ndarray:
    """c[k] = how many times agent[k] has updated up to and including k."""
    c = np.zeros(agent.shape[0], dtype=np.int64)
    for j in range(n):
        idx = np.nonzero(agent == j)[0]
        c[idx] = np.arange(1, idx.shape[0] + 1)
    return c


def _resolve_read_slots(stamps: np.ndarray, owner: np.ndarray,
                        emit: list[np.ndarray], H: int,
                        n_real: int) -> np.ndarray:
    """Per (event, edge): ring slot of the owner's last write with emitted
    stamp <= stamps[k, e] (slot 0 = the zero-initialized 'no write yet')."""
    out = np.zeros(stamps.shape, dtype=np.int32)
    for e in range(n_real):
        w = np.searchsorted(emit[int(owner[e])], stamps[:, e], side="right")
        out[:, e] = w % H
    return out


def build_wavefront_plan(schedule: Schedule, plan, H: int, *,
                         break_every: int = 0,
                         max_width: int | None = None,
                         e_a: int | None = None) -> WavefrontPlan:
    """Compile ``schedule`` into a :class:`WavefrontPlan` over ``plan``
    (a :class:`repro.core.plan.CommPlan`).

    ``break_every``: force wavefront boundaries at multiples of this event
    index (so evaluation chunks map to whole waves); 0 = no forced breaks.
    ``max_width``: split wavefronts wider than this (any prefix split of a
    valid wavefront is valid — the grouping conditions are monotone in the
    start index).  Padded lanes cost real gradient compute, so the default
    picks the width minimizing modelled cost (scan steps + padded lanes)
    over the realized size distribution.
    ``e_a``: half-size of the flat ρ/ρ̃ state layout the plan indexes
    into; defaults to the plan's real A-edge count, and may be padded up
    front (e.g. to a fleet-wide maximum) instead of remapped later with
    :func:`pad_plan`.
    """
    agent = np.asarray(schedule.agent, dtype=np.int64)
    K, n = agent.shape[0], plan.n
    ev = np.arange(K)

    # per-event gathered in-edge tables of the active agent
    iw_e = plan.in_w_epos[agent]                      # (K, kw)
    ia_e = plan.in_a_epos[agent]                      # (K, ka)
    sv = schedule.stamp_v[ev[:, None], iw_e]          # (K, kw)
    sr = schedule.stamp_rho[ev[:, None], ia_e]        # (K, ka)
    w_ok = plan.in_w_wt[agent] != 0
    a_ok = plan.in_a_val[agent] > 0
    rel = np.maximum(np.where(w_ok, sv, 0).max(axis=1, initial=0),
                     np.where(a_ok, sr, 0).max(axis=1, initial=0))

    # delta-history write slots + host-resolved read slots
    wslot = (_write_counters(agent, n) % H).astype(np.int32)
    emit = [np.nonzero(agent == j)[0] + 1 for j in range(n)]
    slots_v = _resolve_read_slots(schedule.stamp_v, plan.src_w, emit, H,
                                  plan.n_edges_w)
    slots_r = _resolve_read_slots(schedule.stamp_rho, plan.src_a, emit, H,
                                  plan.n_edges_a)
    rslot_v = slots_v[ev[:, None], iw_e]              # (K, kw)
    rslot_rho = slots_r[ev[:, None], ia_e]            # (K, ka)

    # flat ρ/ρ̃ indices: ρ rows at [0, e_a), ρ̃ rows at [e_a, 2·e_a);
    # sentinel 2·e_a marks pad slots (drop-mode scatters discard them)
    if e_a is None:
        e_a = max(1, plan.n_edges_a)
    elif e_a < max(1, plan.n_edges_a):
        raise ValueError(f"e_a={e_a} < the plan's A-edge count "
                         f"{plan.n_edges_a}")
    oa_e, ia_e2 = plan.out_a_epos[agent], plan.in_a_epos[agent]
    o_ok = plan.out_a_val[agent] > 0
    gidx = np.concatenate([np.where(o_ok, oa_e, 2 * e_a),
                           np.where(a_ok, e_a + ia_e2, 2 * e_a)], axis=1)

    # greedy grouping into wavefronts
    starts = [0]
    used = {int(agent[0])}
    for k in range(1, K):
        if ((break_every and k % break_every == 0)
                or int(agent[k]) in used or int(rel[k]) > starts[-1]):
            starts.append(k)
            used = {int(agent[k])}
        else:
            used.add(int(agent[k]))
    starts.append(K)
    sizes = np.diff(np.asarray(starts, dtype=np.int64))

    # split over-wide wavefronts: padded lanes still pay for a vmapped
    # gradient, so a narrower width with a few more scan steps is usually
    # cheaper; the model charges ~1.3 lane-units of fixed per-wave cost
    if max_width is None:
        cands = range(1, int(sizes.max()) + 1)
        cost = lambda B: (1.3 * np.ceil(sizes / B).sum()
                          + (np.ceil(sizes / B) * B).sum())
        max_width = min(cands, key=cost)
    if sizes.max() > max_width:
        split = []
        for s0, sz in zip(starts[:-1], sizes):
            split.extend(range(int(s0), int(s0 + sz), max_width))
        starts = split + [K]
        sizes = np.diff(np.asarray(starts, dtype=np.int64))

    n_waves, B = sizes.shape[0], int(sizes.max())
    event_start = np.asarray(starts[:-1], dtype=np.int64)

    lane = event_start[:, None] + np.arange(B)[None, :]     # (n_waves, B)
    valid = np.arange(B)[None, :] < sizes[:, None]
    kidx = np.where(valid, lane, K)
    pick = lambda arr, pad: np.where(
        valid.reshape(valid.shape + (1,) * (arr.ndim - 1)),
        arr[np.minimum(lane, K - 1)], pad)
    i32 = lambda a: np.asarray(a, np.int32)
    f32 = lambda a: np.asarray(a, np.float32)
    return WavefrontPlan(
        width=B,
        n=n,
        e_a=int(e_a),
        K=K,
        agent=i32(pick(agent, n)),
        wslot=i32(pick(wslot, 0)),
        w_self=f32(pick(plan.w_diag[agent], 0.0)),
        a_self=f32(pick(plan.a_diag[agent], 0.0)),
        rslot_v=i32(pick(rslot_v, 0)),
        src_v=i32(pick(plan.in_w_src[agent], 0)),
        w_in=f32(pick(plan.in_w_wt[agent], 0.0)),
        rslot_rho=i32(pick(rslot_rho, 0)),
        hist_epos=i32(pick(ia_e2, 0)),
        a_val=f32(pick(plan.in_a_val[agent], 0.0)),
        rho_gidx=i32(pick(gidx, 2 * e_a)),
        out_wt=f32(pick(plan.out_a_wt[agent], 0.0)),
        kidx=kidx,
        event_start=event_start,
        sizes=sizes.astype(np.int32),
    )


def round_robin_schedule(topo: Topology, n_rounds: int) -> Schedule:
    """Remark 2: the synchronous counterpart as a global-view schedule.

    ``i^k = k mod n``; at its local iteration ``t`` (global ``k = t·n + i``)
    node ``i`` consumes neighbour ``j``'s payload with local stamp ``t``,
    i.e. global stamp ``(t-1)·n + j + 1`` (0 for t = 0).  Realized delay is
    ``n + i - j - 1 ≤ 2n - 2`` exactly as the paper computes.
    """
    n = topo.n
    K = n_rounds * n
    edges_w = topo.edges_W()
    edges_a = topo.edges_A()
    agent = np.arange(K, dtype=np.int32) % n
    stamp_v = np.zeros((K, max(1, len(edges_w))), dtype=np.int32)
    stamp_rho = np.zeros((K, max(1, len(edges_a))), dtype=np.int32)
    for k in range(K):
        t = k // n
        for e, (j, _i) in enumerate(edges_w):
            stamp_v[k, e] = 0 if t == 0 else (t - 1) * n + j + 1
        for e, (j, _i) in enumerate(edges_a):
            stamp_rho[k, e] = 0 if t == 0 else (t - 1) * n + j + 1
    return Schedule(
        agent=agent,
        stamp_v=stamp_v,
        stamp_rho=stamp_rho,
        times=np.arange(K, dtype=np.float64) / n,
        D=max(1, 2 * n - 2),
        T=n,
    )
