"""One virtual-time scenario engine for R-FAST and every baseline.

The paper's headline claim (Fig. 5-6) is a *time-to-loss* claim, so every
cross-algorithm comparison is only meaningful when all algorithms
experience the same delay/failure model (Lian et al. 2018; Assran et al.
2020).  This module owns that model: a declarative
:class:`NetworkScenario` plus the single event-clock core that is the
only source of virtual time in the repo.

Two clocks, one model:

* :meth:`NetworkScenario.realize` — the asynchronous event clock.  Every
  node runs its own virtual clock (per-node compute rates, multiplicative
  jitter, *time-varying* straggler windows, crash/recovery windows);
  every packet traverses a lossy, delayed channel (per-edge latency
  means, Bernoulli or bursty Gilbert-Elliott loss).  The result is a
  :class:`ScenarioTrace`: the realized :class:`~repro.core.schedule.Schedule`
  (activations + per-edge payload stamps, consumed by ``run_rfast`` and
  the async baselines) plus the per-event send outcomes (consumed by
  OSGP's mailboxes, which — unlike R-FAST's running sums — lose the mass
  of dropped packets).
* :meth:`NetworkScenario.sync_round_times` — the synchronous barrier
  clock, built from the *same* primitives: a round ends when the slowest
  node (stragglers, crash stalls included) finishes its compute AND every
  edge has delivered, with lost packets retransmitted.

The default-parameter ``realize`` path consumes its RNG stream in exactly
the order the pre-refactor ``schedule.generate_schedule`` did, so the
compatibility shim reproduces historical schedules bit-for-bit (pinned by
a golden test).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

from .schedule import Schedule, _realized_T
from .topology import Topology

__all__ = [
    "GilbertElliott", "EdgeChannels", "NetworkScenario", "ScenarioTrace",
    "SCENARIOS", "get_scenario", "realize_batch",
]


# --------------------------------------------------------------------- #
# loss channels
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GilbertElliott:
    """Bursty two-state loss channel (per packet: state step, then loss).

    ``p_gb``/``p_bg`` are the good->bad / bad->good transition
    probabilities per packet; ``loss_good``/``loss_bad`` the loss
    probability within each state.  Stationary loss rate is
    ``pi_bad * loss_bad + (1 - pi_bad) * loss_good`` with
    ``pi_bad = p_gb / (p_gb + p_bg)``; mean burst length ``1 / p_bg``.
    """

    p_gb: float
    p_bg: float
    loss_good: float = 0.0
    loss_bad: float = 1.0


class EdgeChannels:
    """Per-edge loss processes sharing one RNG stream.

    Bernoulli mode draws exactly one uniform per packet (the pre-refactor
    draw order, needed for the ``generate_schedule`` golden test);
    Gilbert-Elliott mode keeps an independent good/bad state per edge and
    draws two uniforms per packet (state transition, then loss).
    """

    def __init__(self, n_edges: int, loss: float,
                 ge: GilbertElliott | None, rng: np.random.Generator):
        self.loss = float(loss)
        self.ge = ge
        self.rng = rng
        self.bad = np.zeros(n_edges, dtype=bool)   # GE state (start good)

    def ok(self, e: int) -> bool:
        """One packet on edge ``e``: True = delivered, False = lost."""
        if self.ge is None:
            return bool(self.rng.uniform() >= self.loss)
        flip = self.ge.p_bg if self.bad[e] else self.ge.p_gb
        if self.rng.uniform() < flip:
            self.bad[e] = not self.bad[e]
        p = self.ge.loss_bad if self.bad[e] else self.ge.loss_good
        return bool(self.rng.uniform() >= p)


# --------------------------------------------------------------------- #
# the scenario
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ScenarioTrace:
    """One realization of a scenario on a topology: the Schedule all
    algorithms consume, plus per-event send outcomes (True = the active
    agent's packet on that out-edge was delivered; rows of inactive
    agents are False)."""

    schedule: Schedule
    send_ok_w: np.ndarray   # (K, max(1, E_W)) bool
    send_ok_a: np.ndarray   # (K, max(1, E_A)) bool


@dataclasses.dataclass(frozen=True)
class NetworkScenario:
    """Declarative network/compute model shared by every algorithm.

    Args:
      compute_time: per-node mean compute interval — scalar or length-n
        sequence (straggler = large value).
      jitter: multiplicative uniform jitter on each compute interval.
      latency: mean packet latency (exponential), in compute-time units.
      edge_latency: per-edge overrides of ``latency``, keyed ``(src, dst)``.
      loss: per-packet Bernoulli loss probability.
      gilbert_elliott: when set, replaces Bernoulli loss with a bursty
        two-state channel per edge.
      stragglers: *time-varying* slowdowns ``(node, t0, t1, factor)`` —
        inside ``[t0, t1)`` the node's compute interval is multiplied by
        ``factor`` (factors of overlapping windows compose).
      failures: crash/recovery windows ``(node, t0, t1)`` — the node does
        not wake inside the window; bounded downtime keeps Assumption 3
        satisfied with a larger realized T.
      D_max: hard staleness bound (Assumption 3ii); default ``4n + 16``.
      name: optional label (used by benchmark rows).
    """

    compute_time: float | Sequence[float] = 1.0
    jitter: float = 0.2
    latency: float = 0.1
    edge_latency: Mapping[tuple[int, int], float] | None = None
    loss: float = 0.0
    gilbert_elliott: GilbertElliott | None = None
    stragglers: tuple[tuple[int, float, float, float], ...] = ()
    failures: tuple[tuple[int, float, float], ...] = ()
    D_max: int | None = None
    name: str = ""

    # -- per-node / per-edge resolution ------------------------------- #
    def node_compute(self, n: int) -> np.ndarray:
        base = np.asarray(self.compute_time, dtype=np.float64)
        if base.ndim == 0:
            base = np.full(n, float(base))
        if base.shape != (n,):
            raise ValueError(
                f"compute_time must be scalar or length {n}, got "
                f"shape {base.shape}")
        return base

    def edge_latency_of(self, edges: list[tuple[int, int]]) -> np.ndarray:
        lat = np.full(max(1, len(edges)), float(self.latency))
        for e, (j, i) in enumerate(edges):
            if self.edge_latency and (j, i) in self.edge_latency:
                lat[e] = float(self.edge_latency[(j, i)])
        return lat

    def slow_factor(self, node: int, t: float) -> float:
        f = 1.0
        for (i, t0, t1, factor) in self.stragglers:
            if i == node and t0 <= t < t1:
                f *= factor
        return f

    def in_failure(self, node: int, t: float) -> bool:
        return any(i == node and t0 <= t < t1 for (i, t0, t1) in self.failures)

    def channels(self, n_edges: int, rng: np.random.Generator) -> EdgeChannels:
        return EdgeChannels(n_edges, self.loss, self.gilbert_elliott, rng)

    def resolved_D_max(self, n: int) -> int:
        """The Assumption-3(ii) staleness bound actually enforced —
        the single source for every consumer (realize's forced delivery,
        AD-PSGD's partner-read clamp/ring sizing)."""
        return self.D_max if self.D_max is not None else 4 * n + 16

    # ----------------------------------------------------------------- #
    # the asynchronous event clock (the only one in the repo)
    # ----------------------------------------------------------------- #
    def realize(self, topo: Topology, K: int, *, seed: int = 0) -> ScenarioTrace:
        """Simulate virtual clocks + network over ``topo`` for ``K`` events.

        Packets carry the sender's post-update stamp; a receiver always
        consumes the largest stamp delivered so far (the paper's ``tau``
        semantics), so per-edge stamps are monotone.  ``D_max`` enforces
        Assumption 3(ii): when loss/latency would push staleness past it,
        delivery is forced (the model excludes infinitely persistent
        loss).  With default parameters the RNG draw order is identical
        to the pre-refactor ``generate_schedule`` (golden-tested).
        """
        rng = np.random.default_rng(seed)
        n = topo.n
        base = self.node_compute(n)
        D_max = self.resolved_D_max(n)

        edges_w = topo.edges_W()
        edges_a = topo.edges_A()
        out_w: dict[int, list[int]] = {i: [] for i in range(n)}
        out_a: dict[int, list[int]] = {i: [] for i in range(n)}
        in_w: dict[int, list[int]] = {i: [] for i in range(n)}
        in_a: dict[int, list[int]] = {i: [] for i in range(n)}
        for e, (j, i) in enumerate(edges_w):
            out_w[j].append(e)
            in_w[i].append(e)
        for e, (j, i) in enumerate(edges_a):
            out_a[j].append(e)
            in_a[i].append(e)
        lat_w = self.edge_latency_of(edges_w)
        lat_a = self.edge_latency_of(edges_a)

        # per-edge arrival queues: (arrival_time, stamp); consumed in
        # stamp order (non-FIFO arrival allowed — max stamp arrived wins)
        arrivals_w: list[list[tuple[float, int]]] = [[] for _ in edges_w]
        arrivals_a: list[list[tuple[float, int]]] = [[] for _ in edges_a]
        best_w = np.zeros(len(edges_w), dtype=np.int64)
        best_a = np.zeros(len(edges_a), dtype=np.int64)

        clocks = rng.uniform(0.0, 1.0, n) * base
        # crash windows: push a node's first wake-up past the recovery time
        for (fn_, t0_, t1_) in self.failures:
            if clocks[fn_] >= t0_:
                clocks[fn_] = max(clocks[fn_], t1_)
        ch_w = self.channels(len(edges_w), rng)
        ch_a = self.channels(len(edges_a), rng)

        agent = np.zeros(K, dtype=np.int32)
        stamp_v = np.zeros((K, max(1, len(edges_w))), dtype=np.int32)
        stamp_rho = np.zeros((K, max(1, len(edges_a))), dtype=np.int32)
        times = np.zeros(K, dtype=np.float64)
        send_ok_w = np.zeros((K, max(1, len(edges_w))), dtype=bool)
        send_ok_a = np.zeros((K, max(1, len(edges_a))), dtype=bool)
        max_delay = 0

        for k in range(K):
            a = int(np.argmin(clocks))
            now = float(clocks[a])
            agent[k] = a
            times[k] = now

            # consume: advance best stamp per in-edge from arrived packets
            for e in in_w[a]:
                q = arrivals_w[e]
                keep = []
                for (t_arr, s) in q:
                    if t_arr <= now:
                        if s > best_w[e]:
                            best_w[e] = s
                    else:
                        keep.append((t_arr, s))
                arrivals_w[e][:] = keep
                if k - best_w[e] > D_max:         # Assumption 3(ii)
                    best_w[e] = k - D_max
            for e in in_a[a]:
                q = arrivals_a[e]
                keep = []
                for (t_arr, s) in q:
                    if t_arr <= now:
                        if s > best_a[e]:
                            best_a[e] = s
                    else:
                        keep.append((t_arr, s))
                arrivals_a[e][:] = keep
                if k - best_a[e] > D_max:
                    best_a[e] = k - D_max

            stamp_v[k] = best_w if len(edges_w) else 0
            stamp_rho[k] = best_a if len(edges_a) else 0
            for e in in_w[a]:
                max_delay = max(max_delay, k - int(best_w[e]))
            for e in in_a[a]:
                max_delay = max(max_delay, k - int(best_a[e]))

            # send: node a finishes local iteration k, emits stamp k+1
            for e in out_w[a]:
                if ch_w.ok(e):
                    send_ok_w[k, e] = True
                    arrivals_w[e].append(
                        (now + rng.exponential(lat_w[e]), k + 1))
            for e in out_a[a]:
                if ch_a.ok(e):
                    send_ok_a[k, e] = True
                    arrivals_a[e].append(
                        (now + rng.exponential(lat_a[e]), k + 1))

            step = base[a] * self.slow_factor(a, now)
            clocks[a] = now + step * (1.0 + rng.uniform(-self.jitter,
                                                        self.jitter))
            for (fn_, t0_, t1_) in self.failures:
                if fn_ == a and t0_ <= clocks[a] < t1_:
                    clocks[a] = t1_       # crash: sleep through the window

        schedule = Schedule(
            agent=agent,
            stamp_v=stamp_v,
            stamp_rho=stamp_rho,
            times=times,
            D=int(max(1, max_delay)),
            T=_realized_T(agent, n),
        )
        return ScenarioTrace(schedule=schedule, send_ok_w=send_ok_w,
                             send_ok_a=send_ok_a)

    # ----------------------------------------------------------------- #
    # the synchronous barrier clock (same primitives, same model)
    # ----------------------------------------------------------------- #
    def sync_round_times(self, topo: Topology | int, rounds: int, *,
                         seed: int = 0, max_retries: int = 50) -> np.ndarray:
        """Cumulative virtual completion time of ``rounds`` barrier rounds.

        Round ``r`` starting at barrier time ``t`` ends at::

            max_i compute_i(t)  +  max_e retransmit_latency_e

        where ``compute_i`` draws from node ``i``'s profile (straggler
        windows apply, crash windows stall the barrier until recovery —
        the synchronous cost of a failure) and each edge redraws its
        latency until the loss channel delivers (at most ``max_retries``
        tries; bursty channels cannot stall a barrier forever).

        ``topo`` may be an ``int`` node count (e.g. Ring-AllReduce): the
        communication graph is then taken as the n-edge directed ring.
        """
        rng = np.random.default_rng(seed)
        if isinstance(topo, int):
            n = topo
            edges = [(i, (i + 1) % n) for i in range(n)]
        else:
            n = topo.n
            edges = sorted(set(topo.edges_W()) | set(topo.edges_A()))
        base = self.node_compute(n)
        lat = self.edge_latency_of(edges)
        ch = self.channels(len(edges), rng)

        times = np.zeros(rounds, dtype=np.float64)
        t = 0.0
        for r in range(rounds):
            finish = t
            for i in range(n):
                step = base[i] * self.slow_factor(i, t)
                f_i = t + step * (1.0 + rng.uniform(-self.jitter, self.jitter))
                # a crash window overlapping the work stalls the barrier
                for (fn_, t0_, t1_) in self.failures:
                    if fn_ == i and t0_ < f_i and t1_ > t:
                        f_i = max(f_i, t1_)
                finish = max(finish, f_i)
            comm = 0.0
            for e in range(len(edges)):
                t_e = rng.exponential(lat[e])
                tries = 1
                while not ch.ok(e) and tries < max_retries:
                    t_e += rng.exponential(lat[e])
                    tries += 1
                comm = max(comm, t_e)
            t = finish + comm
            times[r] = t
        return times


# --------------------------------------------------------------------- #
# named scenarios (the benchmark suite's shared vocabulary)
# --------------------------------------------------------------------- #
def _uniform(n: int) -> NetworkScenario:
    return NetworkScenario(latency=0.3, name="uniform")


def _straggler(n: int) -> NetworkScenario:
    compute = np.ones(n)
    compute[-1] = 4.0
    return NetworkScenario(compute_time=tuple(compute), latency=0.3,
                           name="straggler")


def _flaky_straggler(n: int) -> NetworkScenario:
    """Time-varying: the last node runs 6x slow in two windows."""
    s = n - 1
    return NetworkScenario(
        latency=0.3,
        stragglers=((s, 100.0, 300.0, 6.0), (s, 600.0, 800.0, 6.0)),
        name="flaky_straggler")


def _packet_loss(n: int) -> NetworkScenario:
    return NetworkScenario(latency=0.3, loss=0.2, name="packet_loss")


def _bursty_loss(n: int) -> NetworkScenario:
    # ~20% stationary loss in bursts of mean length 10 packets
    return NetworkScenario(
        latency=0.3,
        gilbert_elliott=GilbertElliott(p_gb=0.025, p_bg=0.1),
        name="bursty_loss")


def _crash_recovery(n: int) -> NetworkScenario:
    """Two nodes crash (disjoint windows) and recover."""
    return NetworkScenario(
        latency=0.3,
        failures=((n - 1, 150.0, 280.0), (max(0, n // 2), 450.0, 560.0)),
        name="crash_recovery")


SCENARIOS: dict[str, Callable[[int], NetworkScenario]] = {
    "uniform": _uniform,
    "straggler": _straggler,
    "flaky_straggler": _flaky_straggler,
    "packet_loss": _packet_loss,
    "bursty_loss": _bursty_loss,
    "crash_recovery": _crash_recovery,
}


def get_scenario(name: str, n: int) -> NetworkScenario:
    """Named scenario for an ``n``-node deployment (see :data:`SCENARIOS`)."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    return SCENARIOS[name](n)


def realize_batch(
    topo: Topology, K: int, *,
    scenario: NetworkScenario | str | None = None,
    scenarios: Sequence[NetworkScenario | str] | None = None,
    seeds: Sequence[int] = (0,),
) -> list[ScenarioTrace]:
    """Realize a fleet of independent :class:`ScenarioTrace` lanes.

    Exactly one of ``scenario`` (one scenario × many seeds) or
    ``scenarios`` (a sweep — e.g. names from the :data:`SCENARIOS`
    registry — crossed with ``seeds``) must be given; strings resolve
    through :func:`get_scenario` for ``topo.n``.  Lane order is
    scenario-major, seed-minor.  Every lane shares ``topo`` and ``K``,
    so the result feeds :func:`repro.core.simulator.run_sweep` directly
    (lane ``i * len(seeds) + j`` carries scenario ``i``, seed
    ``seeds[j]``); mixed-topology fleets realize per topology and
    concatenate.
    """
    if (scenario is None) == (scenarios is None):
        raise ValueError("pass exactly one of scenario= or scenarios=")
    if scenario is not None:
        scenarios = [scenario]
    resolved = [get_scenario(sc, topo.n) if isinstance(sc, str) else sc
                for sc in scenarios]
    return [sc.realize(topo, K, seed=int(seed))
            for sc in resolved for seed in seeds]
