"""One virtual-time scenario engine for R-FAST and every baseline.

The paper's headline claim (Fig. 5-6) is a *time-to-loss* claim, so every
cross-algorithm comparison is only meaningful when all algorithms
experience the same delay/failure model (Lian et al. 2018; Assran et al.
2020).  This module owns that model: a declarative
:class:`NetworkScenario` plus the single event-clock core that is the
only source of virtual time in the repo.

Two clocks, one model:

* :meth:`NetworkScenario.realize` — the asynchronous event clock.  Every
  node runs its own virtual clock (per-node compute rates, multiplicative
  jitter, *time-varying* straggler windows, crash/recovery windows);
  every packet traverses a lossy, delayed channel (per-edge latency
  means, Bernoulli or bursty Gilbert-Elliott loss).  The result is a
  :class:`ScenarioTrace`: the realized :class:`~repro.core.schedule.Schedule`
  (activations + per-edge payload stamps, consumed by ``run_rfast`` and
  the async baselines) plus the per-event send outcomes (consumed by
  OSGP's mailboxes, which — unlike R-FAST's running sums — lose the mass
  of dropped packets).
* :meth:`NetworkScenario.sync_round_times` — the synchronous barrier
  clock, built from the *same* primitives: a round ends when the slowest
  node (stragglers, crash stalls included) finishes its compute AND every
  edge has delivered, with lost packets retransmitted.

The default-parameter ``realize`` path consumes its RNG stream in exactly
the order the pre-refactor ``schedule.generate_schedule`` did, so the
compatibility shim reproduces historical schedules bit-for-bit (pinned by
a golden test).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

from .schedule import Schedule, _realized_T
from .topology import Topology, epoch_topology

__all__ = [
    "GilbertElliott", "EdgeChannels", "NetworkScenario", "ScenarioTrace",
    "Epoch", "EpochTrace",
    "SCENARIOS", "get_scenario", "realize_batch", "realize_epochs_batch",
]


# --------------------------------------------------------------------- #
# loss channels
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GilbertElliott:
    """Bursty two-state loss channel (per packet: state step, then loss).

    ``p_gb``/``p_bg`` are the good->bad / bad->good transition
    probabilities per packet; ``loss_good``/``loss_bad`` the loss
    probability within each state.  Stationary loss rate is
    ``pi_bad * loss_bad + (1 - pi_bad) * loss_good`` with
    ``pi_bad = p_gb / (p_gb + p_bg)``; mean burst length ``1 / p_bg``.
    """

    p_gb: float
    p_bg: float
    loss_good: float = 0.0
    loss_bad: float = 1.0


class EdgeChannels:
    """Per-edge loss processes sharing one RNG stream.

    Bernoulli mode draws exactly one uniform per packet (the pre-refactor
    draw order, needed for the ``generate_schedule`` golden test);
    Gilbert-Elliott mode keeps an independent good/bad state per edge and
    draws two uniforms per packet (state transition, then loss).
    """

    def __init__(self, n_edges: int, loss: float,
                 ge: GilbertElliott | None, rng: np.random.Generator):
        self.loss = float(loss)
        self.ge = ge
        self.rng = rng
        self.bad = np.zeros(n_edges, dtype=bool)   # GE state (start good)

    def ok(self, e: int) -> bool:
        """One packet on edge ``e``: True = delivered, False = lost."""
        if self.ge is None:
            return bool(self.rng.uniform() >= self.loss)
        flip = self.ge.p_bg if self.bad[e] else self.ge.p_gb
        if self.rng.uniform() < flip:
            self.bad[e] = not self.bad[e]
        p = self.ge.loss_bad if self.bad[e] else self.ge.loss_good
        return bool(self.rng.uniform() >= p)


# --------------------------------------------------------------------- #
# the scenario
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ScenarioTrace:
    """One realization of a scenario on a topology: the Schedule all
    algorithms consume, plus per-event send outcomes (True = the active
    agent's packet on that out-edge was delivered; rows of inactive
    agents are False)."""

    schedule: Schedule
    send_ok_w: np.ndarray   # (K, max(1, E_W)) bool
    send_ok_a: np.ndarray   # (K, max(1, E_A)) bool


@dataclasses.dataclass(frozen=True)
class Epoch:
    """One membership epoch of a dynamic scenario: a fixed topology (with
    its ``active`` mask), the trace realized over it, and the membership
    delta against the previous epoch (what :func:`~repro.core.simulator.
    migrate_state` must absorb at the transition into this epoch)."""

    topology: Topology
    trace: ScenarioTrace
    t0: float             # virtual-time offset of this epoch's clock 0
    k0: int               # global event offset of this epoch's event 0
    joined: np.ndarray    # (n,) bool: nodes active now, inactive before
    departed: np.ndarray  # (n,) bool: nodes inactive now, active before
    root: int             # the epoch's elected common root (global id)

    @property
    def K(self) -> int:
        return int(len(self.trace.schedule.agent))


@dataclasses.dataclass(frozen=True)
class EpochTrace:
    """A scenario realization partitioned into membership epochs, each
    with its own validated topology — the input of
    :func:`~repro.core.simulator.run_epochs`.  Static scenarios yield a
    single epoch whose trace is bit-identical to :meth:`NetworkScenario.
    realize`."""

    epochs: tuple[Epoch, ...]
    n: int
    K: int

    @property
    def dynamic(self) -> bool:
        return len(self.epochs) > 1


@dataclasses.dataclass(frozen=True)
class NetworkScenario:
    """Declarative network/compute model shared by every algorithm.

    Args:
      compute_time: per-node mean compute interval — scalar or length-n
        sequence (straggler = large value).
      jitter: multiplicative uniform jitter on each compute interval.
      latency: mean packet latency (exponential), in compute-time units.
      edge_latency: per-edge overrides of ``latency``, keyed ``(src, dst)``.
      loss: per-packet Bernoulli loss probability.
      gilbert_elliott: when set, replaces Bernoulli loss with a bursty
        two-state channel per edge.
      stragglers: *time-varying* slowdowns ``(node, t0, t1, factor)`` —
        inside ``[t0, t1)`` the node's compute interval is multiplied by
        ``factor`` (factors of overlapping windows compose).
      failures: crash/recovery windows ``(node, t0, t1)`` — the node does
        not wake inside the window; bounded downtime keeps Assumption 3
        satisfied with a larger realized T.
      joins: dynamic membership ``(node, t_join)`` — the node is not a
        member before ``t_join`` (a ``t_join`` of 0 means member from the
        start).  Under :meth:`realize_epochs` a join opens a new epoch
        (the node enters with the root's iterate); under the frozen
        :meth:`realize` it degrades to a first-wake delay.
      leaves: dynamic membership ``(node, t_leave)`` — the node departs
        permanently at ``t_leave``.  Under :meth:`realize_epochs` this
        opens a new epoch (with root re-election when the departing node
        was a common root); under the frozen :meth:`realize` it degrades
        to a crash window that never ends — which is exactly how a
        frozen plan *fails* when the sole common root leaves.
      regional_failures: correlated failure groups
        ``(nodes, t0, t1, prob)`` — ONE Bernoulli(prob) draw per group;
        when it fires, every node in the group gets the crash window
        ``[t0, t1)`` together (rack/region outage).
      D_max: hard staleness bound (Assumption 3ii); default ``4n + 16``.
      name: optional label (used by benchmark rows).
    """

    compute_time: float | Sequence[float] = 1.0
    jitter: float = 0.2
    latency: float = 0.1
    edge_latency: Mapping[tuple[int, int], float] | None = None
    loss: float = 0.0
    gilbert_elliott: GilbertElliott | None = None
    stragglers: tuple[tuple[int, float, float, float], ...] = ()
    failures: tuple[tuple[int, float, float], ...] = ()
    joins: tuple[tuple[int, float], ...] = ()
    leaves: tuple[tuple[int, float], ...] = ()
    regional_failures: tuple[
        tuple[tuple[int, ...], float, float, float], ...] = ()
    D_max: int | None = None
    name: str = ""

    @property
    def dynamic(self) -> bool:
        """True when the scenario can change the member set mid-run —
        such scenarios should realize through :meth:`realize_epochs`
        (the frozen :meth:`realize` only *degrades* them)."""
        return bool(self.joins or self.leaves or self.regional_failures)

    # -- per-node / per-edge resolution ------------------------------- #
    def node_compute(self, n: int) -> np.ndarray:
        base = np.asarray(self.compute_time, dtype=np.float64)
        if base.ndim == 0:
            base = np.full(n, float(base))
        if base.shape != (n,):
            raise ValueError(
                f"compute_time must be scalar or length {n}, got "
                f"shape {base.shape}")
        return base

    def edge_latency_of(self, edges: list[tuple[int, int]]) -> np.ndarray:
        lat = np.full(max(1, len(edges)), float(self.latency))
        for e, (j, i) in enumerate(edges):
            if self.edge_latency and (j, i) in self.edge_latency:
                lat[e] = float(self.edge_latency[(j, i)])
        return lat

    def slow_factor(self, node: int, t: float) -> float:
        f = 1.0
        for (i, t0, t1, factor) in self.stragglers:
            if i == node and t0 <= t < t1:
                f *= factor
        return f

    def in_failure(self, node: int, t: float) -> bool:
        return any(i == node and t0 <= t < t1 for (i, t0, t1) in self.failures)

    def channels(self, n_edges: int, rng: np.random.Generator) -> EdgeChannels:
        return EdgeChannels(n_edges, self.loss, self.gilbert_elliott, rng)

    def resolved_D_max(self, n: int) -> int:
        """The Assumption-3(ii) staleness bound actually enforced —
        the single source for every consumer (realize's forced delivery,
        AD-PSGD's partner-read clamp/ring sizing)."""
        return self.D_max if self.D_max is not None else 4 * n + 16

    # -- dynamic membership helpers ----------------------------------- #
    def _effective_failures(self, rng: np.random.Generator) \
            -> list[tuple[int, float, float]]:
        """Crash windows actually in force this realization: the declared
        ``failures`` plus every *fired* regional group (one Bernoulli
        draw per group — drawn only when groups exist, so the default
        RNG stream is untouched and historical schedules stay golden)."""
        eff = [(int(i), float(t0), float(t1)) for (i, t0, t1)
               in self.failures]
        if self.regional_failures:
            draws = rng.uniform(size=len(self.regional_failures))
            for (group, t0, t1, p), u in zip(self.regional_failures,
                                             draws):
                if u < p:
                    eff += [(int(i), float(t0), float(t1))
                            for i in group]
        return eff

    def _membership_windows(self) -> list[tuple[int, float, float]]:
        """joins/leaves degraded to frozen-graph crash windows: a leave
        is a crash that never recovers, a join a crash since forever."""
        wins = [(int(j), float(t), np.inf) for (j, t) in self.leaves]
        wins += [(int(j), -np.inf, float(t)) for (j, t) in self.joins
                 if t > 0.0]
        return wins

    def _epoch_scenario(self, t0: float,
                        eff_failures: list[tuple[int, float, float]]) \
            -> "NetworkScenario":
        """This scenario re-expressed in one epoch's local clock: windows
        shifted by ``-t0`` (expired ones dropped), membership fields
        cleared (the epoch's ``Topology.active`` mask owns membership),
        regional draws already resolved into ``eff_failures``."""
        strag = tuple((i, s0 - t0, s1 - t0, f)
                      for (i, s0, s1, f) in self.stragglers if s1 > t0)
        fails = tuple((i, f0 - t0, f1 - t0)
                      for (i, f0, f1) in eff_failures if f1 > t0)
        return dataclasses.replace(
            self, stragglers=strag, failures=fails,
            joins=(), leaves=(), regional_failures=())

    def _epoch_timeline(self, topo: Topology,
                        eff_failures: list[tuple[int, float, float]],
                        max_epochs: int = 64) \
            -> list[tuple[float, float, np.ndarray, Topology]]:
        """Partition [0, inf) into membership epochs ``(t0, t1, active,
        topology)``.

        Boundaries come from joins/leaves and from the re-election
        trigger: a crash window opening on a node that is currently a
        *common root* converts into a leave-at-``t0`` / rejoin-at-``t1``
        pair (the fleet rewires around the crashed root instead of
        stalling on it).  Each epoch's topology is
        :func:`~repro.core.topology.epoch_topology` of the surviving
        member set — restriction when Assumption 2 survives, tree
        rebuild around a re-elected root otherwise; a ``ValueError``
        propagates when neither is possible.
        """
        n = topo.n
        active = np.ones(n, dtype=bool)
        pending: list[tuple[float, int, bool]] = []
        for (j, tj) in self.joins:
            if tj > 0.0:
                active[int(j)] = False
                pending.append((float(tj), int(j), True))
        for (j, tj) in self.leaves:
            pending.append((float(tj), int(j), False))
        handled: set[tuple[int, float, float]] = set()
        out: list[tuple[float, float, np.ndarray, Topology]] = []
        t = 0.0
        prev_root: int | None = None
        for _ in range(max_epochs):
            if not active.any():
                raise ValueError("membership timeline empties the graph")
            if active.all() and topo.active is None:
                etopo = topo          # static full-membership epoch
            else:
                etopo = epoch_topology(topo, active, prefer=prev_root)
            roots_now = etopo.common_roots
            prev_root = int(roots_now[0])
            tm = min((tt for (tt, _, _) in pending if tt > t),
                     default=np.inf)
            tr, win = np.inf, None
            for w in eff_failures:
                (fn, t0, t1) = w
                if (w not in handled and int(fn) in roots_now
                        and t0 > t and t1 > t0 and t0 < tr):
                    tr, win = t0, w
            b = min(tm, tr)
            if not np.isfinite(b):
                out.append((t, np.inf, active.copy(), etopo))
                return out
            if win is not None and tr <= tm:
                handled.add(win)
                (fn, t0, t1) = win
                pending.append((float(t0), int(fn), False))
                if np.isfinite(t1):
                    pending.append((float(t1), int(fn), True))
            out.append((t, float(b), active.copy(), etopo))
            still = []
            for (tt, node, on) in pending:
                if tt <= b:
                    active[node] = on
                else:
                    still.append((tt, node, on))
            pending = still
            t = float(b)
        raise ValueError(f"membership timeline exceeds {max_epochs} "
                         f"epochs")

    def realize_epochs(self, topo: Topology, K: int, *, seed: int = 0,
                       max_epochs: int = 64) -> EpochTrace:
        """Realize the scenario as an epochized trace: one validated
        (Topology, ScenarioTrace) per membership epoch, K events total.

        Regional-failure draws happen once up front; the membership
        timeline then fixes the epochs, the global event budget ``K`` is
        split across them in proportion to expected wake counts
        (duration × aggregate active wake rate, every epoch keeping at
        least one event), and each epoch realizes independently over its
        own topology in its own local clock (windows shifted, inactive
        nodes never wake).  Static scenarios return one epoch whose
        trace is bit-identical to :meth:`realize` — the oracle the
        epochized engine is pinned against.
        """
        rng = np.random.default_rng(seed)
        eff = self._effective_failures(rng)
        timeline = self._epoch_timeline(topo, eff, max_epochs=max_epochs)
        n = topo.n
        n_ep = len(timeline)
        if K < n_ep:
            raise ValueError(f"K={K} cannot cover {n_ep} epochs")
        base = self.node_compute(n)
        exp = [max(1.0, (t1 - t0) * float(np.sum(1.0 / base[act])))
               for (t0, t1, act, _e) in timeline[:-1]]
        ks = [max(1, int(round(v))) for v in exp]
        if sum(ks) > K - 1:          # budget overrun: rescale, floor 1
            scale = (K - n_ep) / max(1, sum(ks))
            ks = [max(1, int(v * scale)) for v in ks]
        ks.append(K - sum(ks))
        epochs: list[Epoch] = []
        k0 = 0
        prev_act: np.ndarray | None = None
        for e, ((t0, _t1, act, etopo), Ke) in enumerate(zip(timeline,
                                                            ks)):
            sd = seed if e == 0 else int(
                np.random.SeedSequence([seed, e]).generate_state(1)[0])
            trace = self._epoch_scenario(t0, eff).realize(etopo, Ke,
                                                          seed=sd)
            joined = (act & ~prev_act if prev_act is not None
                      else np.zeros(n, dtype=bool))
            departed = (prev_act & ~act if prev_act is not None
                        else np.zeros(n, dtype=bool))
            epochs.append(Epoch(topology=etopo, trace=trace,
                                t0=float(t0), k0=k0, joined=joined,
                                departed=departed,
                                root=int(etopo.common_roots[0])))
            k0 += Ke
            prev_act = act
        return EpochTrace(epochs=tuple(epochs), n=n, K=K)

    # ----------------------------------------------------------------- #
    # the asynchronous event clock (the only one in the repo)
    # ----------------------------------------------------------------- #
    def realize(self, topo: Topology, K: int, *, seed: int = 0) -> ScenarioTrace:
        """Simulate virtual clocks + network over ``topo`` for ``K`` events.

        Packets carry the sender's post-update stamp; a receiver always
        consumes the largest stamp delivered so far (the paper's ``tau``
        semantics), so per-edge stamps are monotone.  ``D_max`` enforces
        Assumption 3(ii): when loss/latency would push staleness past it,
        delivery is forced (the model excludes infinitely persistent
        loss).  With default parameters the RNG draw order is identical
        to the pre-refactor ``generate_schedule`` (golden-tested).
        """
        rng = np.random.default_rng(seed)
        n = topo.n
        base = self.node_compute(n)
        D_max = self.resolved_D_max(n)
        # regional draws (none by default — golden RNG order preserved),
        # then joins/leaves degraded to frozen-graph crash windows: this
        # path keeps the realize()-time graph, so membership can only
        # stall nodes, never rewire around them
        eff_failures = (self._effective_failures(rng)
                        + self._membership_windows())

        edges_w = topo.edges_W()
        edges_a = topo.edges_A()
        out_w: dict[int, list[int]] = {i: [] for i in range(n)}
        out_a: dict[int, list[int]] = {i: [] for i in range(n)}
        in_w: dict[int, list[int]] = {i: [] for i in range(n)}
        in_a: dict[int, list[int]] = {i: [] for i in range(n)}
        for e, (j, i) in enumerate(edges_w):
            out_w[j].append(e)
            in_w[i].append(e)
        for e, (j, i) in enumerate(edges_a):
            out_a[j].append(e)
            in_a[i].append(e)
        lat_w = self.edge_latency_of(edges_w)
        lat_a = self.edge_latency_of(edges_a)

        # per-edge arrival queues: (arrival_time, stamp); consumed in
        # stamp order (non-FIFO arrival allowed — max stamp arrived wins)
        arrivals_w: list[list[tuple[float, int]]] = [[] for _ in edges_w]
        arrivals_a: list[list[tuple[float, int]]] = [[] for _ in edges_a]
        best_w = np.zeros(len(edges_w), dtype=np.int64)
        best_a = np.zeros(len(edges_a), dtype=np.int64)

        clocks = rng.uniform(0.0, 1.0, n) * base
        # crash windows: push a node's first wake-up past the recovery time
        for (fn_, t0_, t1_) in eff_failures:
            if clocks[fn_] >= t0_:
                clocks[fn_] = max(clocks[fn_], t1_)
        # epoch-restricted topologies: inactive members never wake
        clocks[~topo.active_mask()] = np.inf
        ch_w = self.channels(len(edges_w), rng)
        ch_a = self.channels(len(edges_a), rng)

        agent = np.zeros(K, dtype=np.int32)
        stamp_v = np.zeros((K, max(1, len(edges_w))), dtype=np.int32)
        stamp_rho = np.zeros((K, max(1, len(edges_a))), dtype=np.int32)
        times = np.zeros(K, dtype=np.float64)
        send_ok_w = np.zeros((K, max(1, len(edges_w))), dtype=bool)
        send_ok_a = np.zeros((K, max(1, len(edges_a))), dtype=bool)
        max_delay = 0

        for k in range(K):
            a = int(np.argmin(clocks))
            now = float(clocks[a])
            if not np.isfinite(now):
                raise ValueError(
                    "every node left/crashed forever before realizing "
                    f"all {K} events (got {k})")
            agent[k] = a
            times[k] = now

            # consume: advance best stamp per in-edge from arrived packets
            for e in in_w[a]:
                q = arrivals_w[e]
                keep = []
                for (t_arr, s) in q:
                    if t_arr <= now:
                        if s > best_w[e]:
                            best_w[e] = s
                    else:
                        keep.append((t_arr, s))
                arrivals_w[e][:] = keep
                if k - best_w[e] > D_max:         # Assumption 3(ii)
                    best_w[e] = k - D_max
            for e in in_a[a]:
                q = arrivals_a[e]
                keep = []
                for (t_arr, s) in q:
                    if t_arr <= now:
                        if s > best_a[e]:
                            best_a[e] = s
                    else:
                        keep.append((t_arr, s))
                arrivals_a[e][:] = keep
                if k - best_a[e] > D_max:
                    best_a[e] = k - D_max

            stamp_v[k] = best_w if len(edges_w) else 0
            stamp_rho[k] = best_a if len(edges_a) else 0
            for e in in_w[a]:
                max_delay = max(max_delay, k - int(best_w[e]))
            for e in in_a[a]:
                max_delay = max(max_delay, k - int(best_a[e]))

            # send: node a finishes local iteration k, emits stamp k+1
            for e in out_w[a]:
                if ch_w.ok(e):
                    send_ok_w[k, e] = True
                    arrivals_w[e].append(
                        (now + rng.exponential(lat_w[e]), k + 1))
            for e in out_a[a]:
                if ch_a.ok(e):
                    send_ok_a[k, e] = True
                    arrivals_a[e].append(
                        (now + rng.exponential(lat_a[e]), k + 1))

            step = base[a] * self.slow_factor(a, now)
            clocks[a] = now + step * (1.0 + rng.uniform(-self.jitter,
                                                        self.jitter))
            for (fn_, t0_, t1_) in eff_failures:
                if fn_ == a and t0_ <= clocks[a] < t1_:
                    clocks[a] = t1_       # crash: sleep through the window

        schedule = Schedule(
            agent=agent,
            stamp_v=stamp_v,
            stamp_rho=stamp_rho,
            times=times,
            D=int(max(1, max_delay)),
            T=_realized_T(agent, n),
        )
        return ScenarioTrace(schedule=schedule, send_ok_w=send_ok_w,
                             send_ok_a=send_ok_a)

    # ----------------------------------------------------------------- #
    # the synchronous barrier clock (same primitives, same model)
    # ----------------------------------------------------------------- #
    def sync_round_times(self, topo: Topology | int, rounds: int, *,
                         seed: int = 0, max_retries: int = 50) -> np.ndarray:
        """Cumulative virtual completion time of ``rounds`` barrier rounds.

        Round ``r`` starting at barrier time ``t`` ends at::

            max_i compute_i(t)  +  max_e retransmit_latency_e

        where ``compute_i`` draws from node ``i``'s profile (straggler
        windows apply, crash windows stall the barrier until recovery —
        the synchronous cost of a failure) and each edge redraws its
        latency until the loss channel delivers (at most ``max_retries``
        tries; bursty channels cannot stall a barrier forever).

        ``topo`` may be an ``int`` node count (e.g. Ring-AllReduce): the
        communication graph is then taken as the n-edge directed ring.

        Dynamic membership stalls-and-rewires the barrier too, so the
        showdown rows stay fair against the epochized async engines: a
        round's participants are the members at the round's start; a
        node leaving mid-round caps its contribution at its leave time;
        edges with a non-member endpoint are skipped; fired regional
        groups stall like any other crash window.
        """
        rng = np.random.default_rng(seed)
        if isinstance(topo, int):
            n = topo
            edges = [(i, (i + 1) % n) for i in range(n)]
        else:
            n = topo.n
            edges = sorted(set(topo.edges_W()) | set(topo.edges_A()))
        eff_failures = self._effective_failures(rng)
        join_t = {int(j): float(tj) for (j, tj) in self.joins}
        leave_t = {int(j): float(tj) for (j, tj) in self.leaves}

        def member(i: int, at: float) -> bool:
            return join_t.get(i, 0.0) <= at < leave_t.get(i, np.inf)

        base = self.node_compute(n)
        lat = self.edge_latency_of(edges)
        ch = self.channels(len(edges), rng)

        times = np.zeros(rounds, dtype=np.float64)
        t = 0.0
        for r in range(rounds):
            if not any(member(i, t) for i in range(n)):
                nxt = min((tj for tj in join_t.values() if tj > t),
                          default=None)
                if nxt is None:       # empty forever: clock stops
                    times[r:] = t
                    return times
                t = nxt
            finish = t
            for i in range(n):
                if not member(i, t):
                    continue
                step = base[i] * self.slow_factor(i, t)
                f_i = t + step * (1.0 + rng.uniform(-self.jitter, self.jitter))
                # a crash window overlapping the work stalls the barrier
                for (fn_, t0_, t1_) in eff_failures:
                    if fn_ == i and t0_ < f_i and t1_ > t:
                        f_i = max(f_i, t1_)
                # leaving mid-round cuts the contribution off, not the
                # barrier: survivors re-form without the departed node
                if i in leave_t:
                    f_i = min(f_i, max(t, leave_t[i]))
                finish = max(finish, f_i)
            comm = 0.0
            for e, (j, i) in enumerate(edges):
                if not (member(j, t) and member(i, t)):
                    continue
                t_e = rng.exponential(lat[e])
                tries = 1
                while not ch.ok(e) and tries < max_retries:
                    t_e += rng.exponential(lat[e])
                    tries += 1
                comm = max(comm, t_e)
            t = finish + comm
            times[r] = t
        return times


# --------------------------------------------------------------------- #
# named scenarios (the benchmark suite's shared vocabulary)
# --------------------------------------------------------------------- #
def _uniform(n: int) -> NetworkScenario:
    return NetworkScenario(latency=0.3, name="uniform")


def _straggler(n: int) -> NetworkScenario:
    compute = np.ones(n)
    compute[-1] = 4.0
    return NetworkScenario(compute_time=tuple(compute), latency=0.3,
                           name="straggler")


def _flaky_straggler(n: int) -> NetworkScenario:
    """Time-varying: the last node runs 6x slow in two windows."""
    s = n - 1
    return NetworkScenario(
        latency=0.3,
        stragglers=((s, 100.0, 300.0, 6.0), (s, 600.0, 800.0, 6.0)),
        name="flaky_straggler")


def _packet_loss(n: int) -> NetworkScenario:
    return NetworkScenario(latency=0.3, loss=0.2, name="packet_loss")


def _bursty_loss(n: int) -> NetworkScenario:
    # ~20% stationary loss in bursts of mean length 10 packets
    return NetworkScenario(
        latency=0.3,
        gilbert_elliott=GilbertElliott(p_gb=0.025, p_bg=0.1),
        name="bursty_loss")


def _crash_recovery(n: int) -> NetworkScenario:
    """Two nodes crash (disjoint windows) and recover."""
    return NetworkScenario(
        latency=0.3,
        failures=((n - 1, 150.0, 280.0), (max(0, n // 2), 450.0, 560.0)),
        name="crash_recovery")


def _churn(n: int) -> NetworkScenario:
    """Dynamic membership: a late joiner and a permanent departure give
    a 3-epoch timeline (without joiner / full / without leaver)."""
    return NetworkScenario(
        latency=0.3,
        joins=((max(1, n - 2), 40.0),),
        leaves=((n - 1, 90.0),),
        name="churn")


def _regional_failure(n: int) -> NetworkScenario:
    """Correlated failures: one draw crashes a whole 'rack' together —
    a certain back-of-fleet outage plus a coin-flip repeat."""
    rack = tuple(range(max(1, n - max(2, n // 3)), n))
    return NetworkScenario(
        latency=0.3,
        regional_failures=((rack, 60.0, 120.0, 1.0),
                           (rack, 200.0, 230.0, 0.5)),
        name="regional_failure")


def _root_failover(n: int) -> NetworkScenario:
    """The Assumption-2 stress test: node 0 — the SOLE common root of
    the tree topologies — departs permanently mid-run.  Epochized runs
    re-elect a surviving root and keep converging (pair with
    ``robust_tree``, whose sibling rungs keep the skeleton connected);
    frozen-plan runs stall on the dead root.  The departure lands early
    (t=30, mid-descent at benchmark scale) so the post-crash regime
    dominates the trace and the stall is unambiguous."""
    return NetworkScenario(latency=0.3, leaves=((0, 30.0),),
                           name="root_failover")


SCENARIOS: dict[str, Callable[[int], NetworkScenario]] = {
    "uniform": _uniform,
    "straggler": _straggler,
    "flaky_straggler": _flaky_straggler,
    "packet_loss": _packet_loss,
    "bursty_loss": _bursty_loss,
    "crash_recovery": _crash_recovery,
    "churn": _churn,
    "regional_failure": _regional_failure,
    "root_failover": _root_failover,
}


def get_scenario(name: str, n: int) -> NetworkScenario:
    """Named scenario for an ``n``-node deployment (see :data:`SCENARIOS`)."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    return SCENARIOS[name](n)


def realize_batch(
    topo: Topology, K: int, *,
    scenario: NetworkScenario | str | None = None,
    scenarios: Sequence[NetworkScenario | str] | None = None,
    seeds: Sequence[int] = (0,),
) -> list[ScenarioTrace]:
    """Realize a fleet of independent :class:`ScenarioTrace` lanes.

    Exactly one of ``scenario`` (one scenario × many seeds) or
    ``scenarios`` (a sweep — e.g. names from the :data:`SCENARIOS`
    registry — crossed with ``seeds``) must be given; strings resolve
    through :func:`get_scenario` for ``topo.n``.  Lane order is
    scenario-major, seed-minor.  Every lane shares ``topo`` and ``K``,
    so the result feeds :func:`repro.core.simulator.run_sweep` directly
    (lane ``i * len(seeds) + j`` carries scenario ``i``, seed
    ``seeds[j]``); mixed-topology fleets realize per topology and
    concatenate.
    """
    if (scenario is None) == (scenarios is None):
        raise ValueError("pass exactly one of scenario= or scenarios=")
    if scenario is not None:
        scenarios = [scenario]
    resolved = [get_scenario(sc, topo.n) if isinstance(sc, str) else sc
                for sc in scenarios]
    return [sc.realize(topo, K, seed=int(seed))
            for sc in resolved for seed in seeds]


def realize_epochs_batch(
    topo: Topology, K: int, *,
    scenario: NetworkScenario | str | None = None,
    scenarios: Sequence[NetworkScenario | str] | None = None,
    seeds: Sequence[int] = (0,),
) -> list[EpochTrace]:
    """:func:`realize_batch` for epochized traces: one
    :class:`EpochTrace` per (scenario, seed) lane, scenario-major —
    the input of :func:`repro.core.simulator.run_sweep_epochs`.  Note
    the epoch *timelines* of a fleet may differ per lane (regional
    draws are per-seed)."""
    if (scenario is None) == (scenarios is None):
        raise ValueError("pass exactly one of scenario= or scenarios=")
    if scenario is not None:
        scenarios = [scenario]
    resolved = [get_scenario(sc, topo.n) if isinstance(sc, str) else sc
                for sc in scenarios]
    return [sc.realize_epochs(topo, K, seed=int(seed))
            for sc in resolved for seed in seeds]
