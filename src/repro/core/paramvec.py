"""The flat-parameter substrate: any model pytree as one ``(p,)`` lane.

The asynchronous engines (:mod:`repro.core.simulator`) run Algorithm 2
over flat per-node parameter vectors — their :class:`PackedState` fuses
``x/v/z/g_prev`` into ``(n, 4, p)`` rows and commits O(p) history deltas
per event.  Real models are pytrees.  This module owns the bridge, in
both directions:

* :class:`RavelSpec` — a static flatten/unflatten plan for a pytree:
  per-leaf shapes/dtypes/offsets, a working dtype for the flat buffer
  (protocol state accumulates in fp32 regardless of the model's leaf
  dtypes), and tail padding to a lane multiple (``pad_to=128`` keeps
  the fused ``kernels/rfast_update`` commit kernel's ``(R, 128)``
  block layout aligned).  :func:`ravel` / :func:`unravel` are traced
  jnp ops — they compose with jit/vmap/scan, so the model can be
  rebuilt *inside* an engine's gradient call.
* :class:`GradProvider` — the protocol every objective speaks to the
  engines: ``n`` nodes, flat dimension ``p``, and ``grad_fn()``
  returning the traced ``(i, x_flat, key) -> g_flat`` the engines
  consume.  ``repro.data.objectives.LogisticProblem`` already conforms
  structurally; :class:`ModelGradProvider` makes any
  ``(params, batch, key) -> (loss, grads)`` model gradient conform.
* :func:`as_grad_fn` — the single resolution point the engines call:
  a bare callable passes through untouched (the pre-substrate API,
  kept bit-exact), a provider contributes its ``grad_fn()``.

All protocol operations (S.1–S.5) are linear in the parameter lane, so
zero-padded tail entries stay exactly zero through descent, consensus,
tracking, and the ρ running sums — padding is invisible to the
algorithm and to Lemma 3.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RavelSpec", "make_ravel_spec", "ravel", "unravel",
    "GradProvider", "ModelGradProvider", "as_grad_fn",
]

FlatGradFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]
# (node_id, x_flat, rng_key) -> g_flat, all traced.


# --------------------------------------------------------------------- #
# ravel / unravel
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RavelSpec:
    """Static plan flattening one pytree layout to a ``(p,)`` buffer.

    ``p`` includes the tail padding (``p = ceil(p_model / pad_to) *
    pad_to``); ``p_model`` is the true parameter count.  The spec is
    hashable-by-identity and closed over by traced code — build it once
    per model, outside jit.
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    offsets: tuple[int, ...]        # start of each leaf in the flat buffer
    p_model: int
    p: int
    pad_to: int
    dtype: Any                      # working dtype of the flat buffer

    def __repr__(self) -> str:      # keep tracebacks readable
        return (f"RavelSpec(leaves={len(self.shapes)}, "
                f"p_model={self.p_model}, p={self.p}, "
                f"pad_to={self.pad_to}, dtype={jnp.dtype(self.dtype).name})")


def make_ravel_spec(tree: Any, *, pad_to: int = 1,
                    dtype=jnp.float32) -> RavelSpec:
    """Build the flatten/unflatten plan for ``tree``'s layout.

    ``pad_to``: round the flat dimension up to this multiple (128 aligns
    the fused commit kernel's lane layout; 1 = no padding).
    ``dtype``: the flat buffer's working dtype — the protocol state
    accumulates in it; :func:`unravel` casts each leaf back to its own
    stored dtype.
    """
    if pad_to < 1:
        raise ValueError(f"pad_to must be >= 1, got {pad_to}")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    offsets = tuple(int(o) for o in np.concatenate([[0],
                                                    np.cumsum(sizes)[:-1]]))
    p_model = int(sum(sizes))
    p = -(-p_model // pad_to) * pad_to
    return RavelSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                     offsets=offsets, p_model=p_model, p=p, pad_to=pad_to,
                     dtype=jnp.dtype(dtype))


def ravel(spec: RavelSpec, tree: Any) -> jnp.ndarray:
    """Pytree -> ``(spec.p,)`` flat buffer (cast to the working dtype,
    zero tail padding).  Traced: usable inside jit/vmap/scan."""
    leaves = jax.tree_util.tree_leaves(tree)
    if len(leaves) != len(spec.shapes):
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{len(spec.shapes)}")
    flat = jnp.concatenate(
        [jnp.reshape(l, (-1,)).astype(spec.dtype) for l in leaves])
    if spec.p != spec.p_model:
        flat = jnp.pad(flat, (0, spec.p - spec.p_model))
    return flat


def unravel(spec: RavelSpec, vec: jnp.ndarray) -> Any:
    """``(spec.p,)`` flat buffer -> pytree (leaf dtypes restored).
    Traced: usable inside jit/vmap/scan."""
    leaves = []
    for shape, dt, off in zip(spec.shapes, spec.dtypes, spec.offsets):
        size = int(np.prod(shape)) if shape else 1
        leaf = jax.lax.dynamic_slice_in_dim(vec, off, size)
        leaves.append(jnp.reshape(leaf, shape).astype(dt))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# --------------------------------------------------------------------- #
# the provider protocol
# --------------------------------------------------------------------- #
@runtime_checkable
class GradProvider(Protocol):
    """What an objective must expose to drive the flat-vector engines.

    ``n`` — number of nodes (problem (1)'s local distributions D_i),
    ``p`` — flat parameter dimension, ``grad_fn()`` — the traced
    ``(i, x_flat, key) -> g_flat`` update the engines consume.
    ``LogisticProblem`` and ``LMProblem`` both conform.
    """

    @property
    def n(self) -> int: ...

    @property
    def p(self) -> int: ...

    def grad_fn(self) -> FlatGradFn: ...


def as_grad_fn(objective: FlatGradFn | GradProvider) -> FlatGradFn:
    """The engines' single objective-resolution point.

    A bare callable is the pre-substrate API and passes through
    untouched (bit-exact compatibility); anything exposing
    ``grad_fn()`` contributes that.
    """
    if callable(objective) and not hasattr(objective, "grad_fn"):
        return objective
    if hasattr(objective, "grad_fn"):
        return objective.grad_fn()
    raise TypeError(
        f"objective must be a (i, x_flat, key) -> g_flat callable or a "
        f"GradProvider with .grad_fn(); got {type(objective).__name__}")


# --------------------------------------------------------------------- #
# model gradients as a provider
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ModelGradProvider:
    """Wrap a model's ``(params, batch, key) -> (loss, grads)`` into the
    flat ``(i, x_flat, key) -> g_flat`` engine signature.

    ``batch_fn(i, key) -> batch`` must be traced (device-side sampling
    or a gather from pre-staged arrays): the engines call ``grad_fn``
    inside ``lax.scan``/``vmap``, so no host work can happen per event.
    The per-event ``key`` is split between batch sampling and the
    model's own stochasticity (dropout etc.); the node id is folded into
    the batch key so nodes draw from distinct shard streams even when a
    caller hands every node the same key.
    """

    spec: RavelSpec
    n_nodes: int
    value_and_grad: Callable[[Any, Any, jax.Array], tuple[jnp.ndarray, Any]]
    batch_fn: Callable[[jnp.ndarray, jax.Array], Any]

    @property
    def n(self) -> int:
        return self.n_nodes

    @property
    def p(self) -> int:
        return self.spec.p

    def grad_fn(self) -> FlatGradFn:
        spec, vg, batch_fn = self.spec, self.value_and_grad, self.batch_fn

        def gfn(i, x_flat, key):
            params = unravel(spec, x_flat)
            bkey, gkey = jax.random.split(key)
            batch = batch_fn(i, jax.random.fold_in(bkey, i))
            _, grads = vg(params, batch, gkey)
            return ravel(spec, grads)

        return gfn
