"""Production R-FAST runtime: the protocol wrapped around a sharded model
``train_step`` on the (pod, data, model) mesh.

Node granularity (DESIGN.md §3.4): one R-FAST node = one slice of the
``node_axes`` mesh axes; each node holds its OWN model replica x_i
(stacked on a leading N axis, sharded over ``node_axes``), plus the
protocol state:

  z       (N, …)  gradient-tracking variable
  g_prev  (N, …)  last sampled local gradient (cleared out in S2b)
  rho     (E, …)  running sums ρ_{ji} per A-edge   (padded to E_pad)
  rho_buf (E, …)  receiver buffers ρ̃_{ij}
  mail_v  (E, …)  consensus mailboxes (robust mode only)

Execution is *bounded-staleness SPMD rounds*: every round runs S1–S5 for
all nodes; per-edge ``masks`` gate delivery (0 = packet lost/late — the
receiver reuses its mailbox copy and the ρ running sums recover the mass
on the next success).  ``masks=None`` (or all-ones) is the synchronous
special case of Remark 2 — the path used by the dry-run.

This module is an *engine shell*: it owns the mesh/vmap concerns (the
per-node gradient runs under ``jax.vmap(..., spmd_axis_name=node_axes)``
so the model's logical sharding annotations compose with the node axis)
and delegates all protocol math to :mod:`repro.core.protocol` over a
:class:`repro.core.plan.CommPlan`.  ``impl="pallas"`` routes the state
commit through the fused ``kernels/rfast_update`` Pallas kernel.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from .plan import CommPlan, build_comm_plan
from .protocol import (ProtocolState, init_protocol_state,
                       make_protocol_round, protocol_tracked_mass)
from .topology import Topology

__all__ = ["RFASTNodeState", "RuntimeSpec", "make_rfast_round",
           "init_node_state", "edge_arrays", "runtime_tracked_mass"]

GradFn = Callable[[Any, Any, jax.Array], tuple[jnp.ndarray, Any]]
# per-node: (params, batch, key) -> (loss, grads)

# The runtime's state and static-spec types ARE the protocol's; the old
# names remain the public API of this engine.
RFASTNodeState = ProtocolState
RuntimeSpec = CommPlan


def edge_arrays(topo: Topology, e_pad: int | None = None) -> CommPlan:
    """Topology -> CommPlan (kept name: the runtime's static spec)."""
    return build_comm_plan(topo, e_pad)


def _make_vgrads(grad_fn: GradFn, node_axes: Sequence[str]):
    """Node-vmapped gradient: (x, batches, keys) -> (losses, grads)."""
    spmd = None
    if node_axes:
        spmd = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]
    f = lambda p, b, k: grad_fn(p, b, k)
    if spmd is not None:
        return jax.vmap(f, spmd_axis_name=spmd)
    return jax.vmap(f)


def init_node_state(
    spec: CommPlan,
    params: Any,
    grad_fn: GradFn,
    batches: Any,            # (N, ...) pytree: each node's first batch
    key: jax.Array,
    *,
    node_axes: Sequence[str] = (),
    robust: bool = False,
    momentum: float = 0.0,
    stacked: bool = False,
) -> RFASTNodeState:
    """Paper init: x_i = x0 (broadcast), z_i = g_prev_i = ∇f_i(x0; ζ0)."""
    vgrads = _make_vgrads(grad_fn, node_axes)
    keys = jax.random.split(key, spec.n)
    return init_protocol_state(spec, params, vgrads, batches, keys,
                               robust=robust, momentum=momentum,
                               stacked=stacked)


def make_rfast_round(
    spec: CommPlan,
    grad_fn: GradFn,
    *,
    gamma,
    node_axes: Sequence[str] = (),
    robust: bool = False,
    momentum: float = 0.0,
    impl: str = "jnp",
    interpret: bool | None = None,
    donate: bool = False,
):
    """Build ``round_fn(state, batches, keys, masks) -> (state, metrics)``.

    ``batches``: (N, ...) pytree of per-node minibatches.
    ``masks``: (E_pad,) float deliveries for BOTH graphs (1 = delivered) or
    None for the synchronous special case.  ``gamma`` may be a schedule.
    ``impl``: "jnp" (GSPMD dense mixing) or "pallas" (fused update kernel).
    ``donate=True`` jits the round with the state donated (in-place
    x/z/ρ/ρ̃ commits; callers must rebind and not reuse the old state).
    """
    vgrads = _make_vgrads(grad_fn, node_axes)
    return make_protocol_round(spec, vgrads, gamma=gamma, robust=robust,
                               momentum=momentum, impl=impl,
                               interpret=interpret, donate=donate)


# --------------------------------------------------------------------- #
# Lemma-3 invariant on runtime state (tested under loss masks)
# --------------------------------------------------------------------- #
runtime_tracked_mass = protocol_tracked_mass
