"""Production R-FAST runtime: the protocol wrapped around a sharded model
``train_step`` on the (pod, data, model) mesh.

Node granularity (DESIGN.md §3.4): one R-FAST node = one slice of the
``node_axes`` mesh axes; each node holds its OWN model replica x_i
(stacked on a leading N axis, sharded over ``node_axes``), plus the
protocol state:

  z       (N, …)  gradient-tracking variable
  g_prev  (N, …)  last sampled local gradient (cleared out in S2b)
  rho     (E, …)  running sums ρ_{ji} per A-edge   (padded to E_pad)
  rho_buf (E, …)  receiver buffers ρ̃_{ij}
  mail_v  (E, …)  consensus mailboxes (robust mode only)

Execution is *bounded-staleness SPMD rounds*: every round runs S1–S5 for
all nodes; per-edge ``masks`` gate delivery (0 = packet lost/late — the
receiver reuses its mailbox copy and the ρ running sums recover the mass
on the next success).  ``masks=None`` (or all-ones) is the synchronous
special case of Remark 2 — the path used by the dry-run.

Intra-node model parallelism is GSPMD: the per-node gradient is computed
under ``jax.vmap(..., spmd_axis_name=node_axes)`` so the model's logical
sharding annotations ('model', and 'data' when nodes live on the pod
axis) compose with the node axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .topology import Topology

__all__ = ["RFASTNodeState", "RuntimeSpec", "make_rfast_round",
           "init_node_state", "edge_arrays"]

GradFn = Callable[[Any, Any, jax.Array], tuple[jnp.ndarray, Any]]
# per-node: (params, batch, key) -> (loss, grads)


class RFASTNodeState(NamedTuple):
    step: jnp.ndarray
    x: Any          # (N, ...) pytree
    z: Any
    g_prev: Any
    rho: Any        # (E_pad, ...) pytree
    rho_buf: Any
    mail_v: Any     # (E_pad, ...) pytree or None (sync mode)
    m: Any          # momentum buffers or None


@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """Static protocol data extracted from a Topology, padded for sharding."""
    n: int
    e_pad: int
    w_diag: np.ndarray   # (N,)
    a_diag: np.ndarray   # (N,)
    src_w: np.ndarray; dst_w: np.ndarray; w_edge: np.ndarray  # (E_pad,)
    src_a: np.ndarray; dst_a: np.ndarray; a_edge: np.ndarray  # (E_pad,)


def edge_arrays(topo: Topology, e_pad: int | None = None) -> RuntimeSpec:
    ew, ea = topo.edges_W(), topo.edges_A()
    E = max(len(ew), len(ea), 1)
    e_pad = e_pad or max(topo.n, -(-E // topo.n) * topo.n)

    def pack(edges, M):
        src = np.zeros(e_pad, np.int32)
        dst = np.zeros(e_pad, np.int32)
        wt = np.zeros(e_pad, np.float32)
        for i, (j, k) in enumerate(edges):
            src[i], dst[i], wt[i] = j, k, M[k, j]
        return src, dst, wt

    sw, dw, we = pack(ew, topo.W)
    sa, da, ae = pack(ea, topo.A)
    return RuntimeSpec(
        n=topo.n, e_pad=e_pad,
        w_diag=np.diag(topo.W).astype(np.float32),
        a_diag=np.diag(topo.A).astype(np.float32),
        src_w=sw, dst_w=dw, w_edge=we,
        src_a=sa, dst_a=da, a_edge=ae,
    )


def _stack_n(tree: Any, n: int) -> Any:
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), tree)


def init_node_state(
    spec: RuntimeSpec,
    params: Any,
    grad_fn: GradFn,
    batches: Any,            # (N, ...) pytree: each node's first batch
    key: jax.Array,
    *,
    node_axes: Sequence[str] = (),
    robust: bool = False,
    momentum: float = 0.0,
    stacked: bool = False,
) -> RFASTNodeState:
    """Paper init: x_i = x0 (broadcast), z_i = g_prev_i = ∇f_i(x0; ζ0)."""
    n, e = spec.n, spec.e_pad
    x = params if stacked else _stack_n(params, n)
    keys = jax.random.split(key, n)
    vg = jax.vmap(lambda p, b, k: grad_fn(p, b, k)[1])
    if node_axes:
        vg = jax.vmap(lambda p, b, k: grad_fn(p, b, k)[1],
                      spmd_axis_name=tuple(node_axes) if len(node_axes) > 1
                      else node_axes[0])
    g0 = vg(x, batches, keys)
    zeros_e = jax.tree.map(
        lambda l: jnp.zeros((e,) + l.shape[1:], l.dtype), x)
    return RFASTNodeState(
        step=jnp.zeros((), jnp.int32),
        x=x, z=g0, g_prev=g0,
        rho=zeros_e,
        rho_buf=jax.tree.map(jnp.copy, zeros_e),
        mail_v=jax.tree.map(jnp.copy, zeros_e) if robust else None,
        m=jax.tree.map(jnp.zeros_like, x) if momentum else None,
    )


def make_rfast_round(
    spec: RuntimeSpec,
    grad_fn: GradFn,
    *,
    gamma,
    node_axes: Sequence[str] = (),
    robust: bool = False,
    momentum: float = 0.0,
):
    """Build ``round_fn(state, batches, keys, masks) -> (state, metrics)``.

    ``batches``: (N, ...) pytree of per-node minibatches.
    ``masks``: (E_pad,) float deliveries for BOTH graphs (1 = delivered) or
    None for the synchronous special case.  ``gamma`` may be a schedule.
    """
    n = spec.n
    w_diag = jnp.asarray(spec.w_diag)
    a_diag = jnp.asarray(spec.a_diag)
    src_w = jnp.asarray(spec.src_w); dst_w = jnp.asarray(spec.dst_w)
    src_a = jnp.asarray(spec.src_a); dst_a = jnp.asarray(spec.dst_a)
    w_edge = jnp.asarray(spec.w_edge); a_edge = jnp.asarray(spec.a_edge)

    spmd = None
    if node_axes:
        spmd = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]

    def vgrads(x, batches, keys):
        f = lambda p, b, k: grad_fn(p, b, k)
        if spmd is not None:
            return jax.vmap(f, spmd_axis_name=spmd)(x, batches, keys)
        return jax.vmap(f)(x, batches, keys)

    def round_fn(state: RFASTNodeState, batches, keys, masks=None):
        lr = gamma(state.step) if callable(gamma) else gamma

        # ---- (S1) local descent direction -------------------------------
        if momentum:
            m = jax.tree.map(lambda mm, zz: momentum * mm + zz,
                             state.m, state.z)
            v = jax.tree.map(lambda xx, mm: xx - lr * mm, state.x, m)
        else:
            m = None
            v = jax.tree.map(lambda xx, zz: xx - lr * zz, state.x, state.z)

        # ---- (S2a) consensus pull over G(W) ------------------------------
        if masks is None and not robust:
            def mix_x(vl):
                out = w_diag.reshape((n,) + (1,) * (vl.ndim - 1)) * vl
                contrib = w_edge.reshape((-1,) + (1,) * (vl.ndim - 1)) \
                    * vl[src_w]
                return out.at[dst_w].add(contrib.astype(out.dtype))
            x_new = jax.tree.map(mix_x, v)
            mail_v = state.mail_v
        else:
            mk = jnp.ones((spec.e_pad,), jnp.float32) if masks is None else masks
            def mix_robust(vl, ml):
                mshape = (-1,) + (1,) * (vl.ndim - 1)
                mkr = mk.reshape(mshape)
                recv = mkr * vl[src_w] + (1 - mkr) * ml
                out = w_diag.reshape((n,) + (1,) * (vl.ndim - 1)) * vl
                contrib = w_edge.reshape(mshape) * recv
                return out.at[dst_w].add(contrib.astype(out.dtype)), recv
            pairs = jax.tree.map(mix_robust, v, state.mail_v)
            x_new = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda q: isinstance(q, tuple))
            mail_v = jax.tree.map(lambda p: p[1], pairs,
                                  is_leaf=lambda q: isinstance(q, tuple))

        # ---- (S2b) new gradient sample + robust tracking ------------------
        losses, g_new = vgrads(x_new, batches, keys)

        mk = jnp.ones((spec.e_pad,), jnp.float32) if masks is None else masks

        def track(zl, gl_new, gl_old, rho_l, buf_l):
            mshape = (-1,) + (1,) * (zl.ndim - 1)
            mkr = mk.reshape(mshape)
            diff = (mkr * (rho_l - buf_l)).astype(zl.dtype)
            recv = jnp.zeros_like(zl).at[dst_a].add(diff)
            z_half = zl + recv + gl_new - gl_old
            # (S2c) split mass
            z_new = a_diag.reshape((n,) + (1,) * (zl.ndim - 1)) * z_half
            push = a_edge.reshape(mshape) * z_half[src_a]
            rho_new = rho_l + push.astype(rho_l.dtype)
            # (S4) buffers take consumed values
            buf_new = mkr * rho_l + (1 - mkr) * buf_l
            return z_new, rho_new, buf_new

        trip = jax.tree.map(track, state.z, g_new, state.g_prev,
                            state.rho, state.rho_buf)
        is3 = lambda q: isinstance(q, tuple)
        z_new = jax.tree.map(lambda t: t[0], trip, is_leaf=is3)
        rho_new = jax.tree.map(lambda t: t[1], trip, is_leaf=is3)
        buf_new = jax.tree.map(lambda t: t[2], trip, is_leaf=is3)

        new_state = RFASTNodeState(
            step=state.step + 1, x=x_new, z=z_new, g_prev=g_new,
            rho=rho_new, rho_buf=buf_new, mail_v=mail_v, m=m)
        return new_state, {"loss": losses.mean(), "losses": losses}

    return round_fn


# --------------------------------------------------------------------- #
# Lemma-3 invariant on runtime state (tested under loss masks)
# --------------------------------------------------------------------- #
def runtime_tracked_mass(state: RFASTNodeState):
    tot_z = jax.tree.map(lambda z: z.sum(0), state.z)
    inflight = jax.tree.map(lambda r, b: (r - b).sum(0),
                            state.rho, state.rho_buf)
    return jax.tree.map(lambda a, b: a + b, tot_z, inflight)
