"""Baseline algorithms the paper compares against (Table II / Fig. 5-6).

Synchronous: Ring-AllReduce SGD [12], D-PSGD [14], S-AB [17] (two-matrix
synchronous gradient tracking — the synchronous push-pull recursion (2)),
plus ``push_pull_sync`` itself (eq. (2), the deterministic ancestor of
R-FAST).

Asynchronous: AD-PSGD [22] (atomic pairwise averaging + stale gradients)
and OSGP [23] (overlap stochastic gradient push: push-sum with mailbox
accumulation and non-blocking sends).

All baselines share the simulator's ``grad_fn(node, x, key)`` interface and
a **virtual-time model** so that time-to-loss comparisons under stragglers
are meaningful: synchronous rounds cost ``max_i compute_i`` (barrier),
asynchronous events follow each node's own clock.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .protocol import descent_step, tracking_step
from .topology import Topology

GradFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]

__all__ = [
    "sync_round_times",
    "run_push_pull_sync",
    "run_ring_allreduce",
    "run_dpsgd",
    "run_sab",
    "run_adpsgd",
    "run_osgp",
    "metropolis_weights",
]


# --------------------------------------------------------------------- #
# virtual time for synchronous rounds
# --------------------------------------------------------------------- #
def sync_round_times(
    compute_time: np.ndarray,
    rounds: int,
    *,
    jitter: float = 0.2,
    comm: float = 0.1,
    seed: int = 0,
) -> np.ndarray:
    """Cumulative virtual time of synchronous rounds (barrier = max)."""
    rng = np.random.default_rng(seed)
    n = len(compute_time)
    per = compute_time[None, :] * (1.0 + rng.uniform(-jitter, jitter, (rounds, n)))
    return np.cumsum(per.max(axis=1) + comm)


def metropolis_weights(topo: Topology) -> np.ndarray:
    """Doubly-stochastic weights for an undirected graph (D-PSGD)."""
    n = topo.n
    adj = ((topo.W > 0) | (topo.W.T > 0)) & ~np.eye(n, dtype=bool)
    deg = adj.sum(axis=1)
    Wm = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if adj[i, j]:
                Wm[i, j] = 1.0 / (1 + max(deg[i], deg[j]))
        Wm[i, i] = 1.0 - Wm[i].sum()
    return Wm


def _vgrads(grad_fn: GradFn, x: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    n = x.shape[0]
    keys = jax.random.split(key, n)
    return jax.vmap(grad_fn)(jnp.arange(n), x, keys)


# --------------------------------------------------------------------- #
# synchronous baselines
# --------------------------------------------------------------------- #
def _run_rounds(round_fn, carry, rounds: int, seed: int,
                eval_every: int, eval_fn, times: np.ndarray):
    key = jax.random.PRNGKey(seed)
    metrics: list[dict] = []
    jfn = jax.jit(round_fn)
    for t in range(rounds):
        key, sub = jax.random.split(key)
        carry = jfn(carry, sub)
        if eval_fn is not None and (t + 1) % eval_every == 0:
            m = eval_fn(carry, float(times[t]))
            m["round"] = t + 1
            metrics.append(m)
    return carry, metrics


def run_push_pull_sync(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float,
    rounds: int, *, seed: int = 0, eval_every: int = 10,
    eval_fn=None, times: np.ndarray | None = None,
):
    """Synchronous push-pull (eq. 2): the paper's S-AB-style ancestor.

    x^{t+1} = W (x^t − γ z^t);  z^{t+1} = A z^t + ∇F(x^{t+1}) − ∇F(x^t).

    The per-round formulas are the protocol core's S.1/S.2b steps in
    matrix form (``recv = 0``: mixing happens through A z, not running
    sums) — eq. (2) is the all-delivered, zero-delay limit of R-FAST.
    """
    n = topo.n
    W = jnp.asarray(topo.W, jnp.float32)
    A = jnp.asarray(topo.A, jnp.float32)
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))
    g0 = _vgrads(grad_fn, x0, jax.random.PRNGKey(seed + 1))
    if times is None:
        times = np.arange(1, rounds + 1, dtype=np.float64)

    def round_fn(carry, key):
        x, z, g = carry
        x_new = W @ descent_step(x, z, gamma)                  # S.1 + S.2a
        g_new = _vgrads(grad_fn, x_new, key)
        z_new = tracking_step(A @ z, 0.0, g_new, g)            # S.2b
        return (x_new, z_new, g_new)

    carry, metrics = _run_rounds(round_fn, (x0, g0, g0), rounds, seed,
                                 eval_every, eval_fn, times)
    return carry[0], metrics


def run_sab(topo: Topology, grad_fn: GradFn, x0, gamma, rounds, **kw):
    """S-AB [17]: synchronous stochastic gradient tracking with a
    row-stochastic and a column-stochastic matrix — identical recursion to
    synchronous push-pull over a strongly-connected digraph."""
    return run_push_pull_sync(topo, grad_fn, x0, gamma, rounds, **kw)


def run_ring_allreduce(
    n: int, grad_fn: GradFn, x0: jnp.ndarray, gamma: float, rounds: int,
    *, seed: int = 0, eval_every: int = 10, eval_fn=None,
    times: np.ndarray | None = None,
):
    """Ring-AllReduce SGD: exact gradient average per round (single model)."""
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 2:
        x0 = x0[0]
    if times is None:
        times = np.arange(1, rounds + 1, dtype=np.float64)

    def round_fn(x, key):
        g = _vgrads(grad_fn, jnp.tile(x[None], (n, 1)), key)
        return x - gamma * g.mean(axis=0)

    x, metrics = _run_rounds(round_fn, x0, rounds, seed, eval_every,
                             eval_fn, times)
    return x, metrics


def run_dpsgd(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float,
    rounds: int, *, seed: int = 0, eval_every: int = 10, eval_fn=None,
    times: np.ndarray | None = None,
):
    """D-PSGD [14]: x^{t+1} = W̄ x^t − γ ∇F(x^t), W̄ doubly stochastic."""
    n = topo.n
    Wm = jnp.asarray(metropolis_weights(topo), jnp.float32)
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))
    if times is None:
        times = np.arange(1, rounds + 1, dtype=np.float64)

    def round_fn(x, key):
        g = _vgrads(grad_fn, x, key)
        return Wm @ x - gamma * g

    x, metrics = _run_rounds(round_fn, x0, rounds, seed, eval_every,
                             eval_fn, times)
    return x, metrics


# --------------------------------------------------------------------- #
# asynchronous baselines (event-driven jax scans)
# --------------------------------------------------------------------- #
def _async_events(n: int, K: int, compute_time, jitter, seed):
    rng = np.random.default_rng(seed)
    compute_time = np.asarray(compute_time, np.float64)
    clocks = rng.uniform(0, 1, n) * compute_time
    agent = np.zeros(K, np.int32)
    times = np.zeros(K)
    for k in range(K):
        a = int(np.argmin(clocks))
        agent[k] = a
        times[k] = clocks[a]
        clocks[a] += compute_time[a] * (1 + rng.uniform(-jitter, jitter))
    return agent, times


def run_adpsgd(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float, K: int,
    *, compute_time=None, jitter: float = 0.2, staleness: int = 2,
    loss_prob: float = 0.0, seed: int = 0, eval_every: int = 0, eval_fn=None,
):
    """AD-PSGD [22]: event-driven atomic pairwise averaging + stale grads.

    Active node a picks a random (undirected) neighbour b, atomically
    averages x_a, x_b, then applies a gradient computed at a's model from
    ``staleness`` events ago.  Packet loss => the averaging step is skipped
    (partial mixing), the descent still happens.
    """
    n = topo.n
    rng = np.random.default_rng(seed + 7)
    if compute_time is None:
        compute_time = np.ones(n)
    agent, times = _async_events(n, K, compute_time, jitter, seed)
    nbrs = {i: sorted(set(topo.in_neighbors_W(i) + topo.out_neighbors_W(i)))
            for i in range(n)}
    partner = np.array([nbrs[a][rng.integers(len(nbrs[a]))] if nbrs[a] else a
                        for a in agent], np.int32)
    mixed = (rng.uniform(size=K) >= loss_prob)

    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))
    H = staleness + 1
    x_hist0 = jnp.tile(x0[None], (H, 1, 1))

    def step(carry, inp):
        x, x_hist, k = carry
        a, b, mix, key = inp
        avg = 0.5 * (x[a] + x[b])
        x_a = jnp.where(mix, avg, x[a])
        x_b = jnp.where(mix, avg, x[b])
        g = grad_fn(a, x_hist[k % H, a], key)
        x = x.at[b].set(x_b).at[a].set(x_a - gamma * g)
        x_hist = x_hist.at[(k + 1) % H].set(x)
        return (x, x_hist, k + 1), None

    keys = jax.random.split(jax.random.PRNGKey(seed), K)
    chunk = jax.jit(lambda c, a, b, m, ks: jax.lax.scan(
        step, c, (a, b, m, ks))[0])
    carry = (x0, x_hist0, jnp.zeros((), jnp.int32))
    metrics: list[dict] = []
    ee = eval_every if eval_every > 0 else K
    agent_j, partner_j = jnp.asarray(agent), jnp.asarray(partner)
    mixed_j = jnp.asarray(mixed)
    for s in range(0, K, ee):
        e = min(K, s + ee)
        carry = chunk(carry, agent_j[s:e], partner_j[s:e], mixed_j[s:e],
                      keys[s:e])
        if eval_fn is not None:
            m = eval_fn(carry[0], float(times[e - 1]))
            m["k"] = e
            metrics.append(m)
    return carry[0], metrics


def run_osgp(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float, K: int,
    *, compute_time=None, jitter: float = 0.2, loss_prob: float = 0.0,
    seed: int = 0, eval_every: int = 0, eval_fn=None,
):
    """OSGP [23]: overlap stochastic gradient push (async push-sum).

    Node state (x_i, w_i).  On wake: consume mailbox mass, de-bias
    ẑ = x/w, descend, then push column-stochastic shares to out-neighbour
    mailboxes (non-blocking).  Lost packets lose mass — the robustness gap
    R-FAST's running sums close.
    """
    n = topo.n
    if compute_time is None:
        compute_time = np.ones(n)
    agent, times = _async_events(n, K, compute_time, jitter, seed)
    A = jnp.asarray(topo.A, jnp.float32)           # column-stochastic
    rng = np.random.default_rng(seed + 13)
    # per-event, per-row loss mask for the pushes of the active node
    lost = (rng.uniform(size=(K, n)) < loss_prob)

    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))

    def step(carry, inp):
        x, w, mail_x, mail_w = carry
        a, drop, key = inp
        # consume mailbox
        x_a = x[a] + mail_x[a]
        w_a = w[a] + mail_w[a]
        mail_x = mail_x.at[a].set(0.0)
        mail_w = mail_w.at[a].set(0.0)
        # de-biased gradient step
        g = grad_fn(a, x_a / jnp.maximum(w_a, 1e-8), key)
        x_a = x_a - gamma * w_a * g
        # push shares
        col = A[:, a]                                 # (n,)
        keep = col[a]
        others = col.at[a].set(0.0)
        ok = (~drop).astype(x_a.dtype)                # (n,)
        mail_x = mail_x + (others * ok)[:, None] * x_a[None, :]
        mail_w = mail_w + others * ok * w_a
        x = x.at[a].set(keep * x_a)
        w = w.at[a].set(keep * w_a)
        return (x, w, mail_x, mail_w), None

    keys = jax.random.split(jax.random.PRNGKey(seed), K)
    chunk = jax.jit(lambda c, a, d, ks: jax.lax.scan(step, c, (a, d, ks))[0])
    carry = (x0, jnp.ones(n, jnp.float32), jnp.zeros_like(x0),
             jnp.zeros(n, jnp.float32))
    metrics: list[dict] = []
    ee = eval_every if eval_every > 0 else K
    agent_j, lost_j = jnp.asarray(agent), jnp.asarray(lost)
    for s in range(0, K, ee):
        e = min(K, s + ee)
        carry = chunk(carry, agent_j[s:e], lost_j[s:e], keys[s:e])
        if eval_fn is not None:
            x, w = carry[0], carry[1]
            xd = x / jnp.maximum(w[:, None], 1e-8)
            m = eval_fn(xd, float(times[e - 1]))
            m["k"] = e
            metrics.append(m)
    return carry[0] / jnp.maximum(carry[1][:, None], 1e-8), metrics
