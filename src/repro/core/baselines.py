"""Baseline algorithms the paper compares against (Table II / Fig. 5-6).

Synchronous: Ring-AllReduce SGD [12], D-PSGD [14], S-AB [17] (two-matrix
synchronous gradient tracking — the synchronous push-pull recursion (2)),
plus ``push_pull_sync`` itself (eq. (2), the deterministic ancestor of
R-FAST).

Asynchronous: AD-PSGD [22] (atomic pairwise averaging + stale gradients)
and OSGP [23] (overlap stochastic gradient push: push-sum with mailbox
accumulation and non-blocking sends).

All baselines share the simulator's ``grad_fn(node, x, key)`` interface
and the repo-wide :class:`~repro.core.scenario.NetworkScenario` virtual
clock, so time-to-loss comparisons against R-FAST are apples-to-apples:
synchronous rounds pay the barrier (slowest node + retransmitted edges),
asynchronous events follow the same per-node clocks, and every packet
crosses the same lossy, delayed channels.  How each baseline maps onto
the scenario model is documented in DESIGN.md §7.

``eval_fn`` contract (uniform across baselines): ``eval_fn(x, t)`` where
``x`` is the algorithm's iterate — ``(n, p)`` per-node models, or ``(p,)``
for the single-model Ring-AllReduce — and ``t`` the virtual time.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .protocol import descent_step, tracking_step
from .scenario import NetworkScenario
from .topology import Topology

GradFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]

__all__ = [
    "run_push_pull_sync",
    "run_ring_allreduce",
    "run_dpsgd",
    "run_sab",
    "run_adpsgd",
    "run_osgp",
    "metropolis_weights",
]


def _as_scenario(scenario, compute_time, jitter, loss_prob) -> NetworkScenario:
    """Legacy-kwarg shim: a scenario wins; otherwise build one."""
    if scenario is not None:
        if compute_time is not None or jitter is not None or loss_prob is not None:
            raise ValueError("pass either scenario= or the legacy "
                             "compute_time/jitter/loss_prob kwargs, not both")
        return scenario
    return NetworkScenario(
        compute_time=(1.0 if compute_time is None
                      else tuple(np.asarray(compute_time, np.float64))),
        jitter=0.2 if jitter is None else jitter,
        loss=0.0 if loss_prob is None else loss_prob,
    )


def metropolis_weights(topo: Topology) -> np.ndarray:
    """Doubly-stochastic weights for an undirected graph (D-PSGD)."""
    n = topo.n
    adj = ((topo.W > 0) | (topo.W.T > 0)) & ~np.eye(n, dtype=bool)
    deg = adj.sum(axis=1)
    Wm = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if adj[i, j]:
                Wm[i, j] = 1.0 / (1 + max(deg[i], deg[j]))
        Wm[i, i] = 1.0 - Wm[i].sum()
    return Wm


def _vgrads(grad_fn: GradFn, x: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    n = x.shape[0]
    keys = jax.random.split(key, n)
    return jax.vmap(grad_fn)(jnp.arange(n), x, keys)


# --------------------------------------------------------------------- #
# synchronous baselines
# --------------------------------------------------------------------- #
def _sync_times(scenario, topo_or_n, rounds: int, seed: int,
                times: np.ndarray | None) -> np.ndarray:
    if times is not None:
        return np.asarray(times, np.float64)
    sc = scenario if scenario is not None else NetworkScenario()
    return sc.sync_round_times(topo_or_n, rounds, seed=seed)


def _run_rounds(round_fn, carry, rounds: int, seed: int,
                eval_every: int, eval_fn, times: np.ndarray,
                extract=lambda c: c):
    """Drive ``rounds`` jitted rounds; ``eval_fn`` always receives the
    *iterate* (``extract(carry)``), never the raw carry."""
    key = jax.random.PRNGKey(seed)
    metrics: list[dict] = []
    jfn = jax.jit(round_fn)
    for t in range(rounds):
        key, sub = jax.random.split(key)
        carry = jfn(carry, sub)
        if eval_fn is not None and (t + 1) % eval_every == 0:
            m = eval_fn(extract(carry), float(times[t]))
            m["round"] = t + 1
            metrics.append(m)
    return carry, metrics


def run_push_pull_sync(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float,
    rounds: int, *, scenario: NetworkScenario | None = None, seed: int = 0,
    eval_every: int = 10, eval_fn=None, times: np.ndarray | None = None,
):
    """Synchronous push-pull (eq. 2): the paper's S-AB-style ancestor.

    x^{t+1} = W (x^t − γ z^t);  z^{t+1} = A z^t + ∇F(x^{t+1}) − ∇F(x^t).

    The per-round formulas are the protocol core's S.1/S.2b steps in
    matrix form (``recv = 0``: mixing happens through A z, not running
    sums) — eq. (2) is the all-delivered, zero-delay limit of R-FAST.
    """
    n = topo.n
    W = jnp.asarray(topo.W, jnp.float32)
    A = jnp.asarray(topo.A, jnp.float32)
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))
    g0 = _vgrads(grad_fn, x0, jax.random.PRNGKey(seed + 1))
    times = _sync_times(scenario, topo, rounds, seed, times)

    def round_fn(carry, key):
        x, z, g = carry
        x_new = W @ descent_step(x, z, gamma)                  # S.1 + S.2a
        g_new = _vgrads(grad_fn, x_new, key)
        z_new = tracking_step(A @ z, 0.0, g_new, g)            # S.2b
        return (x_new, z_new, g_new)

    carry, metrics = _run_rounds(round_fn, (x0, g0, g0), rounds, seed,
                                 eval_every, eval_fn, times,
                                 extract=lambda c: c[0])
    return carry[0], metrics


def run_sab(topo: Topology, grad_fn: GradFn, x0, gamma, rounds, **kw):
    """S-AB [17]: synchronous stochastic gradient tracking with a
    row-stochastic and a column-stochastic matrix — identical recursion to
    synchronous push-pull over a strongly-connected digraph."""
    return run_push_pull_sync(topo, grad_fn, x0, gamma, rounds, **kw)


def run_ring_allreduce(
    n: int, grad_fn: GradFn, x0: jnp.ndarray, gamma: float, rounds: int,
    *, scenario: NetworkScenario | None = None, seed: int = 0,
    eval_every: int = 10, eval_fn=None, times: np.ndarray | None = None,
):
    """Ring-AllReduce SGD: exact gradient average per round (single model).

    The barrier clock runs over the n-edge directed ring (the reduce/
    broadcast path), so stragglers, losses and crashes stall every round.
    """
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 2:
        x0 = x0[0]
    times = _sync_times(scenario, n, rounds, seed, times)

    def round_fn(x, key):
        g = _vgrads(grad_fn, jnp.tile(x[None], (n, 1)), key)
        return x - gamma * g.mean(axis=0)

    x, metrics = _run_rounds(round_fn, x0, rounds, seed, eval_every,
                             eval_fn, times)
    return x, metrics


def run_dpsgd(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float,
    rounds: int, *, scenario: NetworkScenario | None = None, seed: int = 0,
    eval_every: int = 10, eval_fn=None, times: np.ndarray | None = None,
):
    """D-PSGD [14]: x^{t+1} = W̄ x^t − γ ∇F(x^t), W̄ doubly stochastic."""
    n = topo.n
    Wm = jnp.asarray(metropolis_weights(topo), jnp.float32)
    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))
    times = _sync_times(scenario, topo, rounds, seed, times)

    def round_fn(x, key):
        g = _vgrads(grad_fn, x, key)
        return Wm @ x - gamma * g

    x, metrics = _run_rounds(round_fn, x0, rounds, seed, eval_every,
                             eval_fn, times)
    return x, metrics


# --------------------------------------------------------------------- #
# asynchronous baselines (event-driven jax scans on the scenario clock)
# --------------------------------------------------------------------- #
def run_adpsgd(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float, K: int,
    *, scenario: NetworkScenario | None = None, compute_time=None,
    jitter: float | None = None, staleness: int = 2,
    loss_prob: float | None = None, seed: int = 0, eval_every: int = 0,
    eval_fn=None,
):
    """AD-PSGD [22]: event-driven atomic pairwise averaging + stale grads.

    On the scenario clock: active node a picks a random (undirected)
    neighbour b and atomically averages with the *freshest delivered*
    copy of b's model (the schedule's per-edge payload stamps — latency
    makes the mixed value stale, exactly like R-FAST's consensus reads);
    b symmetrically averages with its delivered copy of a.  The exchange
    is dropped whole when either direction's packet is lost or the
    partner is inside a crash window.  The descent then applies a
    gradient evaluated at a's model of ``staleness`` events ago.
    """
    n = topo.n
    rng = np.random.default_rng(seed + 7)
    scenario = _as_scenario(scenario, compute_time, jitter, loss_prob)
    trace = scenario.realize(topo, K, seed=seed)
    sched = trace.schedule
    agent, times = sched.agent, sched.times

    edges_w = topo.edges_W()
    eidx = {ji: e for e, ji in enumerate(edges_w)}
    nbrs = {i: sorted(set(topo.in_neighbors_W(i) + topo.out_neighbors_W(i)))
            for i in range(n)}
    # the ring must cover the partner-view reads too: the a->b stamp is
    # only refreshed when b wakes, so between b's wakes its staleness is
    # NOT bounded by sched.D (which measures active-agent reads only).
    # Clamp those stamps to the scenario's Assumption-3(ii) bound D_max —
    # the same forced delivery realize() applies at consumption — and
    # size the ring to match.
    d_max = scenario.resolved_D_max(n)
    H = max(staleness + 1, d_max + 2)
    ch = scenario.channels(len(edges_w), rng)

    # host pass: partner choice, mixing gate (both channel directions +
    # partner liveness), and the hist slots of the delivered payloads
    partner = np.zeros(K, np.int32)
    mixed = np.zeros(K, bool)
    slot_ba = np.zeros(K, np.int32)     # b's state as delivered to a
    slot_ab = np.zeros(K, np.int32)     # a's state as delivered to b
    for k in range(K):
        a = int(agent[k])
        if not nbrs[a]:
            partner[k] = a
            continue
        b = nbrs[a][rng.integers(len(nbrs[a]))]
        partner[k] = b
        e_ba, e_ab = eidx.get((b, a)), eidx.get((a, b))
        ok = not scenario.in_failure(b, float(times[k]))
        for e in (e_ba, e_ab):
            if e is not None:
                ok = ch.ok(e) and ok       # draw both; burst state advances
        mixed[k] = ok
        # stamp s = state after global event s-1, written at hist slot s%H;
        # a missing direction falls back to the current snapshot (slot k%H)
        s_ba = sched.stamp_v[k, e_ba] if e_ba is not None else k
        s_ab = sched.stamp_v[k, e_ab] if e_ab is not None else k
        s_ba = max(int(s_ba), k - d_max)
        s_ab = max(int(s_ab), k - d_max)
        assert k - min(s_ba, s_ab) <= H - 2   # ring slots never alias
        slot_ba[k] = s_ba % H
        slot_ab[k] = s_ab % H

    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))
    x_hist0 = jnp.tile(x0[None], (H, 1, 1))

    def step(carry, inp):
        x, x_hist, k = carry
        a, b, s_ba, s_ab, mix, key = inp
        xb_seen = x_hist[s_ba, b]              # b as delivered to a
        xa_seen = x_hist[s_ab, a]              # a as delivered to b
        x_a = jnp.where(mix, 0.5 * (x[a] + xb_seen), x[a])
        x_b = jnp.where(mix, 0.5 * (x[b] + xa_seen), x[b])
        # the state after m events lives at hist slot m % H (written at
        # the end of event m-1), so `staleness` events ago = slot (k-s)%H;
        # staleness 0 degenerates to the current state, as it should
        g = grad_fn(a, x_hist[(k - staleness) % H, a], key)
        x = x.at[b].set(x_b).at[a].set(x_a - gamma * g)
        x_hist = x_hist.at[(k + 1) % H].set(x)
        return (x, x_hist, k + 1), None

    keys = jax.random.split(jax.random.PRNGKey(seed), K)
    chunk = jax.jit(lambda c, *seq: jax.lax.scan(step, c, seq)[0])
    carry = (x0, x_hist0, jnp.zeros((), jnp.int32))
    metrics: list[dict] = []
    ee = eval_every if eval_every > 0 else K
    agent_j, partner_j = jnp.asarray(agent), jnp.asarray(partner)
    sba_j, sab_j = jnp.asarray(slot_ba), jnp.asarray(slot_ab)
    mixed_j = jnp.asarray(mixed)
    for s in range(0, K, ee):
        e = min(K, s + ee)
        carry = chunk(carry, agent_j[s:e], partner_j[s:e], sba_j[s:e],
                      sab_j[s:e], mixed_j[s:e], keys[s:e])
        if eval_fn is not None:
            m = eval_fn(carry[0], float(times[e - 1]))
            m["k"] = e
            metrics.append(m)
    return carry[0], metrics


def run_osgp(
    topo: Topology, grad_fn: GradFn, x0: jnp.ndarray, gamma: float, K: int,
    *, scenario: NetworkScenario | None = None, compute_time=None,
    jitter: float | None = None, loss_prob: float | None = None,
    seed: int = 0, eval_every: int = 0, eval_fn=None,
):
    """OSGP [23]: overlap stochastic gradient push (async push-sum).

    Node state (x_i, w_i).  On wake: consume the arrived mailbox mass,
    de-bias ẑ = x/w, descend, then push column-stochastic shares to
    out-neighbour mailboxes (non-blocking).  On the scenario clock the
    mailboxes are per-edge *cumulative* streams read at the schedule's
    payload stamps — latency delays mass, and a lost packet's share is
    excluded from the stream forever (push-sum has no retransmission:
    the mass is gone — exactly the robustness gap R-FAST's running sums
    close; R-FAST's ρ streams are cumulative at the *algorithm* level,
    so a later arrival re-delivers everything).
    """
    n = topo.n
    scenario = _as_scenario(scenario, compute_time, jitter, loss_prob)
    trace = scenario.realize(topo, K, seed=seed)
    sched = trace.schedule
    agent, times = sched.agent, sched.times

    edges_a = topo.edges_A()
    E1 = max(1, len(edges_a))
    H = sched.D + 2
    src = np.zeros(E1, np.int32)
    dst = np.full(E1, -1, np.int32)      # -1 on pads: matches no agent
    wt = np.zeros(E1, np.float32)
    for e, (j, i) in enumerate(edges_a):
        src[e], dst[e], wt[e] = j, i, topo.A[i, j]
    src[len(edges_a):] = -1
    a_diag = jnp.asarray(np.diag(topo.A), jnp.float32)
    src_j, dst_j, wt_j = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(wt)
    rslot = jnp.asarray(sched.stamp_rho % H, jnp.int32)        # (K, E1)
    send_ok = jnp.asarray(trace.send_ok_a, jnp.float32)        # (K, E1)

    x0 = jnp.asarray(x0, jnp.float32)
    if x0.ndim == 1:
        x0 = jnp.tile(x0[None], (n, 1))
    p = x0.shape[1]

    def step(carry, inp):
        x, w, cum_x, cum_w, cons_x, cons_w, hist_x, hist_w, k = carry
        a, rs, ok, key = inp
        # consume: cumulative stream at the delivered stamp, minus what
        # this receiver already took (the receiver-side ρ̃ idiom)
        vals_x = hist_x[rs, jnp.arange(E1)]                    # (E1, p)
        vals_w = hist_w[rs, jnp.arange(E1)]                    # (E1,)
        m_in = (dst_j == a)
        mx = jnp.sum(jnp.where(m_in[:, None], vals_x - cons_x, 0.0), axis=0)
        mw = jnp.sum(jnp.where(m_in, vals_w - cons_w, 0.0))
        cons_x = jnp.where(m_in[:, None], vals_x, cons_x)
        cons_w = jnp.where(m_in, vals_w, cons_w)
        x_a = x[a] + mx
        w_a = w[a] + mw
        # de-biased gradient step
        g = grad_fn(a, x_a / jnp.maximum(w_a, 1e-8), key)
        x_a = x_a - gamma * w_a * g
        # push shares: delivered packets extend the stream, lost ones
        # never enter it (their mass is gone)
        m_out = (src_j == a).astype(x.dtype) * ok * wt_j       # (E1,)
        cum_x = cum_x + m_out[:, None] * x_a[None, :]
        cum_w = cum_w + m_out * w_a
        x = x.at[a].set(a_diag[a] * x_a)
        w = w.at[a].set(a_diag[a] * w_a)
        hist_x = hist_x.at[(k + 1) % H].set(cum_x)
        hist_w = hist_w.at[(k + 1) % H].set(cum_w)
        return (x, w, cum_x, cum_w, cons_x, cons_w, hist_x, hist_w,
                k + 1), None

    keys = jax.random.split(jax.random.PRNGKey(seed), K)
    chunk = jax.jit(lambda c, *seq: jax.lax.scan(step, c, seq)[0])
    carry = (x0, jnp.ones(n, jnp.float32),
             jnp.zeros((E1, p), jnp.float32), jnp.zeros(E1, jnp.float32),
             jnp.zeros((E1, p), jnp.float32), jnp.zeros(E1, jnp.float32),
             jnp.zeros((H, E1, p), jnp.float32),
             jnp.zeros((H, E1), jnp.float32),
             jnp.zeros((), jnp.int32))
    metrics: list[dict] = []
    ee = eval_every if eval_every > 0 else K
    agent_j = jnp.asarray(agent)
    for s in range(0, K, ee):
        e = min(K, s + ee)
        carry = chunk(carry, agent_j[s:e], rslot[s:e], send_ok[s:e],
                      keys[s:e])
        if eval_fn is not None:
            x, w = carry[0], carry[1]
            xd = x / jnp.maximum(w[:, None], 1e-8)
            m = eval_fn(xd, float(times[e - 1]))
            m["k"] = e
            metrics.append(m)
    return carry[0] / jnp.maximum(carry[1][:, None], 1e-8), metrics
