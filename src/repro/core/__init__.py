"""R-FAST core: topology, schedules, the global-view simulator, baselines,
and the production shard_map runtime."""
from .topology import (  # noqa: F401
    Topology, get_topology, binary_tree, line, directed_ring,
    undirected_ring, exponential, mesh2d, parameter_server, TOPOLOGIES,
    validate_weights, spanning_tree_roots, common_roots,
)
from .schedule import Schedule, generate_schedule, round_robin_schedule  # noqa: F401
from .simulator import (  # noqa: F401
    RFASTState, init_state, rfast_scan, run_rfast, tracked_mass,
)
from . import baselines  # noqa: F401
