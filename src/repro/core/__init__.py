"""R-FAST core: topology, the CommPlan/protocol substrate, schedules, the
global-view simulator, baselines, and the production shard_map runtime.

Layering (DESIGN.md): ``Topology`` -> :class:`CommPlan` (one static
edge-plan extraction) -> :mod:`protocol` (the single S.1–S.5 update, with
``jnp``/``pallas`` backends) -> execution engines (``simulator``,
``runtime``, ``runtime_sharded``)."""
from .topology import (  # noqa: F401
    Topology, get_topology, binary_tree, line, directed_ring,
    undirected_ring, exponential, mesh2d, parameter_server, robust_tree,
    TOPOLOGIES, validate_weights, spanning_tree_roots,
    spanning_tree_roots_dense, common_roots, subgraph_topology,
    bfs_tree_topology, epoch_topology,
)
from .plan import (  # noqa: F401
    CommPlan, build_comm_plan, pad_comm_plan, matchings,
)
from .paramvec import (  # noqa: F401
    RavelSpec, make_ravel_spec, ravel, unravel,
    GradProvider, ModelGradProvider, as_grad_fn,
)
from .protocol import (  # noqa: F401
    ProtocolState, init_protocol_state, make_protocol_round,
    protocol_tracked_mass, descent_step, momentum_mix, consensus_mix,
    tracking_step, mailbox_merge, IMPLS,
)
from .schedule import (  # noqa: F401
    Schedule, WavefrontPlan, build_wavefront_plan, pad_plan, stack_plans,
    generate_schedule, round_robin_schedule,
)
from .scenario import (  # noqa: F401
    NetworkScenario, ScenarioTrace, Epoch, EpochTrace, GilbertElliott,
    EdgeChannels, SCENARIOS, get_scenario, realize_batch,
    realize_epochs_batch,
)
from .simulator import (  # noqa: F401
    RFASTState, init_state, rfast_scan, run_rfast, run_sweep,
    migrate_state, run_epochs, run_sweep_epochs, tracked_mass,
)
from . import baselines  # noqa: F401
