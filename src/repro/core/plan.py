"""CommPlan: the single Topology -> edge-plan extraction.

Every execution engine (global-view simulator, dense GSPMD runtime,
shard_map ppermute runtime, fused-kernel protocol backend) needs the same
static data derived from a :class:`~repro.core.topology.Topology`:

* **dense padded edge arrays** — ``(src, dst, weight)`` triples per edge of
  G(W) and G(A), zero-padded to a common length ``e_pad`` (a multiple of
  ``n`` so the edge dim shards evenly), plus the diagonals.  Padded entries
  have ``src = dst = 0`` and weight ``0`` so masked scatter/gather sums
  ignore them.
* **matching decomposition** — the edge sets split into slots with unique
  sources AND destinations, each realizable as one ``lax.ppermute``; plus
  per-slot weight tables indexed by node id.
* **per-node neighbour tables** — in-/out-edges of each node padded to the
  max degree, as (edge-position, neighbour-id, weight, validity) arrays.
  These feed the fused per-node Pallas update kernel
  (`kernels/rfast_update`), which wants dense ``(K, P)`` neighbour stacks.

Historically this extraction was triplicated (``runtime.edge_arrays``,
``simulator._EdgeData.build``, ``runtime_sharded._slot_tables``); it now
lives here, built ONCE per topology, and the engines consume slices of it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import Topology

__all__ = ["CommPlan", "build_comm_plan", "as_comm_plan", "pad_comm_plan",
           "matchings"]


def matchings(edges: list[tuple[int, int]]) -> list[list[tuple[int, int]]]:
    """Greedy decomposition into unique-source/unique-dest matchings.

    Each matching can be realized as a single ``lax.ppermute`` along the
    node mesh axes (exactly one inter-node hop per edge).
    """
    remaining = list(edges)
    slots = []
    while remaining:
        used_s: set[int] = set()
        used_d: set[int] = set()
        slot, rest = [], []
        for (j, i) in remaining:
            if j not in used_s and i not in used_d:
                slot.append((j, i))
                used_s.add(j)
                used_d.add(i)
            else:
                rest.append((j, i))
        slots.append(slot)
        remaining = rest
    return slots


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Static protocol data extracted once from a Topology.

    Edge convention: position ``e`` in the W arrays is the ``e``-th edge of
    ``topo.edges_W()`` (and likewise for A); per-edge delivery masks are
    indexed the same way.  Positions ``>= n_edges_*`` are zero-weight
    padding.
    """

    n: int
    e_pad: int
    n_edges_w: int
    n_edges_a: int

    # -- dense padded edge arrays (all length e_pad / n) ----------------- #
    w_diag: np.ndarray   # (n,) f32
    a_diag: np.ndarray   # (n,) f32
    src_w: np.ndarray; dst_w: np.ndarray; w_edge: np.ndarray  # (e_pad,)
    src_a: np.ndarray; dst_a: np.ndarray; a_edge: np.ndarray  # (e_pad,)

    # -- matching decomposition (ppermute engine) ------------------------ #
    slots_w: tuple[tuple[tuple[int, int], ...], ...]
    slots_a: tuple[tuple[tuple[int, int], ...], ...]
    w_in_table: np.ndarray   # (S_w, n) f32: W[i, j] for slot edge (j, i)
    a_out_table: np.ndarray  # (S_a, n) f32: A[i, j] for slot edge (j, i)
    has_in_a: np.ndarray     # (S_a, n) f32: node i receives in slot s

    # -- per-node neighbour tables (fused-kernel backend) ---------------- #
    kw: int                  # max W in-degree  (>= 1)
    ka: int                  # max A in-degree  (>= 1)
    ko: int                  # max A out-degree (>= 1)
    in_w_epos: np.ndarray    # (n, kw) i32 W-edge position  (pad -> 0)
    in_w_src: np.ndarray     # (n, kw) i32 sender node id   (pad -> 0)
    in_w_wt: np.ndarray      # (n, kw) f32 W[i, j]          (pad -> 0)
    in_a_epos: np.ndarray    # (n, ka) i32 A-edge position  (pad -> 0)
    in_a_val: np.ndarray     # (n, ka) f32 1 = real edge
    out_a_epos: np.ndarray   # (n, ko) i32 A-edge position  (pad -> 0)
    out_a_wt: np.ndarray     # (n, ko) f32 A[dst, i]        (pad -> 0)
    out_a_val: np.ndarray    # (n, ko) f32 1 = real edge

    @property
    def s_w(self) -> int:
        return max(1, len(self.slots_w))

    @property
    def s_a(self) -> int:
        return max(1, len(self.slots_a))


def as_comm_plan(topo) -> "CommPlan":
    """Coerce a Topology-or-CommPlan argument to a CommPlan (engines
    accept either so a prebuilt plan is never re-derived)."""
    return topo if isinstance(topo, CommPlan) else build_comm_plan(topo)


def pad_comm_plan(plan: CommPlan, *, kw: int | None = None,
                  ka: int | None = None, ko: int | None = None) -> CommPlan:
    """Degree-pad the per-node neighbour tables to common maxima.

    CommPlans from different topologies (over the same ``n``) carry
    different max in-/out-degrees ``(kw, ka, ko)``; padding them to a
    shared maximum makes the WavefrontPlans built on top stackable into
    dense ``(S, ...)`` fleet arrays.  Padded columns are inert by the
    same argument as build_comm_plan's own degree padding: zero weight
    and zero validity (so gathers contribute nothing) with edge
    position / sender id 0 (so reads clamp harmlessly).  The dense edge
    arrays, matching decomposition, and diagonals are untouched.
    """
    kw = plan.kw if kw is None else int(kw)
    ka = plan.ka if ka is None else int(ka)
    ko = plan.ko if ko is None else int(ko)
    if kw < plan.kw or ka < plan.ka or ko < plan.ko:
        raise ValueError(
            f"cannot shrink degrees: have (kw={plan.kw}, ka={plan.ka}, "
            f"ko={plan.ko}), asked for ({kw}, {ka}, {ko})")
    if (kw, ka, ko) == (plan.kw, plan.ka, plan.ko):
        return plan

    def cols(a: np.ndarray, k: int) -> np.ndarray:
        if a.shape[1] == k:
            return a
        return np.concatenate(
            [a, np.zeros((a.shape[0], k - a.shape[1]), a.dtype)], axis=1)

    return dataclasses.replace(
        plan, kw=kw, ka=ka, ko=ko,
        in_w_epos=cols(plan.in_w_epos, kw), in_w_src=cols(plan.in_w_src, kw),
        in_w_wt=cols(plan.in_w_wt, kw),
        in_a_epos=cols(plan.in_a_epos, ka), in_a_val=cols(plan.in_a_val, ka),
        out_a_epos=cols(plan.out_a_epos, ko),
        out_a_wt=cols(plan.out_a_wt, ko), out_a_val=cols(plan.out_a_val, ko),
    )


def _pack_dense(edges, M, e_pad):
    src = np.zeros(e_pad, np.int32)
    dst = np.zeros(e_pad, np.int32)
    wt = np.zeros(e_pad, np.float32)
    for e, (j, i) in enumerate(edges):
        src[e], dst[e], wt[e] = j, i, M[i, j]
    return src, dst, wt


def _slot_tables(topo: Topology, slots_w, slots_a):
    n = topo.n
    w_in = np.zeros((max(1, len(slots_w)), n), np.float32)
    for s, es in enumerate(slots_w):
        for (j, i) in es:
            w_in[s, i] = topo.W[i, j]
    a_out = np.zeros((max(1, len(slots_a)), n), np.float32)
    has_in = np.zeros((max(1, len(slots_a)), n), np.float32)
    for s, es in enumerate(slots_a):
        for (j, i) in es:
            a_out[s, j] = topo.A[i, j]
            has_in[s, i] = 1.0
    return w_in, a_out, has_in


def _node_tables(n, edges, M, *, by: str):
    """Pad each node's edge list (by='dst': in-edges, by='src': out-edges)
    to the max degree.  Returns (epos, peer, weight, valid)."""
    per: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for e, (j, i) in enumerate(edges):
        if by == "dst":
            per[i].append((e, j))
        else:
            per[j].append((e, i))
    k = max(1, max((len(p) for p in per), default=0))
    epos = np.zeros((n, k), np.int32)
    peer = np.zeros((n, k), np.int32)
    wt = np.zeros((n, k), np.float32)
    val = np.zeros((n, k), np.float32)
    for node, lst in enumerate(per):
        for s, (e, other) in enumerate(lst):
            epos[node, s] = e
            peer[node, s] = other
            if by == "dst":       # in-edge (other -> node): weight M[node, other]
                wt[node, s] = M[node, other]
            else:                 # out-edge (node -> other): weight M[other, node]
                wt[node, s] = M[other, node]
            val[node, s] = 1.0
    return epos, peer, wt, val


def build_comm_plan(topo: Topology, e_pad: int | None = None) -> CommPlan:
    """Build the complete communication plan for ``topo``.

    ``e_pad`` defaults to the smallest multiple of ``n`` that fits every
    edge of either graph (so the padded edge dim shards evenly over the
    node mesh axes).
    """
    ew, ea = topo.edges_W(), topo.edges_A()
    E = max(len(ew), len(ea), 1)
    e_pad = e_pad or max(topo.n, -(-E // topo.n) * topo.n)
    if e_pad < max(len(ew), len(ea)):
        raise ValueError(f"e_pad={e_pad} < edge count {max(len(ew), len(ea))}")

    src_w, dst_w, w_edge = _pack_dense(ew, topo.W, e_pad)
    src_a, dst_a, a_edge = _pack_dense(ea, topo.A, e_pad)

    slots_w = matchings(ew)
    slots_a = matchings(ea)
    w_in_table, a_out_table, has_in_a = _slot_tables(topo, slots_w, slots_a)

    in_w_epos, in_w_src, in_w_wt, _ = _node_tables(topo.n, ew, topo.W,
                                                   by="dst")
    in_a_epos, _, _, in_a_val = _node_tables(topo.n, ea, topo.A, by="dst")
    out_a_epos, _, out_a_wt, out_a_val = _node_tables(topo.n, ea, topo.A,
                                                      by="src")

    return CommPlan(
        n=topo.n, e_pad=e_pad, n_edges_w=len(ew), n_edges_a=len(ea),
        w_diag=np.diag(topo.W).astype(np.float32),
        a_diag=np.diag(topo.A).astype(np.float32),
        src_w=src_w, dst_w=dst_w, w_edge=w_edge,
        src_a=src_a, dst_a=dst_a, a_edge=a_edge,
        slots_w=tuple(tuple(s) for s in slots_w),
        slots_a=tuple(tuple(s) for s in slots_a),
        w_in_table=w_in_table, a_out_table=a_out_table, has_in_a=has_in_a,
        kw=in_w_epos.shape[1], ka=in_a_epos.shape[1], ko=out_a_epos.shape[1],
        in_w_epos=in_w_epos, in_w_src=in_w_src, in_w_wt=in_w_wt,
        in_a_epos=in_a_epos, in_a_val=in_a_val,
        out_a_epos=out_a_epos, out_a_wt=out_a_wt, out_a_val=out_a_val,
    )
