"""shard_map R-FAST runtime: spanning-tree gossip as ``lax.ppermute``.

The dense-mixing runtime (runtime.py) is protocol-faithful but lowers the
node-axis mixing to gather/scatter that GSPMD can only realize by
all-gathering full per-node replicas — O(N · |params|) temp memory.  Here
the gossip is explicit: the edge sets of G(W)/G(A) are decomposed into
*matchings* (unique sources AND destinations; see
:func:`repro.core.plan.matchings`) and each matching becomes one
``ppermute`` along the node mesh axes — O(deg · |params|) traffic and
O(1) extra memory, exactly one inter-node hop per edge.

The node axes are MANUAL (shard_map); the 'model' axis stays AUTO, so the
per-node gradient runs the same GSPMD-sharded model code as everywhere
else.  The protocol *math* is :mod:`repro.core.protocol`'s scalar steps
over a :class:`repro.core.plan.CommPlan`'s slot tables — bit-identical to
runtime.py (tested); only the data movement differs.

State layout (node-major, padded to S slots = max degree):
  x, z, g_prev, m : (N, ...)          sharded over node axes
  rho_out         : (N, S_a, ...)     sender's running sums, slot-indexed
  rho_buf         : (N, S_a, ...)     receiver's buffers, slot-indexed
  mail_v          : (N, S_w, ...)     consensus mailboxes (robust mode)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .plan import CommPlan, as_comm_plan, matchings  # noqa: F401  (re-export)
from .protocol import descent_step, mailbox_merge, momentum_mix, tracking_step
from .topology import Topology

__all__ = ["ShardedState", "matchings", "make_sharded_round",
           "init_sharded_state", "sharded_state_specs",
           "partial_auto_shard_map_supported", "_shard_map",
           "packed_sweep_specs"]

GradFn = Callable[[Any, Any, jax.Array], tuple[jnp.ndarray, Any]]


class ShardedState(NamedTuple):
    step: jnp.ndarray
    x: Any
    z: Any
    g_prev: Any
    rho_out: Any
    rho_buf: Any
    mail_v: Any
    m: Any


def partial_auto_shard_map_supported() -> bool:
    """True when shard_map can keep non-node mesh axes AUTO (GSPMD) while
    the node axes are manual.  jax >= 0.6 exposes this as
    ``jax.shard_map(axis_names=...)``; on 0.4.x the partial-auto mode
    exists but its collectives hit unimplemented SPMD-partitioner paths
    (PartitionId / manual-subgroup mismatches), so we fall back to a
    fully-manual region there — see :func:`_shard_map`."""
    return hasattr(jax, "shard_map")


def _shard_map(fn, mesh, in_specs, out_specs, manual_axes):
    """Compat shim over the two shard_map generations.

    New jax: partial-auto (only ``manual_axes`` manual; 'model' stays
    GSPMD).  jax 0.4.x: a fully-manual region with ``check_rep=False`` —
    collectives work, but the wrapped ``fn`` must not emit sharding
    constraints on the non-node axes (engines that need those should pick
    the dense runtime instead; ``launch.specs`` does this automatically).
    """
    if partial_auto_shard_map_supported():
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(manual_axes), check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _slot_tables(topo: Topology | CommPlan):
    """Per-slot weight tables indexed by node id (from the CommPlan).

    Compat accessor kept for external consumers (tests/helpers); the
    round builder reads the CommPlan fields directly."""
    plan = as_comm_plan(topo)
    slots_w = [list(s) for s in plan.slots_w]
    slots_a = [list(s) for s in plan.slots_a]
    return (slots_w, slots_a, plan.w_in_table, plan.a_out_table,
            plan.has_in_a)


def _node_index(node_axes: Sequence[str], mesh) -> jnp.ndarray:
    idx = jnp.zeros((), jnp.int32)
    for a in node_axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def init_sharded_state(topo: Topology | CommPlan, params: Any, grad_fn: GradFn,
                       batches: Any, keys: Any, *, momentum: float = 0.0,
                       robust: bool = False) -> ShardedState:
    """Host-side init (unsharded semantics; shard via device_put)."""
    plan = as_comm_plan(topo)
    n = plan.n
    x = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n,) + l.shape),
                     params)
    g0 = jax.vmap(lambda p, b, k: grad_fn(p, b, k)[1])(x, batches, keys)
    sa, sw = plan.s_a, plan.s_w
    zer = lambda S: jax.tree.map(
        lambda l: jnp.zeros((n, S) + l.shape, l.dtype), params)
    return ShardedState(
        # g_prev gets its own buffer: donating rounds forbid aliased leaves
        step=jnp.zeros((), jnp.int32), x=x, z=g0,
        g_prev=jax.tree.map(jnp.copy, g0),
        rho_out=zer(sa), rho_buf=zer(sa),
        mail_v=zer(sw) if robust else None,
        m=jax.tree.map(jnp.zeros_like, x) if momentum else None)


def sharded_state_specs(state: ShardedState, node_axes) -> ShardedState:
    """shard_map in/out specs: node dim manual, everything else auto."""
    na = tuple(node_axes)

    def spec(l):
        return P(na, *([None] * (l.ndim - 1)))

    f = lambda tree: (None if tree is None
                      else jax.tree.map(spec, tree))
    return ShardedState(
        step=P(), x=f(state.x), z=f(state.z), g_prev=f(state.g_prev),
        rho_out=f(state.rho_out), rho_buf=f(state.rho_buf),
        mail_v=f(state.mail_v), m=f(state.m))


def packed_sweep_specs(lane_axis: str = "data",
                       param_axis: str | None = None):
    """Per-leaf spec builders for the mesh-mapped fleet sweep.

    The sweep engine stacks its packed state and wave tables on a leading
    *lane-group* axis (one group of ``S_loc`` lanes per ``lane_axis``
    device) and keeps the flat parameter axis last.  Returns two
    ``leaf -> PartitionSpec`` callables for ``jax.tree.map``:

    * ``state_spec``: ``P(lane_axis, None, ..., param_axis)`` — group
      axis sharded over the lanes, flat-p axis sharded over
      ``param_axis`` (replicated when ``param_axis`` is None).
    * ``wave_spec``:  ``P(lane_axis, None, ...)`` — plan tables and step
      keys are lane-group data; their trailing axes are table axes, not
      parameters, so only the leading axis is sharded.
    """

    def state_spec(leaf):
        return P(lane_axis, *([None] * (leaf.ndim - 2)), param_axis)

    def wave_spec(leaf):
        return P(lane_axis, *([None] * (leaf.ndim - 1)))

    return state_spec, wave_spec


def make_sharded_round(
    topo: Topology | CommPlan,
    grad_fn: GradFn,
    mesh,
    *,
    gamma,
    node_axes: Sequence[str],
    momentum: float = 0.0,
    robust: bool = False,
    donate: bool = False,
):
    """Build ``round_fn(state, batches, keys, masks) -> (state, metrics)``.

    ``masks``: (n, S_w + S_a) float deliveries in robust mode, else None.
    ``donate=True`` jits the round with the state donated (in-place
    protocol-state commits; callers must rebind and not reuse the old
    state).
    """
    plan = as_comm_plan(topo)
    slots_w, slots_a = plan.slots_w, plan.slots_a
    w_diag = jnp.asarray(plan.w_diag)
    a_diag = jnp.asarray(plan.a_diag)
    w_in_t = jnp.asarray(plan.w_in_table)
    a_out_t = jnp.asarray(plan.a_out_table)
    has_in_t = jnp.asarray(plan.has_in_a)
    na = tuple(node_axes)
    ax = na if len(na) > 1 else na[0]
    S_w, S_a = plan.s_w, plan.s_a

    # The collectives are chained through an optimization_barrier token so
    # every device issues them in the same order — independent ppermutes
    # may otherwise be scheduled in different orders by the concurrent
    # thunk executor and deadlock the rendezvous (observed on XLA:CPU; on
    # TPU the fixed order also makes the ICI schedule deterministic).
    def tperm(tree, perm, token):
        if not perm:
            return jax.tree.map(jnp.zeros_like, tree), token
        def one(l):
            l, _ = jax.lax.optimization_barrier((l, token))
            return jax.lax.ppermute(l, ax, perm=list(perm))
        out = jax.tree.map(one, tree)
        new_token = jax.tree.leaves(out)[0].ravel()[:1]
        return out, new_token

    def block_step(state: ShardedState, batch, key, masks):
        idx = _node_index(na, mesh)
        lr = gamma(state.step) if callable(gamma) else gamma
        token = jnp.zeros((1,), jnp.float32)
        sq = lambda tree: jax.tree.map(lambda l: l[0], tree)
        unsq = lambda tree: jax.tree.map(lambda l: l[None], tree)

        # (S1) local descent direction
        if momentum:
            m = jax.tree.map(lambda mm, zz: momentum_mix(mm, zz, momentum),
                             state.m, state.z)
            v = jax.tree.map(lambda xx, mm: descent_step(xx, mm, lr),
                             state.x, m)
        else:
            m = None
            v = jax.tree.map(lambda xx, zz: descent_step(xx, zz, lr),
                             state.x, state.z)

        # (S2a) consensus pull: one ppermute per W-matching
        x_new = jax.tree.map(lambda vv: w_diag[idx] * vv, v)
        mail_new = [] if robust else None
        for s in range(S_w):
            rv, token = tperm(v, slots_w[s] if s < len(slots_w) else [],
                              token)
            if robust:
                mk = masks[0, s] if masks is not None else 1.0
                old = jax.tree.map(lambda l: l[:, s], state.mail_v)
                rv = jax.tree.map(
                    lambda r, o: mailbox_merge(r, o, mk), rv, old)
                mail_new.append(rv)
            x_new = jax.tree.map(
                lambda xn, r: xn + (w_in_t[s, idx] * r).astype(xn.dtype),
                x_new, rv)

        # (S2b) fresh gradient at the mixed point
        loss, g_new = grad_fn(sq(x_new), sq(batch), key[0])
        g_new = unsq(g_new)

        # robust tracking: one ppermute per A-matching
        recv = jax.tree.map(jnp.zeros_like, state.z)
        buf_new = []
        for s in range(S_a):
            rr, token = tperm(jax.tree.map(lambda l: l[:, s],
                                           state.rho_out),
                              slots_a[s] if s < len(slots_a) else [],
                              token)
            mk = (masks[0, S_w + s] if (robust and masks is not None)
                  else 1.0)
            old = jax.tree.map(lambda l: l[:, s], state.rho_buf)
            gate = mk * has_in_t[s, idx]
            recv = jax.tree.map(
                lambda rc, r, o: rc + (gate * (r - o)).astype(rc.dtype),
                recv, rr, old)
            buf_new.append(jax.tree.map(
                lambda r, o: mailbox_merge(r, o, gate), rr, old))

        z_half = jax.tree.map(
            lambda zz, rc, gn, go: tracking_step(zz, rc, gn, go),
            state.z, recv, g_new, state.g_prev)
        z_new = jax.tree.map(lambda zh: (a_diag[idx] * zh).astype(zh.dtype),
                             z_half)
        rho_out_new = jax.tree.map(
            lambda ro, zh: ro + jnp.stack(
                [(a_out_t[s, idx] * zh[0]).astype(ro.dtype)
                 for s in range(S_a)])[None],
            state.rho_out, z_half)
        rho_buf_new = jax.tree.map(
            lambda *cols: jnp.stack([c[0] for c in cols])[None], *buf_new)
        mail_v_new = None
        if robust:
            mail_v_new = jax.tree.map(
                lambda *cols: jnp.stack([c[0] for c in cols])[None],
                *mail_new)

        new_state = ShardedState(
            step=state.step + 1, x=x_new, z=z_new, g_prev=g_new,
            rho_out=rho_out_new, rho_buf=rho_buf_new,
            mail_v=mail_v_new, m=m)
        return new_state, loss[None]

    def round_fn(state: ShardedState, batches, keys, masks=None):
        specs = sharded_state_specs(state, na)
        bspec = jax.tree.map(
            lambda l: P(na, *([None] * (l.ndim - 1))), batches)
        kspec = P(na)
        mspec = P(na) if masks is not None else None
        in_specs = (specs, bspec, kspec)
        args = (state, batches, keys)
        if masks is not None:
            in_specs = in_specs + (mspec,)
            args = args + (masks,)
            fn = block_step
        else:
            fn = lambda s, b, k: block_step(s, b, k, None)
        out_specs = (specs, P(na))
        new_state, losses = _shard_map(
            fn, mesh, in_specs, out_specs, na)(*args)
        return new_state, {"loss": losses.mean(), "losses": losses}

    if donate:
        return jax.jit(round_fn, donate_argnums=(0,))
    return round_fn
