"""The R-FAST protocol core: ONE implementation of the S.1–S.5 update.

Algorithm 2's recursion, written once and consumed by every execution
engine:

  S.1   v_i = x_i − γ ẑ_i                       (ẑ = momentum-mixed z)
  S.2a  x_i⁺ = w_ii v_i + Σ_j w_ij recv_ij       (masked consensus pull,
                                                  mailbox reuse on loss)
  S.2b  z½  = z_i + Σ_j m_ij (ρ_ji − ρ̃_ji) + ∇f_i(x⁺;ζ) − ∇f_i(x;ζ⁻)
  S.2c  z_i⁺ = a_ii z½ ;  ρ_ij += a_ji z½        (push running sums)
  S.4   ρ̃_ji ← ρ_ji  where delivered             (buffer commit)

Two interchangeable backends, selected with ``impl``:

* ``"jnp"``    — batched scatter/gather over the dense padded edge arrays
  of a :class:`~repro.core.plan.CommPlan`.  Bit-identical to the historic
  ``runtime.make_rfast_round`` math; the path GSPMD partitions best.
* ``"pallas"`` — the whole round's commit (all N nodes, every ρ/ρ̃ row)
  in ONE fused ``kernels/rfast_update.grid`` launch: the plan's edge-slot
  tables become in-kernel gather indices over the flat leaves, so no
  per-node neighbour stacks are materialized and no per-node kernel is
  dispatched.  ``interpret`` is the tri-state dispatch override (None =
  compiled launch on TPU / the fused edge-major jnp program elsewhere —
  the round's tables are trace-time constants, so off-TPU the emulation
  needs no slot-major gathers at all; True = the original vmapped
  per-node kernel in the Pallas interpreter, kept as the tests-only
  oracle).

The gradient is sampled at the *mixed* point x⁺ (S.2b), so the consensus
pull runs before the fused commit kernel in both backends; the kernel then
performs the whole protocol-state commit (z, ρ, ρ̃ — the bandwidth-bound
part) in a single fused pass.

Scalar building blocks (``descent_step`` …) are exported for engines whose
execution structure is not a dense SPMD round (the global-view simulator's
per-agent stale reads, the shard_map runtime's per-matching ppermutes, the
synchronous baselines): the protocol *math* lives here even when the data
movement cannot.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .plan import CommPlan
from ..kernels.rfast_update import dispatch
from ..kernels.rfast_update.grid import block_pad_width, commit_grid
from ..kernels.rfast_update.ops import rfast_commit

__all__ = [
    "ProtocolState", "VGradFn", "make_protocol_round", "init_protocol_state",
    "protocol_tracked_mass", "descent_step", "momentum_mix", "consensus_mix",
    "tracking_step", "mailbox_merge", "IMPLS",
]

IMPLS = ("jnp", "pallas")

VGradFn = Callable[[Any, Any, Any], tuple[jnp.ndarray, Any]]
# vgrads(x_stacked, batches, keys) -> (losses, grads): node-vmapped by the
# calling engine (which owns spmd_axis_name / sharding concerns).


# --------------------------------------------------------------------- #
# scalar building blocks — the protocol formulas, written once
# --------------------------------------------------------------------- #
def descent_step(x, z, lr):
    """S.1: local descent direction v = x − γ z."""
    return x - lr * z


def momentum_mix(m, z, beta):
    """Heavy-ball mix of the tracked direction: m⁺ = β m + z."""
    return beta * m + z


def consensus_mix(w_self, v_self, w_in, v_in):
    """S.2a: x⁺ = w_ii v_i + Σ_k w_in[k] · v_in[k] (sum over leading axis)."""
    return w_self * v_self + jnp.sum(w_in * v_in, axis=0)


def tracking_step(z, recv, g_new, g_old):
    """S.2b: robust gradient tracking z½ = z + recv + g_new − g_old."""
    return z + recv + g_new - g_old


def mailbox_merge(new, old, mask):
    """Masked commit (S.2a mailboxes / S.4 buffers): m·new + (1−m)·old."""
    return mask * new + (1 - mask) * old


# --------------------------------------------------------------------- #
# protocol state
# --------------------------------------------------------------------- #
class ProtocolState(NamedTuple):
    """Stacked per-node protocol state (leading N axis; ρ arrays E_pad)."""

    step: jnp.ndarray
    x: Any          # (N, ...) pytree
    z: Any
    g_prev: Any
    rho: Any        # (E_pad, ...) pytree — sender running sums
    rho_buf: Any    # (E_pad, ...) pytree — receiver buffers
    mail_v: Any     # (E_pad, ...) pytree or None (sync mode)
    m: Any          # momentum buffers or None


def _stack_n(tree: Any, n: int) -> Any:
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n,) + l.shape),
                        tree)


def init_protocol_state(
    plan: CommPlan,
    params: Any,
    vgrads: VGradFn,
    batches: Any,
    keys: Any,
    *,
    robust: bool = False,
    momentum: float = 0.0,
    stacked: bool = False,
) -> ProtocolState:
    """Paper init: x_i = x0 (broadcast), z_i = g_prev_i = ∇f_i(x0; ζ0)."""
    n, e = plan.n, plan.e_pad
    x = params if stacked else _stack_n(params, n)
    g0 = vgrads(x, batches, keys)[1]
    zeros_e = jax.tree.map(
        lambda l: jnp.zeros((e,) + l.shape[1:], l.dtype), x)
    return ProtocolState(
        step=jnp.zeros((), jnp.int32),
        # g_prev gets its own buffer: donating rounds forbid aliased leaves
        x=x, z=g0, g_prev=jax.tree.map(jnp.copy, g0),
        rho=zeros_e,
        rho_buf=jax.tree.map(jnp.copy, zeros_e),
        mail_v=jax.tree.map(jnp.copy, zeros_e) if robust else None,
        m=jax.tree.map(jnp.zeros_like, x) if momentum else None,
    )


def protocol_tracked_mass(state: ProtocolState):
    """Lemma-3 LHS on stacked state: Σ_i z_i + Σ_e (ρ_e − ρ̃_e)."""
    tot_z = jax.tree.map(lambda z: z.sum(0), state.z)
    inflight = jax.tree.map(lambda r, b: (r - b).sum(0),
                            state.rho, state.rho_buf)
    return jax.tree.map(lambda a, b: a + b, tot_z, inflight)


# --------------------------------------------------------------------- #
# the round builder
# --------------------------------------------------------------------- #
def make_protocol_round(
    plan: CommPlan,
    vgrads: VGradFn,
    *,
    gamma,
    robust: bool = False,
    momentum: float = 0.0,
    impl: str = "jnp",
    interpret: bool | None = None,
    donate: bool = False,
):
    """Build ``round_fn(state, batches, keys, masks) -> (state, metrics)``.

    ``masks``: (E_pad,) float {0, 1} delivery indicators for BOTH graphs
    (1 = delivered), or None for the synchronous special case (Remark 2).
    Masks must be binary: the backends agree only on 0/1 values (the
    fused kernel commits ρ̃ with a hard ``mask > 0`` threshold, the jnp
    path with the blending form — identical for indicators, divergent for
    fractional weights).  ``gamma`` may be a schedule ``step -> lr``.
    ``impl`` selects the backend; ``interpret`` (pallas only) is the
    tri-state dispatch override (None = autodetect, True = interpreter
    oracle, False = force a compiled launch).

    ``donate=True`` returns the round jitted with the state argument
    donated: x/z/ρ/ρ̃ update in place instead of double-buffering.  The
    caller must rebind (``state = round_fn(state, ...)[0]``) and never
    touch the old state again — training loops do; benchmarks and tests
    that replay a state must use the default.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl == "jnp":
        round_fn = _make_round_jnp(plan, vgrads, gamma, robust, momentum)
    else:
        round_fn = _make_round_pallas(plan, vgrads, gamma, robust, momentum,
                                      interpret)
    if donate:
        round_fn = jax.jit(round_fn, donate_argnums=(0,))
    return round_fn


# --------------------------------------------------------------------- #
# impl="jnp": batched scatter/gather over dense padded edge arrays
# --------------------------------------------------------------------- #
def _make_round_jnp(plan: CommPlan, vgrads: VGradFn, gamma, robust, momentum):
    n = plan.n
    w_diag = jnp.asarray(plan.w_diag)
    a_diag = jnp.asarray(plan.a_diag)
    src_w = jnp.asarray(plan.src_w); dst_w = jnp.asarray(plan.dst_w)
    src_a = jnp.asarray(plan.src_a); dst_a = jnp.asarray(plan.dst_a)
    w_edge = jnp.asarray(plan.w_edge); a_edge = jnp.asarray(plan.a_edge)

    def round_fn(state: ProtocolState, batches, keys, masks=None):
        lr = gamma(state.step) if callable(gamma) else gamma

        # ---- (S1) local descent direction -------------------------------
        if momentum:
            m = jax.tree.map(lambda mm, zz: momentum_mix(mm, zz, momentum),
                             state.m, state.z)
            v = jax.tree.map(lambda xx, mm: descent_step(xx, mm, lr),
                             state.x, m)
        else:
            m = None
            v = jax.tree.map(lambda xx, zz: descent_step(xx, zz, lr),
                             state.x, state.z)

        # ---- (S2a) consensus pull over G(W) ------------------------------
        if masks is None and not robust:
            def mix_x(vl):
                out = w_diag.reshape((n,) + (1,) * (vl.ndim - 1)) * vl
                contrib = w_edge.reshape((-1,) + (1,) * (vl.ndim - 1)) \
                    * vl[src_w]
                return out.at[dst_w].add(contrib.astype(out.dtype))
            x_new = jax.tree.map(mix_x, v)
            mail_v = state.mail_v
        else:
            mk = jnp.ones((plan.e_pad,), jnp.float32) if masks is None \
                else masks
            def mix_robust(vl, ml):
                mshape = (-1,) + (1,) * (vl.ndim - 1)
                mkr = mk.reshape(mshape)
                recv = mailbox_merge(vl[src_w], ml, mkr)
                out = w_diag.reshape((n,) + (1,) * (vl.ndim - 1)) * vl
                contrib = w_edge.reshape(mshape) * recv
                return out.at[dst_w].add(contrib.astype(out.dtype)), recv
            pairs = jax.tree.map(mix_robust, v, state.mail_v)
            x_new = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda q: isinstance(q, tuple))
            mail_v = jax.tree.map(lambda p: p[1], pairs,
                                  is_leaf=lambda q: isinstance(q, tuple))

        # ---- (S2b) new gradient sample + robust tracking ------------------
        losses, g_new = vgrads(x_new, batches, keys)

        mk = jnp.ones((plan.e_pad,), jnp.float32) if masks is None else masks

        def track(zl, gl_new, gl_old, rho_l, buf_l):
            mshape = (-1,) + (1,) * (zl.ndim - 1)
            mkr = mk.reshape(mshape)
            diff = (mkr * (rho_l - buf_l)).astype(zl.dtype)
            recv = jnp.zeros_like(zl).at[dst_a].add(diff)
            z_half = tracking_step(zl, recv, gl_new, gl_old)
            # (S2c) split mass
            z_new = a_diag.reshape((n,) + (1,) * (zl.ndim - 1)) * z_half
            push = a_edge.reshape(mshape) * z_half[src_a]
            rho_new = rho_l + push.astype(rho_l.dtype)
            # (S4) buffers take consumed values
            buf_new = mailbox_merge(rho_l, buf_l, mkr)
            return z_new, rho_new, buf_new

        trip = jax.tree.map(track, state.z, g_new, state.g_prev,
                            state.rho, state.rho_buf)
        is3 = lambda q: isinstance(q, tuple)
        z_new = jax.tree.map(lambda t: t[0], trip, is_leaf=is3)
        rho_new = jax.tree.map(lambda t: t[1], trip, is_leaf=is3)
        buf_new = jax.tree.map(lambda t: t[2], trip, is_leaf=is3)

        new_state = ProtocolState(
            step=state.step + 1, x=x_new, z=z_new, g_prev=g_new,
            rho=rho_new, rho_buf=buf_new, mail_v=mail_v, m=m)
        return new_state, {"loss": losses.mean(), "losses": losses}

    return round_fn


# --------------------------------------------------------------------- #
# impl="pallas": one fused grid launch per round over the flat leaves
# --------------------------------------------------------------------- #
def _make_round_pallas(plan: CommPlan, vgrads: VGradFn, gamma, robust,
                       momentum, interpret):
    mode = dispatch.resolve_mode(interpret)
    n, e_pad = plan.n, plan.e_pad
    kw, ka, ko = plan.kw, plan.ka, plan.ko
    w_diag = jnp.asarray(plan.w_diag)
    a_diag = jnp.asarray(plan.a_diag)
    src_a = jnp.asarray(plan.src_a)
    dst_a = jnp.asarray(plan.dst_a)
    a_edge = jnp.asarray(plan.a_edge)
    src_w = jnp.asarray(plan.src_w)
    in_w_epos = jnp.asarray(plan.in_w_epos)
    in_w_src = jnp.asarray(plan.in_w_src)
    in_w_wt = jnp.asarray(plan.in_w_wt)
    in_a_epos = jnp.asarray(plan.in_a_epos)
    in_a_val = jnp.asarray(plan.in_a_val)
    out_a_epos = jnp.asarray(plan.out_a_epos)
    out_a_wt = jnp.asarray(plan.out_a_wt)
    # scatter targets: pad slots point past the edge array and are dropped
    in_scatter = jnp.asarray(
        np.where(plan.in_a_val > 0, plan.in_a_epos, e_pad)
        .astype(np.int32).reshape(-1))
    out_scatter = jnp.asarray(
        np.where(plan.out_a_val > 0, plan.out_a_epos, e_pad)
        .astype(np.int32).reshape(-1))

    def round_fn(state: ProtocolState, batches, keys, masks=None):
        lr = gamma(state.step) if callable(gamma) else gamma
        robust_path = robust or masks is not None
        mk = jnp.ones((e_pad,), jnp.float32) if masks is None else masks

        # ---- (S1) local descent direction -------------------------------
        if momentum:
            m = jax.tree.map(lambda mm, zz: momentum_mix(mm, zz, momentum),
                             state.m, state.z)
            z_eff = m
        else:
            m = None
            z_eff = state.z
        v = jax.tree.map(lambda xx, zz: descent_step(xx, zz, lr),
                         state.x, z_eff)

        # ---- (S2a) mailbox merge + gathered consensus pull ----------------
        # The gradient must be sampled AT the mixed point x⁺ (S.2b), so the
        # pull runs here in jnp; the fused kernel below re-derives the same
        # quantities while committing the bandwidth-bound protocol state.
        if robust_path:
            def edge_recv(vl, ml):
                mshape = (-1,) + (1,) * (vl.ndim - 1)
                mkr = mk.reshape(mshape)
                return mailbox_merge(vl[src_w], ml, mkr)
            vin_pool = jax.tree.map(edge_recv, v, state.mail_v)
            mail_v = vin_pool
            g_idx = in_w_epos
        else:
            vin_pool = v
            mail_v = state.mail_v
            g_idx = in_w_src
        v_in = jax.tree.map(lambda pool: pool[g_idx], vin_pool)  # (N,kw,...)

        def mix(vl, vin):
            wts = in_w_wt.reshape((n, kw) + (1,) * (vl.ndim - 1))
            wsd = w_diag.reshape((n,) + (1,) * (vl.ndim - 1))
            return wsd * vl + jnp.sum(wts * vin, axis=1)
        x_new = jax.tree.map(mix, v, v_in)

        losses, g_new = vgrads(x_new, batches, keys)

        # ---- fused commit: S.2b/c + S.4 in ONE pass -----------------------
        # x⁺ is committed from the jnp pull above (the exact point the
        # gradient saw), so the commit-only kernel variant is used: it
        # skips the x'/v output writes (2 of the full kernel's 5 output
        # streams) and the x/v_in input streams that feed only them.
        mask_in = mk[in_a_epos] * in_a_val          # (N, ka)
        x_leaves = jax.tree.leaves(state.x)
        z_leaves = jax.tree.leaves(state.z)
        gn_leaves = jax.tree.leaves(g_new)
        go_leaves = jax.tree.leaves(state.g_prev)
        rho_leaves = jax.tree.leaves(state.rho)
        buf_leaves = jax.tree.leaves(state.rho_buf)

        # group leaves by dtype so each group concatenates into one flat
        # (lead, P) vector -> a single kernel launch per group per round
        # (x dtype is irrelevant: x does not feed the commit-only kernel)
        groups: dict[tuple, list[int]] = {}
        for i in range(len(x_leaves)):
            key = (jnp.dtype(z_leaves[i].dtype),
                   jnp.dtype(gn_leaves[i].dtype),
                   jnp.dtype(rho_leaves[i].dtype))
            groups.setdefault(key, []).append(i)

        new_z: list = [None] * len(x_leaves)
        new_rho: list = [None] * len(x_leaves)
        new_buf: list = [None] * len(x_leaves)

        def one_node(z_, gn_, go_, ri_, rb_, mki_, ro_, ao_, as_):
            return rfast_commit(
                z_, gn_, go_, ri_, rb_, mki_, ro_, ao_, a_self=as_,
                impl="pallas", interpret=True)

        for idxs in groups.values():
            flat2 = lambda ls, lead: jnp.concatenate(
                [ls[i].reshape(lead, -1) for i in idxs], axis=1)
            z_f = flat2(z_leaves, n)
            gn_f = flat2(gn_leaves, n)
            go_f = flat2(go_leaves, n)
            rho_f = flat2(rho_leaves, e_pad)
            buf_f = flat2(buf_leaves, e_pad)

            if mode == "emulate":
                # Plan tables are trace-time CONSTANTS here (unlike the
                # engines' per-wave traced tables), so the grid twin's
                # honest CPU lowering is the fused edge-major program:
                # the TPU launch streams its gather blocks and never
                # materializes (N, k, P) neighbour stacks, and neither
                # should its emulation — same S.2b/c + S.4 blend, row
                # for row, bit-identical to the impl="jnp" track.
                mkr = mk[:, None]
                diff = (mkr * (rho_f - buf_f)).astype(z_f.dtype)
                recv = jnp.zeros_like(z_f).at[dst_a].add(diff)
                z_half = tracking_step(z_f, recv, gn_f, go_f)
                z_out = a_diag[:, None] * z_half
                push = a_edge[:, None] * z_half[src_a]
                rho_new_f = rho_f + push.astype(rho_f.dtype)
                buf_new_f = mailbox_merge(rho_f, buf_f, mkr)
            elif mode == "interpret":
                # per-node kernel in the interpreter: the oracle path
                z_out, rout_new, rbuf_new = jax.vmap(one_node)(
                    z_f, gn_f, go_f,
                    rho_f[in_a_epos], buf_f[in_a_epos], mask_in,
                    rho_f[out_a_epos], out_a_wt, a_diag)
            else:
                # ONE grid launch for the whole round: the edge-slot
                # tables gather rows of the flat leaves in-kernel
                P = z_f.shape[1]
                Pp = block_pad_width(P)
                if Pp != P:
                    wp = lambda a: jnp.pad(a, ((0, 0), (0, Pp - P)))
                    z_f2, gn_f2, go_f2 = wp(z_f), wp(gn_f), wp(go_f)
                    rho_f2, buf_f2 = wp(rho_f), wp(buf_f)
                else:
                    z_f2, gn_f2, go_f2 = z_f, gn_f, go_f
                    rho_f2, buf_f2 = rho_f, buf_f
                node_ids = jnp.arange(n, dtype=jnp.int32)
                z_out, rout_new, rbuf_new = commit_grid(
                    node_ids, node_ids, in_a_epos, in_a_epos, out_a_epos,
                    a_diag, mask_in, out_a_wt,
                    z_f2, gn_f2, go_f2, rho_f2, buf_f2, rho_f2, mode=mode)
                if Pp != P:
                    z_out = z_out[:, :P]
                    rout_new = rout_new[..., :P]
                    rbuf_new = rbuf_new[..., :P]

            if mode != "emulate":
                # scatter per-node slot results back to the edge-major
                # arrays (each real edge is owned by exactly one
                # (node, slot) pair; pad slots target index e_pad and
                # are dropped)
                rho_new_f = rho_f.at[out_scatter].set(
                    rout_new.astype(rho_f.dtype).reshape(n * ko, -1),
                    mode="drop")
                buf_new_f = buf_f.at[in_scatter].set(
                    rbuf_new.astype(buf_f.dtype).reshape(n * ka, -1),
                    mode="drop")

            off = 0
            for i in idxs:
                sz = max(1, int(np.prod(z_leaves[i].shape[1:])))
                new_z[i] = z_out[:, off:off + sz] \
                    .reshape(z_leaves[i].shape).astype(z_leaves[i].dtype)
                new_rho[i] = rho_new_f[:, off:off + sz] \
                    .reshape(rho_leaves[i].shape)
                new_buf[i] = buf_new_f[:, off:off + sz] \
                    .reshape(buf_leaves[i].shape)
                off += sz

        zdef = jax.tree.structure(state.z)
        new_state = ProtocolState(
            step=state.step + 1, x=x_new,
            z=jax.tree.unflatten(zdef, new_z),
            g_prev=g_new,
            rho=jax.tree.unflatten(jax.tree.structure(state.rho), new_rho),
            rho_buf=jax.tree.unflatten(jax.tree.structure(state.rho_buf),
                                       new_buf),
            mail_v=mail_v, m=m)
        return new_state, {"loss": losses.mean(), "losses": losses}

    return round_fn
