"""Communication topologies and weight matrices for R-FAST.

R-FAST communicates over two digraphs induced by weight matrices:

* ``W`` — **row-stochastic** (pull / consensus graph ``G(W)``).  Node ``i``
  pulls ``v_j`` from in-neighbours ``j`` with ``W[i, j] > 0``.
* ``A`` — **column-stochastic** (push / gradient-tracking graph ``G(A)``).
  Node ``i`` pushes scaled ``z`` mass to out-neighbours ``j`` with
  ``A[j, i] > 0``.

Assumption 1: positive diagonals, nonzero entries bounded below.
Assumption 2: ``G(W)`` and ``G(A)^T`` each contain a spanning tree, and at
least one pair of spanning trees shares a common root.

The convention throughout: an edge ``(j, i)`` means *j sends to i*; in
matrix form ``M[i, j] > 0``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "Topology",
    "binary_tree",
    "line",
    "directed_ring",
    "exponential",
    "mesh2d",
    "parameter_server",
    "robust_tree",
    "undirected_ring",
    "validate_weights",
    "spanning_tree_roots",
    "spanning_tree_roots_dense",
    "common_roots",
    "subgraph_topology",
    "bfs_tree_topology",
    "epoch_topology",
    "TOPOLOGIES",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A pair of weight matrices + metadata describing the comm graphs.

    ``active`` (optional, default all-true) marks the member node set of
    a *dynamic-membership epoch*: inactive nodes are isolated (identity
    row of W / column of A — they neither send nor receive), and the
    Assumption 1/2 checks plus :meth:`roots` apply to the active
    submatrix only.  All execution engines keep the full ``n``-row state
    layout regardless, so epochs of one run share shapes.
    """

    name: str
    n: int
    W: np.ndarray  # (n, n) row-stochastic, pull graph
    A: np.ndarray  # (n, n) column-stochastic, push graph
    active: np.ndarray | None = None   # (n,) bool; None = all active

    # ------------------------------------------------------------------ #
    def __post_init__(self) -> None:
        if self.active is None:
            validate_weights(self.W, self.A)
            return
        # np.array (not asarray): own the mask — callers may keep
        # mutating the array they passed in (epoch timeline sweeps)
        act = np.array(self.active, dtype=bool)
        if act.shape != (self.n,):
            raise ValueError(f"active mask must have shape ({self.n},)")
        if not act.any():
            raise ValueError("a topology epoch needs at least one "
                             "active node")
        object.__setattr__(self, "active", act)
        idx = np.nonzero(act)[0]
        off = np.nonzero(~act)[0]
        sub = np.ix_(idx, idx)
        if (np.any(self.W[np.ix_(off, idx)] > 0)
                or np.any(self.W[np.ix_(idx, off)] > 0)
                or np.any(self.A[np.ix_(off, idx)] > 0)
                or np.any(self.A[np.ix_(idx, off)] > 0)):
            raise ValueError("inactive nodes must be isolated "
                             "(no weight to or from an active node)")
        validate_weights(self.W[sub], self.A[sub])

    def active_mask(self) -> np.ndarray:
        """(n,) bool membership mask (all-true when ``active`` is None)."""
        if self.active is None:
            return np.ones(self.n, dtype=bool)
        return np.asarray(self.active, dtype=bool)

    # -- edge sets (excluding self-loops) ------------------------------- #
    def edges_W(self) -> list[tuple[int, int]]:
        """Edges (j, i): j sends v to i over G(W)."""
        return [(j, i) for i in range(self.n) for j in range(self.n)
                if i != j and self.W[i, j] > 0]

    def edges_A(self) -> list[tuple[int, int]]:
        """Edges (j, i): j pushes rho to i over G(A)."""
        return [(j, i) for i in range(self.n) for j in range(self.n)
                if i != j and self.A[i, j] > 0]

    def in_neighbors_W(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.W[i, j] > 0]

    def in_neighbors_A(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.A[i, j] > 0]

    def out_neighbors_W(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.W[j, i] > 0]

    def out_neighbors_A(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.A[j, i] > 0]

    def roots(self) -> list[int]:
        """Common roots R = R_W ∩ R_{A^T} (Assumption 2), restricted to
        the active submatrix for membership epochs (global node ids)."""
        if self.active is None:
            return common_roots(self.W, self.A)
        idx = np.nonzero(self.active)[0]
        sub = np.ix_(idx, idx)
        return [int(idx[r]) for r in common_roots(self.W[sub], self.A[sub])]

    @property
    def common_roots(self) -> list[int]:
        """Alias for :meth:`roots` (the Assumption-2 root set)."""
        return self.roots()

    @property
    def max_in_degree(self) -> int:
        deg_w = max(len(self.in_neighbors_W(i)) for i in range(self.n))
        deg_a = max(len(self.in_neighbors_A(i)) for i in range(self.n))
        return max(deg_w, deg_a)


# ---------------------------------------------------------------------- #
# validation helpers
# ---------------------------------------------------------------------- #
def validate_weights(W: np.ndarray, A: np.ndarray, atol: float = 1e-8) -> None:
    """Assumption 1 + 2 checks.  Raises ValueError on violation."""
    n = W.shape[0]
    if W.shape != (n, n) or A.shape != (n, n):
        raise ValueError("W and A must be square with matching size")
    if np.any(W < 0) or np.any(A < 0):
        raise ValueError("weights must be non-negative")
    if np.any(np.diag(W) <= 0) or np.any(np.diag(A) <= 0):
        raise ValueError("Assumption 1(i): diagonals must be positive")
    if not np.allclose(W.sum(axis=1), 1.0, atol=atol):
        raise ValueError("Assumption 1(ii): W must be row-stochastic")
    if not np.allclose(A.sum(axis=0), 1.0, atol=atol):
        raise ValueError("Assumption 1(ii): A must be column-stochastic")
    if not common_roots(W, A):
        raise ValueError("Assumption 2: G(W) and G(A^T) must share a root")


def _reachable_from(adj: np.ndarray, root: int) -> set[int]:
    """Nodes reachable from ``root`` following edges adj[i, j]>0 : j -> i."""
    n = adj.shape[0]
    seen = {root}
    stack = [root]
    while stack:
        u = stack.pop()
        for v in range(n):
            # u -> v exists iff adj[v, u] > 0
            if adj[v, u] > 0 and v not in seen:
                seen.add(v)
                stack.append(v)
    return seen


def spanning_tree_roots_dense(M: np.ndarray) -> list[int]:
    """Brute-force oracle: one dense O(n²) reachability scan per candidate
    root, O(n³) total.  Kept purely as the reference
    :func:`spanning_tree_roots` is pinned against in tests."""
    n = M.shape[0]
    return [r for r in range(n) if len(_reachable_from(M, r)) == n]


def _adjacency(M: np.ndarray) -> list[np.ndarray]:
    """Out-adjacency lists of G(M): ``adj[u]`` = successors of ``u``
    (edge u -> v iff ``M[v, u] > 0``), self-loops dropped."""
    nz_i, nz_j = np.nonzero(M > 0)
    keep = nz_i != nz_j
    nz_i, nz_j = nz_i[keep], nz_j[keep]          # edge nz_j -> nz_i
    order = np.argsort(nz_j, kind="stable")
    nz_i, nz_j = nz_i[order], nz_j[order]
    bounds = np.searchsorted(nz_j, np.arange(M.shape[0] + 1))
    return [nz_i[bounds[u]:bounds[u + 1]] for u in range(M.shape[0])]


def _bfs_mask(adj: list[np.ndarray], start: int) -> np.ndarray:
    """Boolean reachable-set of one BFS over adjacency lists (O(V+E))."""
    n = len(adj)
    seen = np.zeros(n, dtype=bool)
    seen[start] = True
    frontier = [start]
    while frontier:
        u = frontier.pop()
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                frontier.append(int(v))
    return seen


def spanning_tree_roots(M: np.ndarray) -> list[int]:
    """Roots r such that every node is reachable from r in G(M).

    ``G(M)`` has edge j -> i iff ``M[i, j] > 0`` (information flows j to i).

    One adjacency-list pass instead of the old per-candidate dense scan
    (O(n³)): the vertex finishing last in a full DFS sweep lies in a
    source SCC of the condensation, so it is the only possible root
    candidate — one forward BFS verifies it reaches everything, and the
    root set is then exactly its SCC, recovered by one backward BFS
    (every root reaches the candidate and vice versa).  Total cost:
    O(n²) adjacency build + three O(V+E) traversals, which keeps
    per-epoch re-election cheap at n ≥ 255.
    """
    n = M.shape[0]
    if n == 0:
        return []
    if n == 1:
        return [0]
    adj = _adjacency(M)

    # iterative DFS sweep over all vertices; record the global finish order
    visited = np.zeros(n, dtype=bool)
    last_finished = 0
    for s in range(n):
        if visited[s]:
            continue
        visited[s] = True
        stack: list[tuple[int, int]] = [(s, 0)]
        while stack:
            u, ptr = stack[-1]
            nxt = adj[u]
            while ptr < len(nxt) and visited[nxt[ptr]]:
                ptr += 1
            if ptr < len(nxt):
                v = int(nxt[ptr])
                stack[-1] = (u, ptr + 1)
                visited[v] = True
                stack.append((v, 0))
            else:
                stack.pop()
                last_finished = u

    cand = int(last_finished)
    if not _bfs_mask(adj, cand).all():
        return []                      # no vertex reaches everything
    # roots = SCC(cand): reach-to-cand ∩ reach-from-cand = reach-to-cand
    radj = _adjacency(M.T)             # reversed edges
    return [int(r) for r in np.nonzero(_bfs_mask(radj, cand))[0]]


def common_roots(W: np.ndarray, A: np.ndarray) -> list[int]:
    """R = R_W ∩ R_{A^T}: roots of spanning trees of G(W) and G(A^T)."""
    r_w = set(spanning_tree_roots(W))
    # G(A^T) has edge j->i iff A^T[i,j] = A[j,i] > 0, i.e. reversed push graph
    r_at = set(spanning_tree_roots(A.T))
    return sorted(r_w & r_at)


# ---------------------------------------------------------------------- #
# dynamic membership: restriction, re-election, tree rebuild
# ---------------------------------------------------------------------- #
def subgraph_topology(topo: Topology, active: np.ndarray,
                      name: str | None = None) -> Topology:
    """Restrict ``topo`` to the ``active`` node set, renormalizing.

    Weights to/from inactive nodes are dropped; every active row of W
    (column of A) is renormalized over its surviving support — the
    positive diagonal guarantees a nonzero normalizer, so Assumption 1
    survives restriction by construction.  Inactive nodes become
    isolated identity rows/columns so the full ``n``-shape state layout
    is preserved.  Raises ``ValueError`` when the restricted graphs lose
    Assumption 2 (no surviving common root) — the caller then falls back
    to a rebuild (:func:`bfs_tree_topology` via :func:`epoch_topology`).
    """
    act = np.asarray(active, dtype=bool)
    W = np.where(np.outer(act, act), topo.W, 0.0)
    A = np.where(np.outer(act, act), topo.A, 0.0)
    off = np.nonzero(~act)[0]
    W[off, off] = 1.0
    A[off, off] = 1.0
    W = W / W.sum(axis=1, keepdims=True)
    A = A / A.sum(axis=0, keepdims=True)
    return Topology(name or f"{topo.name}|sub{int(act.sum())}",
                    topo.n, W, A, active=act)


def bfs_tree_topology(topo: Topology, active: np.ndarray, root: int,
                      name: str | None = None) -> Topology:
    """Rebuild W/A spanning trees around ``root`` over the *undirected
    skeleton* of ``topo`` (the union of W- and A-edges in either
    direction) restricted to ``active``.

    This is the paper's Fig.-1 construction re-run at epoch time: a BFS
    tree from the elected root, G(W) oriented root → leaves (each node
    pulls from its parent) and G(A) reversed (each node pushes to its
    parent), so G(A^T) equals G(W) and ``root`` is the common root.
    Raises ``ValueError`` when the skeleton does not connect the active
    set — Assumption 2 is then unrecoverable for this membership.
    """
    act = np.asarray(active, dtype=bool)
    n = topo.n
    if not act[root]:
        raise ValueError(f"re-election root {root} is not active")
    skel = ((topo.W > 0) | (topo.W.T > 0)
            | (topo.A > 0) | (topo.A.T > 0)) & np.outer(act, act)
    np.fill_diagonal(skel, False)
    parent = np.full(n, -1, dtype=np.int64)
    seen = np.zeros(n, dtype=bool)
    seen[root] = True
    frontier = [root]
    while frontier:
        u = frontier.pop(0)
        for v in np.nonzero(skel[:, u] | skel[u, :])[0]:
            if not seen[v]:
                seen[v] = True
                parent[v] = u
                frontier.append(int(v))
    if not np.array_equal(seen, act):
        stranded = sorted(np.nonzero(act & ~seen)[0].tolist())
        raise ValueError(
            f"Assumption 2 unrecoverable: active nodes {stranded} are "
            f"disconnected from root {root} in the surviving skeleton")
    in_w: dict[int, list[int]] = {}
    out_a: dict[int, list[int]] = {}
    for i in np.nonzero(parent >= 0)[0]:
        in_w[int(i)] = [int(parent[i])]    # i pulls v from its parent
        out_a[int(i)] = [int(parent[i])]   # i pushes rho to its parent
    W = _row_stochastic_from_in_edges(n, in_w)
    A = _col_stochastic_from_out_edges(n, out_a)
    return Topology(name or f"{topo.name}|retree@{root}", n, W, A,
                    active=act)


def epoch_topology(topo: Topology, active: np.ndarray,
                   prefer: int | None = None,
                   name: str | None = None) -> Topology:
    """The per-epoch topology for membership set ``active``: restriction
    when Assumption 2 survives it, else root re-election + tree rebuild.

    The re-election rule (DESIGN.md §11): first try the renormalized
    restriction of the original W/A — if ``common_roots`` of the
    surviving subgraph is non-empty, the restriction IS the epoch
    topology (``prefer``, typically the previous root, wins when it is
    still a common root; otherwise the smallest surviving common root
    is the new root, but the weights need no rebuild).  Only when the
    restriction loses Assumption 2 entirely are the two trees rebuilt
    around a newly elected root via :func:`bfs_tree_topology` —
    ``prefer`` if active, else the smallest active node id.  Raises
    ``ValueError`` when the surviving skeleton is disconnected.
    """
    act = np.asarray(active, dtype=bool)
    try:
        return subgraph_topology(topo, act, name=name)
    except ValueError:
        pass
    root = (int(prefer) if prefer is not None and act[prefer]
            else int(np.nonzero(act)[0][0]))
    return bfs_tree_topology(topo, act, root, name=name)


# ---------------------------------------------------------------------- #
# weight-matrix builders
# ---------------------------------------------------------------------- #
def _row_stochastic_from_in_edges(n: int, in_edges: dict[int, list[int]]) -> np.ndarray:
    """Uniform row-stochastic W given each node's in-neighbour list."""
    W = np.zeros((n, n))
    for i in range(n):
        nbrs = sorted(set(in_edges.get(i, [])) - {i})
        w = 1.0 / (len(nbrs) + 1)
        W[i, i] = w
        for j in nbrs:
            W[i, j] = w
    return W


def _col_stochastic_from_out_edges(n: int, out_edges: dict[int, list[int]]) -> np.ndarray:
    """Uniform column-stochastic A given each node's out-neighbour list."""
    A = np.zeros((n, n))
    for i in range(n):
        nbrs = sorted(set(out_edges.get(i, [])) - {i})
        a = 1.0 / (len(nbrs) + 1)
        A[i, i] = a
        for j in nbrs:
            A[j, i] = a
    return A


def _tree_topology(name: str, n: int, parent: list[int | None]) -> Topology:
    """Build (W, A) from a rooted tree given parent pointers.

    G(W) = tree oriented root -> leaves (each node pulls from its parent).
    G(A) = reversed tree (each node pushes to its parent), so G(A^T) equals
    G(W) and the tree root is the common root (Fig. 1 construction).
    """
    in_w: dict[int, list[int]] = {}
    out_a: dict[int, list[int]] = {}
    for i, p in enumerate(parent):
        if p is None:
            continue
        in_w.setdefault(i, []).append(p)   # i pulls v from parent
        out_a.setdefault(i, []).append(p)  # i pushes rho to parent
    W = _row_stochastic_from_in_edges(n, in_w)
    A = _col_stochastic_from_out_edges(n, out_a)
    return Topology(name, n, W, A)


def _checked_builder(fn: Callable[..., Topology]) -> Callable[..., Topology]:
    """Wrap a topology builder so every constructed graph is re-validated
    (Assumption 1 weight structure + Assumption 2 common root) and any
    violation is reported with the *builder's* name, not just the matrix
    row that tripped.  ``Topology.__post_init__`` already validates, but a
    bare "W must be row-stochastic" from deep inside a sweep over eight
    builders is unattributable; this pins the blame."""
    import functools

    @functools.wraps(fn)
    def build(n: int, *args, **kwargs) -> Topology:
        try:
            topo = fn(n, *args, **kwargs)
            validate_weights(topo.W, topo.A)
        except ValueError as e:
            raise ValueError(
                f"topology builder {fn.__name__!r} (n={n}) produced an "
                f"invalid graph: {e}") from e
        if not topo.roots():
            raise ValueError(
                f"topology builder {fn.__name__!r} (n={n}) violates "
                "Assumption 2: G(W) and G(A^T) share no common root")
        return topo

    return build


@_checked_builder
def binary_tree(n: int) -> Topology:
    """Complete-ish binary tree rooted at node 0 (Fig. 3a)."""
    parent: list[int | None] = [None] + [(i - 1) // 2 for i in range(1, n)]
    return _tree_topology(f"binary_tree_{n}", n, parent)


@_checked_builder
def robust_tree(n: int) -> Topology:
    """Binary tree + bidirectional sibling rungs, sole common root 0.

    The ``root_failover`` topology: like :func:`binary_tree`, every node
    pulls v from its parent (W) and pushes ρ to it (A), so node 0 is the
    ONLY common root — but each sibling pair (1,2), (3,4), … is also
    linked both ways in both graphs.  A plain tree physically
    disconnects when the root dies; here the rung between 0's children
    keeps the surviving skeleton connected, so when 0 crashes the
    restricted subgraph still satisfies Assumption 2 with common roots
    {1, 2} and an epochized run can re-elect instead of stalling.
    """
    parent: list[int | None] = [None] + [(i - 1) // 2 for i in range(1, n)]
    in_w: dict[int, list[int]] = {}
    out_a: dict[int, list[int]] = {}
    for i, p in enumerate(parent):
        if p is not None:
            in_w.setdefault(i, []).append(p)
            out_a.setdefault(i, []).append(p)
    for i in range(1, n - 1, 2):        # sibling pairs (1,2), (3,4), ...
        for a, b in ((i, i + 1), (i + 1, i)):
            in_w.setdefault(a, []).append(b)
            out_a.setdefault(a, []).append(b)
    W = _row_stochastic_from_in_edges(n, in_w)
    A = _col_stochastic_from_out_edges(n, out_a)
    return Topology(f"robust_tree_{n}", n, W, A)


@_checked_builder
def line(n: int) -> Topology:
    """Line graph 0 - 1 - ... - n-1 rooted at 0 (Fig. 3c)."""
    parent: list[int | None] = [None] + list(range(n - 1))
    return _tree_topology(f"line_{n}", n, parent)


@_checked_builder
def parameter_server(n: int, n_servers: int = 1) -> Topology:
    """Star / PS structure: servers 0..n_servers-1 as common roots."""
    in_w: dict[int, list[int]] = {}
    out_a: dict[int, list[int]] = {}
    servers = list(range(n_servers))
    # servers form a ring among themselves (if >1) and broadcast to workers
    for s in servers:
        if n_servers > 1:
            in_w.setdefault(s, []).append(servers[(s - 1) % n_servers])
            out_a.setdefault(s, []).append(servers[(s + 1) % n_servers])
    for wk in range(n_servers, n):
        s = servers[wk % n_servers]
        in_w.setdefault(wk, []).append(s)   # worker pulls model from server
        out_a.setdefault(wk, []).append(s)  # worker pushes grads to server
    W = _row_stochastic_from_in_edges(n, in_w)
    A = _col_stochastic_from_out_edges(n, out_a)
    return Topology(f"ps_{n}_{n_servers}", n, W, A)


@_checked_builder
def directed_ring(n: int) -> Topology:
    """Directed ring i -> i+1 (mod n) for both graphs (Fig. 3b)."""
    in_edges = {i: [(i - 1) % n] for i in range(n)}
    out_edges = {i: [(i + 1) % n] for i in range(n)}
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, out_edges)
    return Topology(f"directed_ring_{n}", n, W, A)


@_checked_builder
def undirected_ring(n: int) -> Topology:
    """Symmetric ring (both directions) — used by D-PSGD/AD-PSGD baselines."""
    in_edges = {i: [(i - 1) % n, (i + 1) % n] for i in range(n)}
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, in_edges)
    return Topology(f"undirected_ring_{n}", n, W, A)


@_checked_builder
def exponential(n: int) -> Topology:
    """Directed exponential graph: i -> (i + 2^k) mod n."""
    hops = [2 ** k for k in range(max(1, int(np.ceil(np.log2(n)))))]
    in_edges = {i: sorted({(i - h) % n for h in hops} - {i}) for i in range(n)}
    out_edges = {i: sorted({(i + h) % n for h in hops} - {i}) for i in range(n)}
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, out_edges)
    return Topology(f"exponential_{n}", n, W, A)


@_checked_builder
def mesh2d(n: int) -> Topology:
    """2-D grid (4-neighbour, undirected) topology."""
    rows = int(np.floor(np.sqrt(n)))
    while n % rows:
        rows -= 1
    cols = n // rows
    def nid(r: int, c: int) -> int:
        return r * cols + c
    in_edges: dict[int, list[int]] = {i: [] for i in range(n)}
    for r in range(rows):
        for c in range(cols):
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                rr, cc = r + dr, c + dc
                if 0 <= rr < rows and 0 <= cc < cols:
                    in_edges[nid(r, c)].append(nid(rr, cc))
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, in_edges)
    return Topology(f"mesh2d_{n}", n, W, A)


TOPOLOGIES: dict[str, Callable[[int], Topology]] = {
    "binary_tree": binary_tree,
    "line": line,
    "directed_ring": directed_ring,
    "undirected_ring": undirected_ring,
    "exponential": exponential,
    "mesh2d": mesh2d,
    "parameter_server": parameter_server,
    "robust_tree": robust_tree,
}


def get_topology(name: str, n: int) -> Topology:
    if name not in TOPOLOGIES:
        raise KeyError(f"unknown topology {name!r}; have {sorted(TOPOLOGIES)}")
    return TOPOLOGIES[name](n)
