"""Communication topologies and weight matrices for R-FAST.

R-FAST communicates over two digraphs induced by weight matrices:

* ``W`` — **row-stochastic** (pull / consensus graph ``G(W)``).  Node ``i``
  pulls ``v_j`` from in-neighbours ``j`` with ``W[i, j] > 0``.
* ``A`` — **column-stochastic** (push / gradient-tracking graph ``G(A)``).
  Node ``i`` pushes scaled ``z`` mass to out-neighbours ``j`` with
  ``A[j, i] > 0``.

Assumption 1: positive diagonals, nonzero entries bounded below.
Assumption 2: ``G(W)`` and ``G(A)^T`` each contain a spanning tree, and at
least one pair of spanning trees shares a common root.

The convention throughout: an edge ``(j, i)`` means *j sends to i*; in
matrix form ``M[i, j] > 0``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "Topology",
    "binary_tree",
    "line",
    "directed_ring",
    "exponential",
    "mesh2d",
    "parameter_server",
    "undirected_ring",
    "validate_weights",
    "spanning_tree_roots",
    "common_roots",
    "TOPOLOGIES",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A pair of weight matrices + metadata describing the comm graphs."""

    name: str
    n: int
    W: np.ndarray  # (n, n) row-stochastic, pull graph
    A: np.ndarray  # (n, n) column-stochastic, push graph

    # ------------------------------------------------------------------ #
    def __post_init__(self) -> None:
        validate_weights(self.W, self.A)

    # -- edge sets (excluding self-loops) ------------------------------- #
    def edges_W(self) -> list[tuple[int, int]]:
        """Edges (j, i): j sends v to i over G(W)."""
        return [(j, i) for i in range(self.n) for j in range(self.n)
                if i != j and self.W[i, j] > 0]

    def edges_A(self) -> list[tuple[int, int]]:
        """Edges (j, i): j pushes rho to i over G(A)."""
        return [(j, i) for i in range(self.n) for j in range(self.n)
                if i != j and self.A[i, j] > 0]

    def in_neighbors_W(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.W[i, j] > 0]

    def in_neighbors_A(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.A[i, j] > 0]

    def out_neighbors_W(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.W[j, i] > 0]

    def out_neighbors_A(self, i: int) -> list[int]:
        return [j for j in range(self.n) if j != i and self.A[j, i] > 0]

    def roots(self) -> list[int]:
        """Common roots R = R_W ∩ R_{A^T} (Assumption 2)."""
        return common_roots(self.W, self.A)

    @property
    def max_in_degree(self) -> int:
        deg_w = max(len(self.in_neighbors_W(i)) for i in range(self.n))
        deg_a = max(len(self.in_neighbors_A(i)) for i in range(self.n))
        return max(deg_w, deg_a)


# ---------------------------------------------------------------------- #
# validation helpers
# ---------------------------------------------------------------------- #
def validate_weights(W: np.ndarray, A: np.ndarray, atol: float = 1e-8) -> None:
    """Assumption 1 + 2 checks.  Raises ValueError on violation."""
    n = W.shape[0]
    if W.shape != (n, n) or A.shape != (n, n):
        raise ValueError("W and A must be square with matching size")
    if np.any(W < 0) or np.any(A < 0):
        raise ValueError("weights must be non-negative")
    if np.any(np.diag(W) <= 0) or np.any(np.diag(A) <= 0):
        raise ValueError("Assumption 1(i): diagonals must be positive")
    if not np.allclose(W.sum(axis=1), 1.0, atol=atol):
        raise ValueError("Assumption 1(ii): W must be row-stochastic")
    if not np.allclose(A.sum(axis=0), 1.0, atol=atol):
        raise ValueError("Assumption 1(ii): A must be column-stochastic")
    if not common_roots(W, A):
        raise ValueError("Assumption 2: G(W) and G(A^T) must share a root")


def _reachable_from(adj: np.ndarray, root: int) -> set[int]:
    """Nodes reachable from ``root`` following edges adj[i, j]>0 : j -> i."""
    n = adj.shape[0]
    seen = {root}
    stack = [root]
    while stack:
        u = stack.pop()
        for v in range(n):
            # u -> v exists iff adj[v, u] > 0
            if adj[v, u] > 0 and v not in seen:
                seen.add(v)
                stack.append(v)
    return seen


def spanning_tree_roots(M: np.ndarray) -> list[int]:
    """Roots r such that every node is reachable from r in G(M).

    ``G(M)`` has edge j -> i iff ``M[i, j] > 0`` (information flows j to i).
    """
    n = M.shape[0]
    return [r for r in range(n) if len(_reachable_from(M, r)) == n]


def common_roots(W: np.ndarray, A: np.ndarray) -> list[int]:
    """R = R_W ∩ R_{A^T}: roots of spanning trees of G(W) and G(A^T)."""
    r_w = set(spanning_tree_roots(W))
    # G(A^T) has edge j->i iff A^T[i,j] = A[j,i] > 0, i.e. reversed push graph
    r_at = set(spanning_tree_roots(A.T))
    return sorted(r_w & r_at)


# ---------------------------------------------------------------------- #
# weight-matrix builders
# ---------------------------------------------------------------------- #
def _row_stochastic_from_in_edges(n: int, in_edges: dict[int, list[int]]) -> np.ndarray:
    """Uniform row-stochastic W given each node's in-neighbour list."""
    W = np.zeros((n, n))
    for i in range(n):
        nbrs = sorted(set(in_edges.get(i, [])) - {i})
        w = 1.0 / (len(nbrs) + 1)
        W[i, i] = w
        for j in nbrs:
            W[i, j] = w
    return W


def _col_stochastic_from_out_edges(n: int, out_edges: dict[int, list[int]]) -> np.ndarray:
    """Uniform column-stochastic A given each node's out-neighbour list."""
    A = np.zeros((n, n))
    for i in range(n):
        nbrs = sorted(set(out_edges.get(i, [])) - {i})
        a = 1.0 / (len(nbrs) + 1)
        A[i, i] = a
        for j in nbrs:
            A[j, i] = a
    return A


def _tree_topology(name: str, n: int, parent: list[int | None]) -> Topology:
    """Build (W, A) from a rooted tree given parent pointers.

    G(W) = tree oriented root -> leaves (each node pulls from its parent).
    G(A) = reversed tree (each node pushes to its parent), so G(A^T) equals
    G(W) and the tree root is the common root (Fig. 1 construction).
    """
    in_w: dict[int, list[int]] = {}
    out_a: dict[int, list[int]] = {}
    for i, p in enumerate(parent):
        if p is None:
            continue
        in_w.setdefault(i, []).append(p)   # i pulls v from parent
        out_a.setdefault(i, []).append(p)  # i pushes rho to parent
    W = _row_stochastic_from_in_edges(n, in_w)
    A = _col_stochastic_from_out_edges(n, out_a)
    return Topology(name, n, W, A)


def binary_tree(n: int) -> Topology:
    """Complete-ish binary tree rooted at node 0 (Fig. 3a)."""
    parent: list[int | None] = [None] + [(i - 1) // 2 for i in range(1, n)]
    return _tree_topology(f"binary_tree_{n}", n, parent)


def line(n: int) -> Topology:
    """Line graph 0 - 1 - ... - n-1 rooted at 0 (Fig. 3c)."""
    parent: list[int | None] = [None] + list(range(n - 1))
    return _tree_topology(f"line_{n}", n, parent)


def parameter_server(n: int, n_servers: int = 1) -> Topology:
    """Star / PS structure: servers 0..n_servers-1 as common roots."""
    in_w: dict[int, list[int]] = {}
    out_a: dict[int, list[int]] = {}
    servers = list(range(n_servers))
    # servers form a ring among themselves (if >1) and broadcast to workers
    for s in servers:
        if n_servers > 1:
            in_w.setdefault(s, []).append(servers[(s - 1) % n_servers])
            out_a.setdefault(s, []).append(servers[(s + 1) % n_servers])
    for wk in range(n_servers, n):
        s = servers[wk % n_servers]
        in_w.setdefault(wk, []).append(s)   # worker pulls model from server
        out_a.setdefault(wk, []).append(s)  # worker pushes grads to server
    W = _row_stochastic_from_in_edges(n, in_w)
    A = _col_stochastic_from_out_edges(n, out_a)
    return Topology(f"ps_{n}_{n_servers}", n, W, A)


def directed_ring(n: int) -> Topology:
    """Directed ring i -> i+1 (mod n) for both graphs (Fig. 3b)."""
    in_edges = {i: [(i - 1) % n] for i in range(n)}
    out_edges = {i: [(i + 1) % n] for i in range(n)}
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, out_edges)
    return Topology(f"directed_ring_{n}", n, W, A)


def undirected_ring(n: int) -> Topology:
    """Symmetric ring (both directions) — used by D-PSGD/AD-PSGD baselines."""
    in_edges = {i: [(i - 1) % n, (i + 1) % n] for i in range(n)}
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, in_edges)
    return Topology(f"undirected_ring_{n}", n, W, A)


def exponential(n: int) -> Topology:
    """Directed exponential graph: i -> (i + 2^k) mod n."""
    hops = [2 ** k for k in range(max(1, int(np.ceil(np.log2(n)))))]
    in_edges = {i: sorted({(i - h) % n for h in hops} - {i}) for i in range(n)}
    out_edges = {i: sorted({(i + h) % n for h in hops} - {i}) for i in range(n)}
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, out_edges)
    return Topology(f"exponential_{n}", n, W, A)


def mesh2d(n: int) -> Topology:
    """2-D grid (4-neighbour, undirected) topology."""
    rows = int(np.floor(np.sqrt(n)))
    while n % rows:
        rows -= 1
    cols = n // rows
    def nid(r: int, c: int) -> int:
        return r * cols + c
    in_edges: dict[int, list[int]] = {i: [] for i in range(n)}
    for r in range(rows):
        for c in range(cols):
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                rr, cc = r + dr, c + dc
                if 0 <= rr < rows and 0 <= cc < cols:
                    in_edges[nid(r, c)].append(nid(rr, cc))
    W = _row_stochastic_from_in_edges(n, in_edges)
    A = _col_stochastic_from_out_edges(n, in_edges)
    return Topology(f"mesh2d_{n}", n, W, A)


TOPOLOGIES: dict[str, Callable[[int], Topology]] = {
    "binary_tree": binary_tree,
    "line": line,
    "directed_ring": directed_ring,
    "undirected_ring": undirected_ring,
    "exponential": exponential,
    "mesh2d": mesh2d,
    "parameter_server": parameter_server,
}


def get_topology(name: str, n: int) -> Topology:
    if name not in TOPOLOGIES:
        raise KeyError(f"unknown topology {name!r}; have {sorted(TOPOLOGIES)}")
    return TOPOLOGIES[name](n)
