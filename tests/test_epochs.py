"""Epochized engine tests (dynamic membership, PR 7): oracle equality on
static traces, mass conservation across migrations, the root-failover
re-election claim (epochized converges, frozen-plan provably stalls),
and the one-compile contract for the pallas dispatch cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    binary_tree, get_scenario, init_state, migrate_state,
    realize_epochs_batch, robust_tree, run_epochs, run_rfast,
    run_sweep_epochs,
)
from repro.core.plan import as_comm_plan
from repro.data import make_logistic_problem

jax.config.update("jax_enable_x64", False)


def _problem(n, seed=0):
    return make_logistic_problem(n, m=700, d=16, batch=8,
                                 heterogeneous=True, seed=seed)


def _quad_gfn(n, p, seed=0):
    """Cheap deterministic quadratic for the fast-tier migration tests."""
    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)

    def gfn(i, x, key):
        del key
        return x - C[i]

    return gfn


# ------------------------------------------------------------------ #
# static traces: the epochized engine IS run_rfast
# ------------------------------------------------------------------ #
@pytest.mark.slow
@pytest.mark.parametrize("sc_name", ["uniform", "straggler"])
def test_single_epoch_matches_run_rfast_oracle(sc_name):
    n, K = 7, 400
    prob = _problem(n)
    topo = binary_tree(n)
    sc = get_scenario(sc_name, n)
    tr = sc.realize(topo, K, seed=3)
    et = sc.realize_epochs(topo, K, seed=3)
    assert len(et.epochs) == 1
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    ev = lambda s, t: {"m": float(jnp.sum(jnp.abs(s.x))), "t": t}
    st_o, ms_o = run_rfast(topo, tr.schedule, prob, x0, 5e-3, seed=3,
                           eval_every=100, eval_fn=ev, mode="wavefront")
    st_e, ms_e = run_epochs(et, prob, x0, 5e-3, seed=3,
                            eval_every=100, eval_fn=ev)
    np.testing.assert_allclose(np.asarray(st_o.x), np.asarray(st_e.x),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_o.z), np.asarray(st_e.z),
                               rtol=1e-6, atol=1e-6)
    assert [m["t"] for m in ms_o] == [m["t"] for m in ms_e]
    np.testing.assert_allclose([m["m"] for m in ms_o],
                               [m["m"] for m in ms_e], rtol=1e-6)


# ------------------------------------------------------------------ #
# migration invariants
# ------------------------------------------------------------------ #
def test_migrate_state_conserves_tracked_mass():
    """Σz + Σ(ρ−ρ̃) − Σg_prev is invariant under migration: in-flight
    mass settles at receivers, a departed node's surplus moves to the
    new root, joiners enter neutrally (z = g_prev = 0)."""
    n, p = 8, 5
    topo = robust_tree(n)
    sc = get_scenario("root_failover", n)
    et = sc.realize_epochs(topo, 1200, seed=1)
    ep0, ep1 = et.epochs
    H = 6
    st = init_state(as_comm_plan(ep0.topology), jnp.zeros((n, p)),
                    _quad_gfn(n, p), jax.random.PRNGKey(0), H)
    # fake undelivered in-flight mass on the ρ/ρ̃ buffers
    e0 = max(1, as_comm_plan(ep0.topology).n_edges_a)
    st = st._replace(rho=st.rho.at[:e0].add(0.37),
                     rho_buf=st.rho_buf.at[: e0 // 2].add(0.11))

    def surplus(s):
        return (float(jnp.sum(s.z)) + float(jnp.sum(s.rho - s.rho_buf))
                - float(jnp.sum(s.g_prev)))

    before = surplus(st)
    mig = migrate_state(st, ep0.topology, ep1, H=H)
    assert abs(surplus(mig) - before) < 1e-3
    # departed root zeroed out, nothing in flight, v carried in slot 0
    assert float(jnp.sum(jnp.abs(mig.z[0]))) == 0.0
    assert float(jnp.sum(jnp.abs(mig.rho))) == 0.0
    assert bool(jnp.all(mig.v_hist[0] == mig.v))


def test_migrate_state_joiner_adopts_root_iterate():
    n, p = 7, 5
    topo = robust_tree(n)
    sc = get_scenario("churn", n)
    et = sc.realize_epochs(topo, 1400, seed=0)
    e0, e1 = et.epochs[0], et.epochs[1]
    assert e1.joined.any()
    j = int(np.nonzero(e1.joined)[0][0])
    H = 6
    st = init_state(as_comm_plan(e0.topology), jnp.zeros((n, p)),
                    _quad_gfn(n, p), jax.random.PRNGKey(0), H)
    st = st._replace(x=st.x.at[:].add(
        jnp.arange(n, dtype=jnp.float32)[:, None]))
    mig = migrate_state(st, e0.topology, e1, H=H)
    np.testing.assert_array_equal(np.asarray(mig.x[j]),
                                  np.asarray(st.x[e1.root]))
    assert float(jnp.sum(jnp.abs(mig.z[j]))) == 0.0
    assert float(jnp.sum(jnp.abs(mig.g_prev[j]))) == 0.0


# ------------------------------------------------------------------ #
# the headline claim: re-election converges, frozen plan stalls
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_root_failover_epochized_converges_frozen_stalls():
    n, rounds, gamma = 8, 150, 2e-3
    K = rounds * n
    prob = make_logistic_problem(n, m=2800, d=64, batch=16,
                                 heterogeneous=True, seed=0)
    topo = robust_tree(n)
    sc = get_scenario("root_failover", n)
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    ev = lambda s, t: {"loss": float(prob.mean_loss(jnp.mean(s.x, 0))),
                       "t": t}
    et = sc.realize_epochs(topo, K, seed=0)
    assert len(et.epochs) == 2 and et.epochs[1].root != 0
    _, ms_e = run_epochs(et, prob, x0, gamma, seed=0,
                         eval_every=max(100, K // 40), eval_fn=ev)
    tr = sc.realize(topo, K, seed=0)
    _, ms_f = run_rfast(topo, tr.schedule, prob, x0, gamma, seed=0,
                        eval_every=max(100, K // 40), eval_fn=ev,
                        mode="wavefront")
    post_e = [m["loss"] for m in ms_e if m["t"] > 40.0]
    post_f = [m["loss"] for m in ms_f if m["t"] > 40.0]
    # epochized: still descending after the crash — the last post-crash
    # loss is well below the first
    assert ms_e[-1]["loss"] < 0.7 * post_e[0]
    # frozen: provably stalled — the plateau never moves more than 5%
    # from its post-crash level, and ends far above the epochized run
    assert max(post_f) < 1.05 * min(post_f)
    assert ms_f[-1]["loss"] > 1.5 * ms_e[-1]["loss"]


# ------------------------------------------------------------------ #
# fleet + one-compile contract
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_sweep_epochs_lane_matches_solo_run():
    n, K = 8, 900
    prob = _problem(n)
    topo = robust_tree(n)
    seeds = (0, 1)
    traces = realize_epochs_batch(topo, K,
                                  scenario=get_scenario("root_failover", n),
                                  seeds=seeds)
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    ev = lambda s, t: {"m": float(jnp.sum(jnp.abs(s.x))), "t": t}
    sts, mss = run_sweep_epochs(traces, prob, x0, 5e-3, seeds=list(seeds),
                                eval_every=300, eval_fn=ev)
    st0, ms0 = run_epochs(traces[0], prob, x0, 5e-3, seed=0,
                          eval_every=300, eval_fn=ev)
    np.testing.assert_allclose(np.asarray(sts[0].x), np.asarray(st0.x),
                               rtol=1e-6, atol=1e-6)
    assert [m["m"] for m in mss[0]] == [m["m"] for m in ms0]


@pytest.mark.slow
def test_churn_dispatch_cache_one_entry_per_shape():
    """A 3-epoch churn run under impl='pallas' must reuse ONE compiled
    commit_grid entry: epoch transitions change data, never shapes."""
    from tests.helpers.recompiles import assert_no_recompiles
    n, K = 7, 1400
    prob = _problem(n)
    topo = robust_tree(n)
    et = get_scenario("churn", n).realize_epochs(topo, K, seed=0)
    assert len(et.epochs) == 3
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    with assert_no_recompiles(expect_entries=1):
        st_p, _ = run_epochs(et, prob, x0, 5e-3, seed=0, impl="pallas")
    # and the pallas path agrees with the jnp path on the same trace
    st_j, _ = run_epochs(et, prob, x0, 5e-3, seed=0, impl="jnp")
    np.testing.assert_allclose(np.asarray(st_p.x), np.asarray(st_j.x),
                               rtol=2e-5, atol=2e-5)
