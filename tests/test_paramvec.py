"""The flat-parameter substrate: ravel/unravel, the GradProvider
protocol, and real-model gradients through the asynchronous engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (as_grad_fn, binary_tree, generate_schedule,
                        make_ravel_spec, ravel, run_rfast, tracked_mass,
                        unravel)
from repro.core.paramvec import GradProvider, ModelGradProvider
from repro.data import make_lm_problem

jax.config.update("jax_enable_x64", False)


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.full((5,), -2.0, jnp.bfloat16),
            "nested": {"s": jnp.asarray(3.5, jnp.float32)}}


# ------------------------------------------------------------------ #
# ravel / unravel
# ------------------------------------------------------------------ #
def test_ravel_roundtrip_shapes_dtypes():
    tree = _tree()
    spec = make_ravel_spec(tree)
    assert spec.p == spec.p_model == 12 + 5 + 1
    flat = ravel(spec, tree)
    assert flat.shape == (spec.p,) and flat.dtype == jnp.float32
    back = unravel(spec, flat)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ravel_padding_and_tail_zeros():
    tree = _tree()
    spec = make_ravel_spec(tree, pad_to=128)
    assert spec.p == 128 and spec.p_model == 18
    flat = ravel(spec, tree)
    np.testing.assert_array_equal(np.asarray(flat[spec.p_model:]), 0.0)
    # padding is invisible to unravel
    back = unravel(spec, flat + 0.0)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    # traced usage: ravel/unravel compose under jit
    f = jax.jit(lambda v: ravel(spec, jax.tree.map(lambda l: 2 * l,
                                                   unravel(spec, v))))
    np.testing.assert_allclose(np.asarray(f(flat))[:spec.p_model],
                               2 * np.asarray(flat)[:spec.p_model],
                               rtol=1e-6)


def test_ravel_leaf_count_mismatch():
    spec = make_ravel_spec(_tree())
    with pytest.raises(ValueError):
        ravel(spec, {"w": jnp.zeros((3, 4))})
    with pytest.raises(ValueError):
        make_ravel_spec(_tree(), pad_to=0)


# ------------------------------------------------------------------ #
# objective resolution
# ------------------------------------------------------------------ #
def test_as_grad_fn_passthrough_and_provider():
    def gfn(i, x, key):
        return x
    assert as_grad_fn(gfn) is gfn      # bare callables stay bit-exact

    class P:
        n, p = 2, 4
        def grad_fn(self):
            return gfn
    assert isinstance(P(), GradProvider)
    assert as_grad_fn(P()) is gfn
    with pytest.raises(TypeError):
        as_grad_fn(42)


def test_model_grad_provider_matches_direct_grad():
    """The provider's flat gradient == ravel of the pytree gradient."""
    spec = make_ravel_spec({"w": jnp.zeros((3, 2)), "b": jnp.zeros(3)},
                           pad_to=8)

    def vg(params, batch, key):
        del key
        loss = lambda p: jnp.sum((batch @ p["w"].T + p["b"]) ** 2)
        return loss(params), jax.grad(loss)(params)

    def batch_fn(i, key):
        return jax.random.normal(key, (4, 2))

    prov = ModelGradProvider(spec=spec, n_nodes=3, value_and_grad=vg,
                             batch_fn=batch_fn)
    assert (prov.n, prov.p) == (3, 16)   # p_model = 9 -> padded to 16
    gfn = prov.grad_fn()
    key = jax.random.PRNGKey(7)
    params = {"w": jnp.ones((3, 2)), "b": jnp.full((3,), 0.5)}
    x_flat = ravel(spec, params)
    g_flat = gfn(jnp.asarray(1), x_flat, key)
    # replay the provider's own sampling to get the reference batch
    bkey, gkey = jax.random.split(key)
    batch = batch_fn(1, jax.random.fold_in(bkey, 1))
    _, g_ref = vg(params, batch, gkey)
    np.testing.assert_allclose(np.asarray(g_flat),
                               np.asarray(ravel(spec, g_ref)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(g_flat[spec.p_model:]), 0.0)


# ------------------------------------------------------------------ #
# the reduced LM through the engines
# ------------------------------------------------------------------ #
def _tiny_lm(n):
    cfg = get_config("rfast-100m").reduced(max_d_model=32, vocab=64)
    return make_lm_problem(cfg, n, batch_per_node=2, seq_len=16,
                           eval_batch=4)


def test_lm_problem_grad_contract():
    prob = _tiny_lm(3)
    gfn = prob.grad_fn()
    g = gfn(jnp.asarray(0), prob.x0_flat, jax.random.PRNGKey(0))
    assert g.shape == (prob.p,)
    assert np.isfinite(np.asarray(g)).all()
    np.testing.assert_array_equal(np.asarray(g[prob.spec.p_model:]), 0.0)
    l0 = float(prob.mean_loss(prob.x0_flat))
    assert 0.0 < l0 < 3 * np.log(prob.shard.vocab)


@pytest.mark.slow
def test_lm_wavefront_matches_event_and_learns():
    """The transformer rides the PackedState lanes: wavefront == event
    oracle on the LM objective, Lemma 3 holds on the padded lane, and
    the eval loss decreases."""
    n, K = 3, 45
    prob = _tiny_lm(n)
    topo = binary_tree(n)
    sched = generate_schedule(topo, K, latency=0.3, seed=0)
    x0 = jnp.tile(prob.x0_flat[None], (n, 1))
    s_ev, _ = run_rfast(topo, sched, prob, x0, 5e-2, mode="event")
    s_wf, _ = run_rfast(topo, sched, prob, x0, 5e-2, mode="wavefront")
    for f in ("x", "v", "z", "g_prev", "rho", "rho_buf"):
        np.testing.assert_allclose(
            np.asarray(getattr(s_wf, f)), np.asarray(getattr(s_ev, f)),
            rtol=2e-4, atol=2e-5, err_msg=f)
    np.testing.assert_allclose(
        np.asarray(tracked_mass(s_wf)),
        np.asarray(s_wf.g_prev.sum(axis=0)), rtol=2e-4, atol=2e-4)
    l0 = float(prob.mean_loss(prob.x0_flat))
    l1 = float(prob.mean_loss(jnp.asarray(s_wf.x).mean(0)))
    assert l1 < l0 - 0.3, (l0, l1)
