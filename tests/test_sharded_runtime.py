"""Multi-device shard_map runtime: equivalence + matchings unit tests.

The heavy check runs in a subprocess so the 8 host-platform devices don't
leak into this process's jax (tests must see 1 device).
"""
import os
import subprocess
import sys

import pytest

from repro.core import binary_tree, directed_ring, exponential
from repro.core.runtime_sharded import matchings


def test_matchings_cover_and_unique():
    for topo in (binary_tree(7), directed_ring(8), exponential(8)):
        for edges in (topo.edges_W(), topo.edges_A()):
            slots = matchings(edges)
            flat = [e for s in slots for e in s]
            assert sorted(flat) == sorted(edges)
            for s in slots:
                srcs = [j for j, _ in s]
                dsts = [i for _, i in s]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)


def test_tree_needs_two_matchings():
    slots = matchings(binary_tree(7).edges_W())
    assert len(slots) == 2      # binary tree: out-degree 2
    assert len(matchings(directed_ring(8).edges_W())) == 1


@pytest.mark.slow
def test_sharded_runtime_equivalence_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath("src")
    r = subprocess.run(
        [sys.executable, os.path.join("tests", "helpers", "sharded_equiv.py")],
        env=env, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK dense-vs-sharded" in r.stdout
    assert "OK robust sharded runtime" in r.stdout
