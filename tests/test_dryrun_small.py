"""CI-scale dry-run: lower+compile reduced configs on a 16-device
host-platform mesh in a subprocess (full production sweep is
``python -m repro.launch.dryrun --all --both-meshes``)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_small_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join("tests", "helpers", "dryrun_small.py")],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "DRYRUN-SMALL-PASS" in r.stdout


@pytest.mark.slow
def test_specs_all_archs_subprocess():
    """All 10 archs x 4 shapes: spec construction + sharding divisibility
    (struct-level, no compile)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join("tests", "helpers", "specs_all.py")],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "SPECS-ALL-PASS" in r.stdout
