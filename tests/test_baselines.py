"""Baseline algorithms: convergence sanity + known robustness gaps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import directed_ring, undirected_ring
from repro.core.baselines import (
    metropolis_weights, run_adpsgd, run_dpsgd, run_osgp, run_ring_allreduce,
    run_sab,
)
from tests.test_simulator import quad_grad_fn


def test_metropolis_doubly_stochastic():
    topo = undirected_ring(8)
    Wm = metropolis_weights(topo)
    np.testing.assert_allclose(Wm.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(Wm.sum(1), 1.0, atol=1e-12)
    assert np.all(Wm >= 0)


def test_ring_allreduce_converges():
    n, p = 5, 6
    gfn, x_star = quad_grad_fn(n, p)
    x, _ = run_ring_allreduce(n, gfn, jnp.zeros(p), gamma=0.1, rounds=400)
    assert np.linalg.norm(np.asarray(x) - np.asarray(x_star)) < 1e-3


def test_sab_converges():
    n, p = 5, 6
    topo = directed_ring(n)
    gfn, x_star = quad_grad_fn(n, p)
    x, _ = run_sab(topo, gfn, jnp.zeros((n, p)), gamma=0.08, rounds=800)
    err = np.linalg.norm(np.asarray(x) - np.asarray(x_star)[None], axis=1).max()
    assert err < 1e-3


def test_dpsgd_biased_under_heterogeneity():
    """D-PSGD's fixed point shifts under heterogeneous data + unequal
    curvatures — the ς-dependence R-FAST removes (Remark 7)."""
    n, p = 5, 4
    topo = undirected_ring(n)
    gfn, x_star = quad_grad_fn(n, p, seed=3)
    x, _ = run_dpsgd(topo, gfn, jnp.zeros((n, p)), gamma=0.05, rounds=3000)
    err = np.linalg.norm(np.asarray(x).mean(0) - np.asarray(x_star))
    # converges to a *neighbourhood*, not the exact optimum
    assert err < 1.0
    assert err > 1e-4


def test_adpsgd_converges_homogeneous():
    n, p = 5, 4
    topo = undirected_ring(n)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.normal(0, 1, p), jnp.float32)

    def gfn(i, x, key):
        return x - c  # homogeneous

    x, _ = run_adpsgd(topo, gfn, jnp.zeros((n, p)), gamma=0.05, K=4000)
    err = np.linalg.norm(np.asarray(x) - np.asarray(c)[None], axis=1).max()
    assert err < 1e-2, err


def test_osgp_converges_no_loss():
    n, p = 5, 4
    topo = directed_ring(n)
    gfn, x_star = quad_grad_fn(n, p)
    x, _ = run_osgp(topo, gfn, jnp.zeros((n, p)), gamma=0.03, K=12000)
    err = np.linalg.norm(np.asarray(x).mean(0) - np.asarray(x_star))
    assert err < 0.3, err


def test_osgp_degrades_with_loss_rfast_does_not():
    """The paper's core robustness claim: push-sum loses mass under packet
    loss; R-FAST's running-sum ρ recovers it."""
    from repro.core import binary_tree, generate_schedule, run_rfast

    n, p, loss = 5, 4, 0.3
    gfn, x_star = quad_grad_fn(n, p, seed=1)

    topo_d = directed_ring(n)
    x_osgp, _ = run_osgp(topo_d, gfn, jnp.zeros((n, p)), gamma=0.03,
                         K=12000, loss_prob=loss, seed=0)
    err_osgp = np.linalg.norm(np.asarray(x_osgp).mean(0) - np.asarray(x_star))

    topo_r = binary_tree(n)
    sched = generate_schedule(topo_r, 12000, loss_prob=loss, latency=0.5)
    state, _ = run_rfast(topo_r, sched, gfn, jnp.zeros((n, p)), gamma=0.03)
    err_rfast = np.linalg.norm(np.asarray(state.x).mean(0) - np.asarray(x_star))

    assert err_rfast < 1e-2, err_rfast
    assert err_osgp > 2 * err_rfast, (err_osgp, err_rfast)
