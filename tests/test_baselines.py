"""Baseline algorithms: convergence sanity + known robustness gaps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NetworkScenario, directed_ring, undirected_ring
from repro.core.baselines import (
    metropolis_weights, run_adpsgd, run_dpsgd, run_osgp, run_push_pull_sync,
    run_ring_allreduce, run_sab,
)
from tests.test_simulator import quad_grad_fn


def test_metropolis_doubly_stochastic():
    topo = undirected_ring(8)
    Wm = metropolis_weights(topo)
    np.testing.assert_allclose(Wm.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(Wm.sum(1), 1.0, atol=1e-12)
    assert np.all(Wm >= 0)


def test_ring_allreduce_converges():
    n, p = 5, 6
    gfn, x_star = quad_grad_fn(n, p)
    x, _ = run_ring_allreduce(n, gfn, jnp.zeros(p), gamma=0.1, rounds=400)
    assert np.linalg.norm(np.asarray(x) - np.asarray(x_star)) < 1e-3


def test_sab_converges():
    n, p = 5, 6
    topo = directed_ring(n)
    gfn, x_star = quad_grad_fn(n, p)
    x, _ = run_sab(topo, gfn, jnp.zeros((n, p)), gamma=0.08, rounds=800)
    err = np.linalg.norm(np.asarray(x) - np.asarray(x_star)[None], axis=1).max()
    assert err < 1e-3


def test_dpsgd_biased_under_heterogeneity():
    """D-PSGD's fixed point shifts under heterogeneous data + unequal
    curvatures — the ς-dependence R-FAST removes (Remark 7)."""
    n, p = 5, 4
    topo = undirected_ring(n)
    gfn, x_star = quad_grad_fn(n, p, seed=3)
    x, _ = run_dpsgd(topo, gfn, jnp.zeros((n, p)), gamma=0.05, rounds=3000)
    err = np.linalg.norm(np.asarray(x).mean(0) - np.asarray(x_star))
    # converges to a *neighbourhood*, not the exact optimum
    assert err < 1.0
    assert err > 1e-4


def test_adpsgd_converges_homogeneous():
    n, p = 5, 4
    topo = undirected_ring(n)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.normal(0, 1, p), jnp.float32)

    def gfn(i, x, key):
        return x - c  # homogeneous

    x, _ = run_adpsgd(topo, gfn, jnp.zeros((n, p)), gamma=0.05, K=4000)
    err = np.linalg.norm(np.asarray(x) - np.asarray(c)[None], axis=1).max()
    assert err < 1e-2, err


def test_osgp_converges_no_loss():
    n, p = 5, 4
    topo = directed_ring(n)
    gfn, x_star = quad_grad_fn(n, p)
    x, _ = run_osgp(topo, gfn, jnp.zeros((n, p)), gamma=0.03, K=12000)
    err = np.linalg.norm(np.asarray(x).mean(0) - np.asarray(x_star))
    assert err < 0.3, err


@pytest.mark.parametrize("staleness", [0, 1, 3])
def test_adpsgd_staleness_semantics(staleness):
    """Regression pin for the staleness bug: the gradient at event k must
    be evaluated at the active node's row of the global state as of
    ``staleness`` events ago.  Mixing is disabled (loss=1) and the
    dynamics linearized (g = x) so a host-side reference reproduces the
    scan exactly."""
    n, p, K, gamma = 3, 4, 200, 0.05
    topo = undirected_ring(n)
    sc = NetworkScenario(loss=1.0)

    def gfn(i, x, key):
        return x

    rng = np.random.default_rng(0)
    x0 = rng.normal(0, 1, (n, p)).astype(np.float32)
    x, _ = run_adpsgd(topo, gfn, jnp.asarray(x0), gamma, K,
                      scenario=sc, staleness=staleness, seed=0)

    # reference: hist[j] = global state after j events
    sched = sc.realize(topo, K, seed=0).schedule
    xr = x0.copy()
    hist = [x0.copy()]
    for k, a in enumerate(sched.agent):
        src = hist[max(0, k - staleness)]      # state `staleness` events ago
        xr = xr.copy()
        xr[a] = xr[a] - gamma * src[a]
        hist.append(xr)
    np.testing.assert_allclose(np.asarray(x), xr, rtol=1e-5, atol=1e-6)


def test_adpsgd_staleness_parameter_matters():
    """The staleness knob must change the trajectory (it used to be
    silently ignored)."""
    n, p, K = 3, 4, 150
    topo = undirected_ring(n)
    sc = NetworkScenario(loss=1.0)
    gfn = lambda i, x, key: x  # noqa: E731
    x0 = jnp.asarray(np.random.default_rng(1).normal(0, 1, (n, p)),
                     jnp.float32)
    x1, _ = run_adpsgd(topo, gfn, x0, 0.05, K, scenario=sc, staleness=1)
    x3, _ = run_adpsgd(topo, gfn, x0, 0.05, K, scenario=sc, staleness=3)
    assert not np.allclose(np.asarray(x1), np.asarray(x3))


@pytest.mark.parametrize("scenario_name", ["crash_recovery", "straggler"])
def test_adpsgd_partner_reads_never_alias_history(scenario_name):
    """Regression: the a->b stamp is refreshed only when b wakes, so a
    crash window (or a slow partner) drives its staleness far past
    sched.D — the partner-read ring slots must clamp to D_max instead of
    aliasing to a wrong (much fresher) snapshot.  run_adpsgd asserts the
    no-alias invariant host-side; this run crosses both crash windows."""
    from repro.core import get_scenario

    n = 8
    sc = get_scenario(scenario_name, n)
    gfn = lambda i, x, key: 0.1 * x  # noqa: E731
    x0 = jnp.ones((n, 3))
    x, _ = run_adpsgd(undirected_ring(n), gfn, x0, 0.05, 5000,
                      scenario=sc, seed=0)
    assert np.all(np.isfinite(np.asarray(x)))


def test_eval_fn_receives_bare_iterate_everywhere():
    """Uniform eval_fn contract: every baseline hands the iterate array
    (never the raw carry tuple) and a float virtual time."""
    n, p = 5, 4
    gfn, _ = quad_grad_fn(n, p)
    topo_d, topo_u = directed_ring(n), undirected_ring(n)
    x0 = jnp.zeros((n, p))
    seen = {}

    def spy(tag, want_shape):
        def eval_fn(x, t):
            assert not isinstance(x, tuple), tag
            assert jnp.asarray(x).shape == want_shape, (tag, x.shape)
            assert isinstance(t, float)
            seen[tag] = True
            return {"loss": 0.0, "t": t}
        return eval_fn

    run_push_pull_sync(topo_d, gfn, x0, 0.05, 12, eval_every=6,
                       eval_fn=spy("pps", (n, p)))
    run_sab(topo_d, gfn, x0, 0.05, 12, eval_every=6,
            eval_fn=spy("sab", (n, p)))
    run_dpsgd(topo_u, gfn, x0, 0.05, 12, eval_every=6,
              eval_fn=spy("dpsgd", (n, p)))
    run_ring_allreduce(n, gfn, jnp.zeros(p), 0.05, 12, eval_every=6,
                       eval_fn=spy("ring", (p,)))
    run_adpsgd(topo_u, gfn, x0, 0.05, 40, eval_every=20,
               eval_fn=spy("adpsgd", (n, p)))
    run_osgp(topo_d, gfn, x0, 0.05, 40, eval_every=20,
             eval_fn=spy("osgp", (n, p)))
    assert set(seen) == {"pps", "sab", "dpsgd", "ring", "adpsgd", "osgp"}


def test_shared_scenario_times_consistent_across_algorithms():
    """One scenario instance drives every algorithm; each reports
    strictly increasing virtual times, and under the straggler profile
    async clocks advance past the same horizon the sync barrier pays."""
    n, p = 5, 4
    gfn, _ = quad_grad_fn(n, p)
    sc = NetworkScenario(compute_time=(1, 1, 1, 1, 4.0), latency=0.2)
    x0 = jnp.zeros((n, p))

    def collect():
        box = []
        return box, lambda x, t: (box.append(t), {"loss": 0.0, "t": t})[1]

    ts_sync, f = collect()
    run_dpsgd(undirected_ring(n), gfn, x0, 0.05, 20, scenario=sc,
              eval_every=2, eval_fn=f)
    ts_ad, g = collect()
    run_adpsgd(undirected_ring(n), gfn, x0, 0.05, 200, scenario=sc,
               eval_every=40, eval_fn=g)
    ts_osgp, h = collect()
    run_osgp(directed_ring(n), gfn, x0, 0.05, 200, scenario=sc,
             eval_every=40, eval_fn=h)
    for ts in (ts_sync, ts_ad, ts_osgp):
        assert len(ts) > 2 and np.all(np.diff(ts) > 0)
    # barrier rounds pay the 4x straggler every round: per-round cost > 4;
    # the event clock advances ~n events per straggler period
    assert ts_sync[0] / 2 > 4.0


@pytest.mark.slow
def test_osgp_degrades_with_loss_rfast_does_not():
    """The paper's core robustness claim: push-sum loses mass under packet
    loss; R-FAST's running-sum ρ recovers it."""
    from repro.core import binary_tree, generate_schedule, run_rfast

    n, p, loss = 5, 4, 0.3
    gfn, x_star = quad_grad_fn(n, p, seed=1)

    topo_d = directed_ring(n)
    x_osgp, _ = run_osgp(topo_d, gfn, jnp.zeros((n, p)), gamma=0.03,
                         K=12000, loss_prob=loss, seed=0)
    err_osgp = np.linalg.norm(np.asarray(x_osgp).mean(0) - np.asarray(x_star))

    topo_r = binary_tree(n)
    sched = generate_schedule(topo_r, 12000, loss_prob=loss, latency=0.5)
    state, _ = run_rfast(topo_r, sched, gfn, jnp.zeros((n, p)), gamma=0.03)
    err_rfast = np.linalg.norm(np.asarray(state.x).mean(0) - np.asarray(x_star))

    assert err_rfast < 1e-2, err_rfast
    assert err_osgp > 2 * err_rfast, (err_osgp, err_rfast)
