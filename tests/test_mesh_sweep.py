"""Mesh-mapped fleet sweep: single-device equivalence, argument
validation, shard-aware block padding, and the forced-4-device
subprocess matrix (lane/param mesh factorizations, dispatch pin,
epochized migration equivalence).

The multi-device check runs in a subprocess so the forced host devices
don't leak into this process's jax (tests must see 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scenario import get_scenario
from repro.core.simulator import run_sweep, run_sweep_epochs
from repro.core.topology import get_topology
from repro.kernels.rfast_update.grid import block_pad_width
from repro.kernels.rfast_update.kernel import BLK_R, LANE
from repro.launch.mesh import make_sweep_mesh


def _quad(n, p, seed=0):
    A = jnp.asarray(np.random.default_rng(seed).normal(size=(n, p)),
                    jnp.float32)
    return lambda i, x, key: A[i] * x + 0.01 * jax.random.normal(
        key, x.shape)


def _sweep_setup(n=5, K=20, S=3, p=6):
    topo = get_topology("binary_tree", n)
    sc = get_scenario("uniform", n)
    scheds = [sc.realize(topo, K, seed=s).schedule for s in range(S)]
    return topo, scheds, _quad(n, p), jnp.zeros(p), [3, 5, 8]


def test_block_pad_width_shards():
    per = BLK_R * LANE
    assert block_pad_width(per) == per
    assert block_pad_width(per + 1) == 2 * per
    # sharded: per-device slice still tiles into whole blocks
    for p, m in [(per, 2), (per + 1, 4), (3 * per + 7, 8), (1, 3)]:
        w = block_pad_width(p, m)
        assert w >= p and w % m == 0 and (w // m) % per == 0
    assert block_pad_width(per, 1) == block_pad_width(per)


def test_trivial_mesh_matches_unsharded():
    topo, scheds, gfn, x0, seeds = _sweep_setup()
    ref, _ = run_sweep(topo, scheds, gfn, x0, 0.01, seeds=seeds)
    mesh = make_sweep_mesh()        # (1, 1) on the single CI device
    got, _ = run_sweep(topo, scheds, gfn, x0, 0.01, seeds=seeds,
                       mesh=mesh)
    for a, b in zip(ref, got):
        for f in ("x", "v", "z", "g_prev", "rho", "rho_buf"):
            np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                       rtol=2e-5, atol=2e-5, err_msg=f)


def test_mesh_validation():
    topo, scheds, gfn, x0, seeds = _sweep_setup()
    bad = make_sweep_mesh(lane_axis="rows", param_axis="cols")
    with pytest.raises(ValueError, match="lane axis"):
        run_sweep(topo, scheds, gfn, x0, 0.01, seeds=seeds, mesh=bad)
    with pytest.raises(ValueError, match="devices"):
        make_sweep_mesh(lanes=len(jax.devices()) + 1)
    with pytest.raises(ValueError, match="param_shards"):
        make_sweep_mesh(param_shards=0)


def test_sweep_epochs_rejects_lane_parallel_mesh():
    topo = get_topology("robust_tree", 6)
    traces = [get_scenario("churn", 6).realize_epochs(topo, 40, seed=0)]
    mesh = make_sweep_mesh(lanes=1)
    # size-1 lane axis is the only legal layout here; fabricate a >1
    # lane axis only when the host exposes enough devices
    if len(jax.devices()) > 1:
        with pytest.raises(ValueError, match="parameter axis only"):
            run_sweep_epochs(traces, _quad(6, 4), jnp.zeros(4), 0.01,
                             mesh=make_sweep_mesh(lanes=2))
    got, _ = run_sweep_epochs(traces, _quad(6, 4), jnp.zeros(4), 0.01,
                              mesh=mesh)
    ref, _ = run_sweep_epochs(traces, _quad(6, 4), jnp.zeros(4), 0.01)
    np.testing.assert_allclose(ref[0].x, got[0].x, rtol=2e-5, atol=2e-5)


def test_mesh_sweep_equivalence_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.abspath("src")
    r = subprocess.run(
        [sys.executable,
         os.path.join("tests", "helpers", "mesh_sweep_equiv.py")],
        env=env, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    for marker in ("OK mesh-vs-unsharded (4,1)",
                   "OK mesh-vs-unsharded (2,2)",
                   "OK mesh-vs-unsharded (1,4)",
                   "OK dispatch single-signature pin",
                   "OK epochs mesh-vs-unsharded (1,4)"):
        assert marker in r.stdout, r.stdout[-2000:]
