"""Subprocess helper: exercise the dry-run spec machinery end-to-end on a
(4, 4) host-platform mesh with reduced configs (fast CI proxy for the
512-device production dry-run)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.specs import build_case  # noqa: E402


def main():
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    for arch in ("llama3-8b", "deepseek-v2-236b", "falcon-mamba-7b",
                 "whisper-large-v3"):
        cfg = get_config(arch).reduced()
        for shape in ("train_4k", "decode_32k"):
            from repro.launch.specs import SHAPES
            info = dict(SHAPES[shape])
            # shrink shapes for CI: seq 256/1k, batch 16
            seq = 256 if shape == "train_4k" else 1024
            fn, args = build_case(
                cfg, mesh, shape, **{})
            # rebuild at reduced scale through the kind-specific builders
            from repro.launch import specs as S
            if info["kind"] == "train":
                fn, args = S.build_train(cfg, mesh, seq=seq, global_batch=16)
            else:
                fn, args = S.build_decode(cfg, mesh, seq=seq,
                                          global_batch=16,
                                          long=info.get("long", False))
            compiled = jax.jit(fn).lower(*args).compile()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict/device
                ca = ca[0]
            assert ma.argument_size_in_bytes > 0
            assert ca.get("flops", 0) > 0
            print(f"OK {arch} {shape} args="
                  f"{ma.argument_size_in_bytes/2**20:.1f}MiB "
                  f"flops={ca['flops']:.3g}", flush=True)
    print("DRYRUN-SMALL-PASS")


if __name__ == "__main__":
    main()
