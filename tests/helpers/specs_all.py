"""Subprocess helper: build input specs for ALL 10 archs × 4 shapes on a
(4,4) mesh and validate every sharding divides its dims (no compile —
fast regression net for the spec machinery)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import jax  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.launch.specs import SHAPES, build_case, shape_supported  # noqa: E402


def check_tree(tree, where):
    def chk(leaf):
        sh = getattr(leaf, "sharding", None)
        if sh is None:
            return
        spec = sh.spec
        mesh = sh.mesh
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (where, leaf.shape, spec)
    jax.tree.map(chk, tree)


def main():
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    n_ok = n_skip = 0
    for arch in ARCHS[:10]:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = shape_supported(cfg, shape)
            if not ok:
                n_skip += 1
                continue
            fn, args = build_case(cfg, mesh, shape)
            check_tree(args, (arch, shape))
            n_ok += 1
    print(f"SPECS-ALL-PASS ok={n_ok} skip={n_skip}")


if __name__ == "__main__":
    main()
