"""Subprocess helper: run dense vs ppermute R-FAST runtimes on an 8-device
host-platform mesh and assert bit-level agreement + convergence.
Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import binary_tree  # noqa: E402
from repro.core.runtime import (edge_arrays, init_node_state,  # noqa: E402
                                make_rfast_round)
from repro.core.runtime_sharded import (init_sharded_state,  # noqa: E402
                                        make_sharded_round)


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n, p = 4, 16
    topo = binary_tree(n)
    rng = np.random.default_rng(0)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)
    S = jnp.asarray(rng.uniform(0.5, 2.0, (n, 1)), jnp.float32)

    def grad_fn(params, batch, key):
        c, s = batch
        g = {"w": s * (params["w"] - c)}
        return 0.5 * jnp.sum(s * (params["w"] - c) ** 2), g

    batches = (C, S)
    params = {"w": jnp.zeros((p,), jnp.float32)}
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    gamma = 0.06

    # dense reference (single-device semantics)
    spec = edge_arrays(topo)
    st_d = init_node_state(spec, params, grad_fn, batches,
                           jax.random.PRNGKey(0))
    dense = jax.jit(make_rfast_round(spec, grad_fn, gamma=gamma))

    # sharded ppermute runtime on the mesh
    st_s = init_sharded_state(topo, params, grad_fn, batches, keys)
    put = lambda tree: jax.tree.map(
        lambda l: jax.device_put(l, NamedSharding(
            mesh, P("data", *([None] * (l.ndim - 1))))), tree)
    st_s = st_s._replace(
        x=put(st_s.x), z=put(st_s.z), g_prev=put(st_s.g_prev),
        rho_out=put(st_s.rho_out), rho_buf=put(st_s.rho_buf))
    batches_d = put(batches)
    sharded = jax.jit(make_sharded_round(topo, grad_fn, mesh, gamma=gamma,
                                         node_axes=("data",)))

    for t in range(200):
        # Block between the single-device and 8-device programs: on a CPU
        # host with fewer cores than devices, interleaving them starves
        # the collective rendezvous (all device threads must join).
        st_d, md = dense(st_d, batches, keys, None)
        jax.block_until_ready(st_d.x["w"])
        st_s, ms = sharded(st_s, batches_d, keys, None)
        jax.block_until_ready(st_s.x["w"])

    xd = np.asarray(st_d.x["w"])
    xs = np.asarray(st_s.x["w"])
    err = np.abs(xd - xs).max()
    assert err < 1e-4, f"dense vs sharded mismatch: {err}"

    x_star = np.asarray((S * C).sum(0) / S.sum(0))
    conv = np.abs(xs - x_star[None]).max()
    assert conv < 1e-2, f"sharded runtime did not converge: {conv}"
    # total tracked-mass invariant on the sharded layout
    mass = (np.asarray(st_s.z["w"]).sum(0)
            + (np.asarray(st_s.rho_out["w"])
               - np.asarray(st_s.rho_buf["w"])).sum((0, 1)))
    gsum = np.asarray(st_s.g_prev["w"]).sum(0)
    np.testing.assert_allclose(mass, gsum, rtol=1e-4, atol=1e-4)
    print(f"OK dense-vs-sharded err={err:.2e} conv={conv:.2e}")


def robust_mode():
    """Robust (masked) sharded runtime: mass conservation under loss."""
    import numpy as np
    from repro.core import binary_tree
    from repro.core.runtime_sharded import (init_sharded_state,
                                            make_sharded_round, _slot_tables)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n, p = 4, 8
    topo = binary_tree(n)
    slots_w, slots_a, *_ = _slot_tables(topo)
    S = len(slots_w) + len(slots_a)
    rng = np.random.default_rng(1)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)

    def gf(params, batch, key):
        c = batch
        return 0.5 * jnp.sum((params["w"] - c) ** 2), \
            {"w": params["w"] - c}

    params = {"w": jnp.zeros((p,), jnp.float32)}
    keys = jax.random.split(jax.random.PRNGKey(1), n)
    st = init_sharded_state(topo, params, gf, C, keys, robust=True)
    put = lambda t: jax.tree.map(lambda l: jax.device_put(
        l, NamedSharding(mesh, P("data", *([None] * (l.ndim - 1))))), t)
    st = st._replace(x=put(st.x), z=put(st.z), g_prev=put(st.g_prev),
                     rho_out=put(st.rho_out), rho_buf=put(st.rho_buf),
                     mail_v=put(st.mail_v))
    rf = jax.jit(make_sharded_round(topo, gf, mesh, gamma=0.05,
                                    node_axes=("data",), robust=True))
    for t in range(300):
        masks = jnp.asarray(
            (rng.uniform(size=(n, S)) > 0.3), jnp.float32)
        st, _ = rf(st, put(C), keys, masks)
        jax.block_until_ready(st.x["w"])
    # Lemma 3 on the slotted layout
    mass = (np.asarray(st.z["w"]).sum(0)
            + (np.asarray(st.rho_out["w"])
               - np.asarray(st.rho_buf["w"])).sum((0, 1)))
    gsum = np.asarray(st.g_prev["w"]).sum(0)
    np.testing.assert_allclose(mass, gsum, rtol=1e-4, atol=1e-4)
    # converges to x* despite 30% loss
    x_star = np.asarray(C.mean(0))
    err = np.abs(np.asarray(st.x["w"]) - x_star[None]).max()
    assert err < 5e-2, err
    print(f"OK robust sharded runtime: loss-mass conserved, conv={err:.2e}")


if __name__ == "__main__":
    main()
    robust_mode()
