"""Subprocess helper: mesh-mapped ``run_sweep`` vs the unsharded fleet
engine on a forced 4-device host mesh.

Checks, on a randomized (topology x scenario x seed) lane matrix:

* per-lane final-state equivalence at fp32 tolerance for every mesh
  factorization of 4 devices — lane-parallel (4,1), mixed (2,2) and
  param-sharded (1,4) — including lane padding (S=5 -> 8 groups of 2);
* the pallas dispatch pin: a heterogeneous mesh-mapped fleet resolves
  ONE commit-grid launch signature (the local shard shape), and a re-run
  with fresh seeds rides the cache with zero new entries;
* ``run_sweep_epochs`` with a param-sharded (1,4) mesh matches its
  unsharded result across membership-epoch migrations.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=4.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.scenario import get_scenario  # noqa: E402
from repro.core.simulator import run_sweep, run_sweep_epochs  # noqa: E402
from repro.core.topology import get_topology  # noqa: E402
from repro.launch.mesh import make_sweep_mesh  # noqa: E402

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from recompiles import assert_no_recompiles  # noqa: E402

FIELDS = ("x", "v", "z", "g_prev", "rho", "rho_buf", "v_hist", "rho_hist")


def assert_lanes_close(ref, got, what):
    for s, (a, b) in enumerate(zip(ref, got)):
        for f in FIELDS:
            np.testing.assert_allclose(
                getattr(a, f), getattr(b, f), rtol=2e-5, atol=2e-5,
                err_msg=f"{what}: lane {s} field {f}")


def quad_grad(n, p, seed):
    A = jnp.asarray(np.random.default_rng(seed).normal(size=(n, p)),
                    jnp.float32)

    def gfn(i, x, key):
        return A[i] * x + 0.01 * jax.random.normal(key, x.shape)

    return gfn


def main():
    assert len(jax.devices()) == 4, jax.devices()
    n, K, S, p = 5, 24, 5, 7          # S=5 pads to 8 lanes; p % 4 != 0
    rng = np.random.default_rng(20260809)
    topo_names = ["binary_tree", "line", "robust_tree"]
    sc_names = ["uniform", "packet_loss", "churn"]
    topos = [get_topology(topo_names[rng.integers(len(topo_names))], n)
             for _ in range(S)]
    scheds = [get_scenario(sc_names[rng.integers(len(sc_names))], n)
              .realize(t, K, seed=int(rng.integers(1 << 16))).schedule
              for t in topos]
    seeds = [int(rng.integers(1 << 16)) for _ in range(S)]
    gfn = quad_grad(n, p, 0)
    x0 = jnp.zeros(p)
    kw = dict(seeds=seeds, eval_every=K // 2)

    ref, _ = run_sweep(topos, scheds, gfn, x0, 0.01, **kw)
    jax.block_until_ready([s.x for s in ref])
    for d, m in [(4, 1), (2, 2), (1, 4)]:
        mesh = make_sweep_mesh(lanes=d, param_shards=m)
        got, _ = run_sweep(topos, scheds, gfn, x0, 0.01, mesh=mesh, **kw)
        # block between programs: interleaving a 4-device program with
        # the next compile starves the collective rendezvous on CPU
        jax.block_until_ready([s.x for s in got])
        assert_lanes_close(ref, got, f"mesh ({d},{m})")
        print(f"OK mesh-vs-unsharded ({d},{m})")

    # dispatch pin: ONE launch signature for the heterogeneous mesh
    # fleet (the local shard shape), cache-riding re-run with new seeds
    mesh = make_sweep_mesh(lanes=2, param_shards=2)
    with assert_no_recompiles(expect_entries=1) as rec:
        got, _ = run_sweep(topos, scheds, gfn, x0, 0.01, mesh=mesh,
                           impl="pallas", **kw)
        jax.block_until_ready([s.x for s in got])
    assert rec.misses == 1, rec
    assert_lanes_close(ref, got, "pallas mesh (2,2)")
    kw2 = dict(kw, seeds=[s + 1 for s in seeds])
    with assert_no_recompiles(expect_entries=0, fresh=False) as rec2:
        got2, _ = run_sweep(topos, scheds, gfn, x0, 0.01, mesh=mesh,
                            impl="pallas", **kw2)
        jax.block_until_ready([s.x for s in got2])
    assert rec2.misses == 0 and rec2.hits > 0, rec2
    print("OK dispatch single-signature pin")

    # epochized lanes: param-sharded mesh across membership migrations
    topo = get_topology("robust_tree", 6)
    sc = get_scenario("churn", 6)
    traces = [sc.realize_epochs(topo, 60, seed=s) for s in range(2)]
    egfn = quad_grad(6, p, 1)
    eref, _ = run_sweep_epochs(traces, egfn, jnp.zeros(p), 0.01,
                               seeds=[7, 9])
    jax.block_until_ready([s.x for s in eref])
    egot, _ = run_sweep_epochs(traces, egfn, jnp.zeros(p), 0.01,
                               seeds=[7, 9],
                               mesh=make_sweep_mesh(lanes=1,
                                                    param_shards=4))
    jax.block_until_ready([s.x for s in egot])
    assert_lanes_close(eref, egot, "epochs mesh (1,4)")
    print("OK epochs mesh-vs-unsharded (1,4)")


if __name__ == "__main__":
    main()
