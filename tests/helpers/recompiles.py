"""``assert_no_recompiles`` — a context manager that turns the
dispatch-cache counters (and, where available, JAX's own compile-event
hooks) into a hard assertion.

The commit-grid dispatch cache (:mod:`repro.kernels.rfast_update.dispatch`)
is the repo's recompile telltale: every distinct launch signature costs
one ``miss``/``entry``, and a steady-state engine loop must ride cached
entries (``hits``) only.  Tests used to read ``dispatch.stats()`` by
hand; this helper centralizes the delta bookkeeping so the assertion
reads as intent::

    with assert_no_recompiles(expect_entries=1) as rec:
        run_sweep(...)
    assert rec.misses == 1

    with assert_no_recompiles(expect_entries=0, fresh=False) as rec2:
        run_sweep(...)          # same shapes: cache must absorb it
    assert rec2.hits > 0

When JAX exposes its monitoring hooks (``jax._src.monitoring``), the
manager also counts backend-compile events fired inside the block and
exposes them as ``rec.jax_compiles`` — informational by default, or a
hard bound via ``max_jax_compiles=``.
"""
from __future__ import annotations

import contextlib
import dataclasses

from repro.kernels.rfast_update import dispatch

__all__ = ["RecompileRecord", "assert_no_recompiles"]


@dataclasses.dataclass
class RecompileRecord:
    """Counter deltas observed across an ``assert_no_recompiles`` block."""

    entries: int = 0       # new dispatch-cache entries (launch signatures)
    misses: int = 0        # dispatch lookups that had to build a launch
    hits: int = 0          # dispatch lookups served from the cache
    jax_compiles: int = 0  # backend-compile events (when hooks available)
    jax_hooked: bool = False


def _jax_compile_listener(record: RecompileRecord):
    """Best-effort JAX compile-event hook; returns ``(listener, remove)``
    or ``(None, None)`` when this JAX build has no monitoring API."""
    try:
        from jax._src import monitoring
        register = monitoring.register_event_duration_secs_listener
        unregister = monitoring._unregister_event_duration_listener_by_callback
    except (ImportError, AttributeError):
        return None, None

    def listener(event: str, duration: float, **kwargs) -> None:
        if "compile" in event:
            record.jax_compiles += 1

    def remove() -> None:
        unregister(listener)

    register(listener)
    return listener, remove


@contextlib.contextmanager
def assert_no_recompiles(expect_entries: int = 1, *, fresh: bool = True,
                         max_jax_compiles: int | None = None, cache=None):
    """Assert the block adds exactly ``expect_entries`` cache entries
    (RF205's runtime counterpart).

    ``cache`` selects WHICH instrumented cache is audited: any module or
    object with the ``stats()``/``clear()`` contract — the commit-grid
    dispatch cache by default, ``repro.serve.cache`` for the serving
    executables.  ``fresh=True`` clears it first, so ``expect_entries``
    counts signatures built by the block itself; ``fresh=False``
    measures against the warm cache — ``expect_entries=0`` then asserts
    the block rode existing executables only.  ``max_jax_compiles``
    optionally bounds backend-compile events too (skipped silently when
    the running JAX exposes no monitoring hooks).

    Yields a :class:`RecompileRecord`; its fields hold the observed
    deltas after the block exits, so tests can make finer assertions
    (``rec.misses``, ``rec.hits``) on top of the entry check.
    """
    cache = dispatch if cache is None else cache
    if fresh:
        cache.clear()
    base = cache.stats()
    rec = RecompileRecord()
    listener, remove = _jax_compile_listener(rec)
    rec.jax_hooked = listener is not None
    try:
        yield rec
    finally:
        if remove is not None:
            remove()
    after = cache.stats()
    rec.entries = after["entries"] - base["entries"]
    rec.misses = after["misses"] - base["misses"]
    rec.hits = after["hits"] - base["hits"]
    assert rec.entries == expect_entries, (
        f"cache grew by {rec.entries} signature(s), "
        f"expected {expect_entries}: {base} -> {after}")
    if max_jax_compiles is not None and rec.jax_hooked:
        assert rec.jax_compiles <= max_jax_compiles, (
            f"{rec.jax_compiles} backend-compile events, "
            f"allowed {max_jax_compiles}")
