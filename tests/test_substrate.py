"""Optimizers, schedules, checkpointing, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.data.pipeline import LMShardConfig, node_batch
from repro.optim import adamw, constant, cosine, momentum, sgd, step_decay, warmup_cosine
from tests.test_simulator import quad_grad_fn


def _params():
    return {"w": jnp.ones((3, 4)), "b": jnp.zeros(4),
            "nested": {"s": jnp.full((2,), 2.0)}}


@pytest.mark.parametrize("opt_fn", [
    lambda: sgd(0.1), lambda: momentum(0.1, 0.9), lambda: adamw(0.05)])
@pytest.mark.slow
def test_optimizers_descend_quadratic(opt_fn):
    opt = opt_fn()
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for step in range(800):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(step))
    assert float(loss(params)) < 1e-3


def test_schedules():
    s = jnp.asarray(0)
    assert float(constant(0.1)(s)) == pytest.approx(0.1)
    assert float(step_decay(0.1, 0.1, 30)(jnp.asarray(31))) == pytest.approx(0.01)
    assert float(cosine(1.0, 100)(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(warmup_cosine(1.0, 10, 100)(jnp.asarray(5))) == pytest.approx(0.5)


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _params()
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(d) == 12
    back = load_checkpoint(d, tree)
    np.testing.assert_allclose(back["w"], np.asarray(tree["w"]) + 1)
    back7 = load_checkpoint(d, tree, step=7)
    np.testing.assert_allclose(back7["nested"]["s"], [2.0, 2.0])


def test_checkpoint_structure_mismatch(tmp_path):
    d = str(tmp_path / "c2")
    save_checkpoint(d, 1, _params())
    with pytest.raises(ValueError):
        load_checkpoint(d, {"other": jnp.zeros(1)})


def test_checkpoint_roundtrip_protocol_state(tmp_path):
    """ProtocolState (the sync runtime's pytree) survives save/load
    bit-identically, step included."""
    from repro.core import directed_ring
    from repro.core.plan import build_comm_plan
    from repro.core.runtime import init_node_state, make_rfast_round
    n, p = 4, 6
    plan = build_comm_plan(directed_ring(n))
    C = jnp.asarray(np.random.default_rng(0).normal(0, 1, (n, p)),
                    jnp.float32)

    def grad_fn(params, batch, key):
        del key
        d = params["w"] - batch
        return 0.5 * jnp.sum(d * d), {"w": d}

    key = jax.random.PRNGKey(0)
    state = init_node_state(plan, {"w": jnp.zeros((p,), jnp.float32)},
                            grad_fn, C, key, robust=True)
    rf = jax.jit(make_rfast_round(plan, grad_fn, gamma=0.05, robust=True))
    for _ in range(3):
        state, _ = rf(state, C, jax.random.split(key, n), None)

    d = str(tmp_path / "proto")
    save_checkpoint(d, int(state.step), state)
    assert latest_step(d) == 3
    back = load_checkpoint(d, state)
    for name, a, b in zip(state._fields, state, back):
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=name)
    assert int(back.step) == 3


def test_checkpoint_roundtrip_flat_substrate_resumes(tmp_path):
    """RFASTState (the packed flat-substrate state) round-trips through
    ckpt.py bit-identically AND a resumed run continues the exact
    trajectory from the saved event."""
    from repro.core import binary_tree, generate_schedule, run_rfast
    from repro.core.simulator import RFASTState
    n, p, K, half = 5, 6, 240, 120
    topo = binary_tree(n)
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    sched = generate_schedule(topo, K, loss_prob=0.1, latency=0.5, seed=1)
    x0 = jnp.zeros((n, p), jnp.float32)
    d = str(tmp_path / "flat")

    def cb(state, k):
        if k == half:
            save_checkpoint(d, k, state)

    full, _ = run_rfast(topo, sched, gfn, x0, 0.02, mode="wavefront",
                        eval_every=half, chunk_cb=cb)
    assert latest_step(d) == half

    # bit-identical round-trip (template only supplies the structure —
    # the same zeros_state recipe launch/train.py uses to resume)
    from repro.core.simulator import zeros_state
    template = zeros_state(topo, p, int(sched.D) + 2)
    mid = load_checkpoint(d, template)
    assert int(mid.k) == half
    save_checkpoint(d, half, mid)          # idempotent re-save
    again = load_checkpoint(d, template, step=half)
    for name, a, b in zip(RFASTState._fields, mid, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)

    # resume at the right step: identical final state vs the full run
    resumed, _ = run_rfast(topo, sched, gfn, x0, 0.02, mode="wavefront",
                           eval_every=half,
                           state0=jax.tree.map(jnp.asarray, mid))
    for name, a, b in zip(RFASTState._fields, resumed, full):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6, err_msg=name)
    # resuming off a chunk boundary is refused
    bad = jax.tree.map(jnp.asarray, mid)._replace(
        k=jnp.asarray(half - 1, jnp.int32))
    with pytest.raises(ValueError):
        run_rfast(topo, sched, gfn, x0, 0.02, mode="wavefront",
                  eval_every=half, state0=bad)
    # a COMPLETED run resumes as a no-op even when K is not a multiple
    # of eval_every (the final chunk is short)
    done = jax.tree.map(jnp.asarray, full)
    assert K % 100 != 0
    out, ms = run_rfast(topo, sched, gfn, x0, 0.02, mode="wavefront",
                        eval_every=100, state0=done)
    assert int(out.k) == K and ms == []


def test_node_batches_disjoint_and_deterministic():
    cfg = LMShardConfig(vocab=100, batch_per_node=2, seq_len=8, n_nodes=4)
    t0a, l0a = node_batch(cfg, 0, 0)
    t0b, _ = node_batch(cfg, 0, 0)
    t1, _ = node_batch(cfg, 1, 0)
    np.testing.assert_array_equal(t0a, t0b)
    assert not np.array_equal(t0a, t1)
    assert t0a.shape == (2, 8)
    np.testing.assert_array_equal(l0a[:, :-1], t0a[:, 1:])


def test_metrics_logger_roundtrip(tmp_path):
    from repro.metrics import MetricsLogger, StepTimer, read_metrics
    path = str(tmp_path / "m.jsonl")
    with MetricsLogger(path, flush_every=2) as lg:
        lg.log(1, loss=2.5)
        lg.log(2, loss=2.0, note="x")
    recs = list(read_metrics(path))
    assert [r["step"] for r in recs] == [1, 2]
    assert recs[0]["loss"] == 2.5
    assert recs[1]["note"] == "x"
    t = StepTimer()
    for _ in range(3):
        t.tick()
    assert t.steps_per_sec >= 0
