"""Optimizers, schedules, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.data.pipeline import LMShardConfig, node_batch
from repro.optim import adamw, constant, cosine, momentum, sgd, step_decay, warmup_cosine


def _params():
    return {"w": jnp.ones((3, 4)), "b": jnp.zeros(4),
            "nested": {"s": jnp.full((2,), 2.0)}}


@pytest.mark.parametrize("opt_fn", [
    lambda: sgd(0.1), lambda: momentum(0.1, 0.9), lambda: adamw(0.05)])
@pytest.mark.slow
def test_optimizers_descend_quadratic(opt_fn):
    opt = opt_fn()
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for step in range(800):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(step))
    assert float(loss(params)) < 1e-3


def test_schedules():
    s = jnp.asarray(0)
    assert float(constant(0.1)(s)) == pytest.approx(0.1)
    assert float(step_decay(0.1, 0.1, 30)(jnp.asarray(31))) == pytest.approx(0.01)
    assert float(cosine(1.0, 100)(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(warmup_cosine(1.0, 10, 100)(jnp.asarray(5))) == pytest.approx(0.5)


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _params()
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(d) == 12
    back = load_checkpoint(d, tree)
    np.testing.assert_allclose(back["w"], np.asarray(tree["w"]) + 1)
    back7 = load_checkpoint(d, tree, step=7)
    np.testing.assert_allclose(back7["nested"]["s"], [2.0, 2.0])


def test_checkpoint_structure_mismatch(tmp_path):
    d = str(tmp_path / "c2")
    save_checkpoint(d, 1, _params())
    with pytest.raises(ValueError):
        load_checkpoint(d, {"other": jnp.zeros(1)})


def test_node_batches_disjoint_and_deterministic():
    cfg = LMShardConfig(vocab=100, batch_per_node=2, seq_len=8, n_nodes=4)
    t0a, l0a = node_batch(cfg, 0, 0)
    t0b, _ = node_batch(cfg, 0, 0)
    t1, _ = node_batch(cfg, 1, 0)
    np.testing.assert_array_equal(t0a, t0b)
    assert not np.array_equal(t0a, t1)
    assert t0a.shape == (2, 8)
    np.testing.assert_array_equal(l0a[:, :-1], t0a[:, 1:])


def test_metrics_logger_roundtrip(tmp_path):
    from repro.metrics import MetricsLogger, StepTimer, read_metrics
    path = str(tmp_path / "m.jsonl")
    with MetricsLogger(path, flush_every=2) as lg:
        lg.log(1, loss=2.5)
        lg.log(2, loss=2.0, note="x")
    recs = list(read_metrics(path))
    assert [r["step"] for r in recs] == [1, 2]
    assert recs[0]["loss"] == 2.5
    assert recs[1]["note"] == "x"
    t = StepTimer()
    for _ in range(3):
        t.tick()
    assert t.steps_per_sec >= 0
