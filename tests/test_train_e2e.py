"""End-to-end launch/train.py runs, in-process: the synchronous SPMD
round engine and the asynchronous --scenario wavefront engine both
train the reduced LM (loss decreases from step 0), and async
checkpoints resume."""
import jax
import pytest

from repro.launch.train import main

jax.config.update("jax_enable_x64", False)

COMMON = ["--reduced", "--nodes", "2", "--steps", "10", "--seq", "32",
          "--batch-per-node", "2", "--gamma", "0.02", "--log-every", "2"]


@pytest.mark.slow
def test_train_sync_loss_decreases():
    out = main(COMMON)
    assert out["mode"] == "sync"
    assert len(out["losses"]) >= 2
    assert out["losses"][-1] < out["losses"][0], out["losses"]


@pytest.mark.slow
def test_train_async_scenario_loss_decreases_and_resumes(tmp_path):
    ck = str(tmp_path / "ck")
    args = COMMON + ["--scenario", "straggler", "--ckpt", ck]
    out = main(args)
    assert out["mode"] == "async" and out["scenario"] == "straggler"
    assert out["events"] == 20
    # losses[0] is the step-0 (init) eval loss
    assert out["losses"][-1] < out["losses"][0], out["losses"]
    assert 0.0 < out["send_ok"] <= 1.0

    # the final checkpoint resumes at the right event: nothing to redo
    out2 = main(args)
    assert out2["losses"] == out2["losses"][:1]

    # --loss-prob belongs to the sync regime
    with pytest.raises(SystemExit):
        main(args + ["--loss-prob", "0.1"])
