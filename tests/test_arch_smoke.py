"""Per-architecture smoke tests: REDUCED variant of each assigned family
(2 layers, d_model<=256, <=4 experts) runs one forward + one train step +
a few decode steps on CPU; asserts shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)

pytestmark = pytest.mark.slow   # model-zoo e2e smoke: full tier only

B, S = 2, 16


def _inputs(cfg, key):
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (B, S), 0, cfg.vocab)
    front = None
    if cfg.frontend:
        front = jax.random.normal(
            k2, (B, cfg.frontend_seq, cfg.frontend_dim or cfg.d_model),
            jnp.float32)
    return toks, front


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks, front = _inputs(cfg, key)
    logits, aux = forward(cfg, params, toks, front)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    toks, front = _inputs(cfg, key)
    labels = jnp.roll(toks, -1, axis=1)

    def loss(p):
        return loss_fn(cfg, p, toks, labels, front)

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    # gradient flows to every parameter leaf
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    nonzero = sum(float(jnp.abs(g).sum()) > 0 for g in flat)
    assert nonzero >= 0.8 * len(flat), f"{nonzero}/{len(flat)} leaves with grad"
    # one SGD step reduces loss locally
    p2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    l1 = float(loss(p2))
    assert l1 < float(l0) + 1e-3


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    _, front = _inputs(cfg, key)
    cache = init_cache(cfg, params, B, max_len=32, frontend=front)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, cache = decode_step(cfg, params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        assert int(cache["idx"]) == i + 1


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen2.5-3b", "olmo-1b",
                                  "deepseek-7b", "deepseek-v2-236b",
                                  "falcon-mamba-7b"])
def test_decode_matches_forward(arch):
    """Prefilled decode logits == full-sequence forward logits (the KV
    cache implements the same function)."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    full, _ = forward(cfg, params, toks)
    cache = init_cache(cfg, params, B, max_len=16)
    _, dec = prefill(cfg, params, cache, toks)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_past():
    """With window=4, logits at position t don't depend on tokens < t-4."""
    import dataclasses
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              attn_window=4)
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    t1 = jax.random.randint(key, (1, 12), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)  # perturb distant past
    l1, _ = forward(cfg, params, t1)
    l2, _ = forward(cfg, params, t2)
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-5, atol=1e-5)


def test_param_count_analytic_close_to_actual():
    for arch in ["llama3-8b", "falcon-mamba-7b", "deepseek-v2-236b"]:
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert abs(actual - approx) / actual < 0.15, (arch, actual, approx)


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b",
                                  "falcon-mamba-7b", "hymba-1.5b",
                                  "whisper-large-v3"])
def test_batched_prefill_matches_tokenwise(arch):
    """prefill_cache (one forward) == token-by-token prefill, and decode
    continues identically from both caches."""
    from repro.models.transformer import prefill_cache
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key)
    toks, front = _inputs(cfg, key)
    toks = toks[:, :8]
    c0 = init_cache(cfg, params, B, max_len=16, frontend=front)
    c_ref, logits_ref = prefill(cfg, params, c0, toks)
    c_new, last = prefill_cache(cfg, params, toks, max_len=16,
                                frontend=front)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_ref[:, -1]),
                               rtol=2e-3, atol=2e-3)
    tok = jnp.zeros((B, 1), jnp.int32)
    l1, _ = decode_step(cfg, params, c_ref, tok)
    l2, _ = decode_step(cfg, params, c_new, tok)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)


def test_batched_prefill_vlm_includes_image_prefix():
    """pixtral: prefill_cache prepends the patch embeddings (token-wise
    prefill cannot); verify against full forward on image+text, and that
    decode continues consistently with forward on one more token."""
    from repro.models.transformer import prefill_cache
    cfg = get_config("pixtral-12b").reduced()
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    toks, front = _inputs(cfg, key)
    toks = toks[:, :8]
    full, _ = forward(cfg, params, toks, front)      # logits for text pos
    max_len = cfg.frontend_seq + 12
    cache, last = prefill_cache(cfg, params, toks, max_len=max_len,
                                frontend=front)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    nxt = jnp.full((B, 1), 3, jnp.int32)
    l_dec, _ = decode_step(cfg, params, cache, nxt)
    full2, _ = forward(cfg, params, jnp.concatenate([toks, nxt], 1), front)
    np.testing.assert_allclose(np.asarray(l_dec[:, 0]),
                               np.asarray(full2[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_batched_prefill_ring_buffer_window():
    """Sliding-window arch: prefill longer than the cache capacity fills
    the ring correctly (only the last `window` positions attended)."""
    import dataclasses
    from repro.models.transformer import prefill_cache
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              attn_window=4)
    key = jax.random.PRNGKey(6)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab)
    c0 = init_cache(cfg, params, 1, max_len=12)
    c_ref, logits_ref = prefill(cfg, params, c0, toks)
    c_new, last = prefill_cache(cfg, params, toks, max_len=12)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_ref[:, -1]),
                               rtol=2e-3, atol=2e-3)
    tok = jnp.zeros((1, 1), jnp.int32)
    l1, _ = decode_step(cfg, params, c_ref, tok)
    l2, _ = decode_step(cfg, params, c_new, tok)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)
