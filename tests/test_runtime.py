"""Production runtime (single-device semantics): convergence, invariants,
path agreement.  Multi-device execution is covered by test_dryrun_subproc.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binary_tree, directed_ring
from repro.core.runtime import (edge_arrays, init_node_state,
                                make_rfast_round, runtime_tracked_mass)


def quad_setup(n, p, seed=0):
    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)
    S = jnp.asarray(rng.uniform(0.5, 2.0, (n, 1)), jnp.float32)

    def make_grad(i_arr, s_arr):
        def grad_fn(params, batch, key):
            # batch carries the node's own (c, s)
            c, s = batch
            g = {"w": s * (params["w"] - c)}
            loss = 0.5 * jnp.sum(s * (params["w"] - c) ** 2)
            return loss, g
        return grad_fn

    x_star = (S * C).sum(0) / S.sum(0)
    batches = (C, S)            # leading N axis
    return make_grad(C, S), batches, x_star


def _run(topo, rounds, gamma, robust=False, masks_fn=None, momentum=0.0,
         n=None, p=6, seed=0):
    n = n or topo.n
    spec = edge_arrays(topo)
    grad_fn, batches, x_star = quad_setup(n, p, seed)
    params = {"w": jnp.zeros((p,), jnp.float32)}
    key = jax.random.PRNGKey(0)
    state = init_node_state(spec, params, grad_fn, batches, key,
                            robust=robust, momentum=momentum)
    round_fn = jax.jit(make_rfast_round(
        spec, grad_fn, gamma=gamma, robust=robust, momentum=momentum))
    rng = np.random.default_rng(seed + 1)
    keys = jax.random.split(key, rounds)
    for t in range(rounds):
        masks = None
        if masks_fn is not None:
            masks = jnp.asarray(masks_fn(rng, spec.e_pad), jnp.float32)
        state, metrics = round_fn(state, batches,
                                  jax.random.split(keys[t], n), masks)
    return state, x_star


@pytest.mark.parametrize("builder", [binary_tree, directed_ring])
def test_runtime_sync_converges_exactly(builder):
    topo = builder(5)
    state, x_star = _run(topo, rounds=700, gamma=0.08)
    err = np.abs(np.asarray(state.x["w"]) - np.asarray(x_star)[None]).max()
    assert err < 1e-4, err


def test_runtime_momentum_converges():
    topo = binary_tree(5)
    state, x_star = _run(topo, rounds=800, gamma=0.05, momentum=0.5)
    err = np.abs(np.asarray(state.x["w"]) - np.asarray(x_star)[None]).max()
    assert err < 1e-3, err


def test_runtime_robust_path_matches_sync_when_all_delivered():
    topo = directed_ring(5)
    s1, _ = _run(topo, rounds=50, gamma=0.05, robust=False)
    s2, _ = _run(topo, rounds=50, gamma=0.05, robust=True,
                 masks_fn=lambda rng, e: np.ones(e))
    np.testing.assert_allclose(np.asarray(s1.x["w"]), np.asarray(s2.x["w"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_runtime_converges_under_packet_loss():
    topo = binary_tree(5)
    state, x_star = _run(
        topo, rounds=2500, gamma=0.05, robust=True,
        masks_fn=lambda rng, e: (rng.uniform(size=e) > 0.3).astype(float))
    err = np.abs(np.asarray(state.x["w"]) - np.asarray(x_star)[None]).max()
    assert err < 1e-3, err


def test_runtime_mass_conservation_under_loss():
    topo = binary_tree(7)
    spec = edge_arrays(topo)
    grad_fn, batches, _ = quad_setup(7, 4)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    key = jax.random.PRNGKey(1)
    state = init_node_state(spec, params, grad_fn, batches, key, robust=True)
    round_fn = jax.jit(make_rfast_round(spec, grad_fn, gamma=0.02,
                                        robust=True))
    rng = np.random.default_rng(3)
    for t in range(60):
        masks = jnp.asarray((rng.uniform(size=spec.e_pad) > 0.4), jnp.float32)
        state, _ = round_fn(state, batches, jax.random.split(key, 7), masks)
        mass = runtime_tracked_mass(state)["w"]
        total_g = state.g_prev["w"].sum(0)
        np.testing.assert_allclose(np.asarray(mass), np.asarray(total_g),
                                   rtol=1e-4, atol=1e-4)


def test_runtime_heterogeneity_free():
    """Fixed point is the exact global optimum despite extreme per-node
    heterogeneity (gradient tracking, Remark 7)."""
    topo = directed_ring(4)
    state, x_star = _run(topo, rounds=900, gamma=0.06, seed=9)
    x_bar = np.asarray(state.x["w"]).mean(0)
    assert np.abs(x_bar - np.asarray(x_star)).max() < 5e-4
