"""The protocol substrate: CommPlan consistency, backend equivalence
(impl="jnp" vs impl="pallas" vs the pre-refactor runtime round), and the
Lemma-3 invariant through a CommPlan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TOPOLOGIES, ProtocolState, get_topology,
                        protocol_tracked_mass)
from repro.core.plan import build_comm_plan, matchings
from repro.core.runtime import (edge_arrays, init_node_state,
                                make_rfast_round)

TOPOS = [("binary_tree", 5), ("directed_ring", 6), ("exponential", 7),
         ("mesh2d", 6), ("line", 4), ("parameter_server", 7)]


def quad_problem(n, p, seed=0):
    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)
    S = jnp.asarray(rng.uniform(0.5, 2.0, (n, 1)), jnp.float32)

    def grad_fn(params, batch, key):
        c, s = batch
        g = {"w": s * (params["w"] - c)}
        return 0.5 * jnp.sum(s * (params["w"] - c) ** 2), g

    return grad_fn, (C, S)


# ------------------------------------------------------------------ #
# pre-refactor oracle: the historic runtime.py round, verbatim
# ------------------------------------------------------------------ #
def make_prerefactor_round(spec, grad_fn, *, gamma, robust=False,
                           momentum=0.0):
    """Copy of make_rfast_round as it existed before the protocol.py
    unification (dense scatter/gather, no backend switch) — the fixture
    the unified implementations must reproduce."""
    n = spec.n
    w_diag = jnp.asarray(spec.w_diag)
    a_diag = jnp.asarray(spec.a_diag)
    src_w = jnp.asarray(spec.src_w); dst_w = jnp.asarray(spec.dst_w)
    src_a = jnp.asarray(spec.src_a); dst_a = jnp.asarray(spec.dst_a)
    w_edge = jnp.asarray(spec.w_edge); a_edge = jnp.asarray(spec.a_edge)

    def vgrads(x, batches, keys):
        return jax.vmap(grad_fn)(x, batches, keys)

    def round_fn(state, batches, keys, masks=None):
        lr = gamma(state.step) if callable(gamma) else gamma
        if momentum:
            m = jax.tree.map(lambda mm, zz: momentum * mm + zz,
                             state.m, state.z)
            v = jax.tree.map(lambda xx, mm: xx - lr * mm, state.x, m)
        else:
            m = None
            v = jax.tree.map(lambda xx, zz: xx - lr * zz, state.x, state.z)

        if masks is None and not robust:
            def mix_x(vl):
                out = w_diag.reshape((n,) + (1,) * (vl.ndim - 1)) * vl
                contrib = w_edge.reshape((-1,) + (1,) * (vl.ndim - 1)) \
                    * vl[src_w]
                return out.at[dst_w].add(contrib.astype(out.dtype))
            x_new = jax.tree.map(mix_x, v)
            mail_v = state.mail_v
        else:
            mk = jnp.ones((spec.e_pad,), jnp.float32) if masks is None \
                else masks
            def mix_robust(vl, ml):
                mshape = (-1,) + (1,) * (vl.ndim - 1)
                mkr = mk.reshape(mshape)
                recv = mkr * vl[src_w] + (1 - mkr) * ml
                out = w_diag.reshape((n,) + (1,) * (vl.ndim - 1)) * vl
                contrib = w_edge.reshape(mshape) * recv
                return out.at[dst_w].add(contrib.astype(out.dtype)), recv
            pairs = jax.tree.map(mix_robust, v, state.mail_v)
            x_new = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda q: isinstance(q, tuple))
            mail_v = jax.tree.map(lambda p: p[1], pairs,
                                  is_leaf=lambda q: isinstance(q, tuple))

        losses, g_new = vgrads(x_new, batches, keys)
        mk = jnp.ones((spec.e_pad,), jnp.float32) if masks is None else masks

        def track(zl, gl_new, gl_old, rho_l, buf_l):
            mshape = (-1,) + (1,) * (zl.ndim - 1)
            mkr = mk.reshape(mshape)
            diff = (mkr * (rho_l - buf_l)).astype(zl.dtype)
            recv = jnp.zeros_like(zl).at[dst_a].add(diff)
            z_half = zl + recv + gl_new - gl_old
            z_new = a_diag.reshape((n,) + (1,) * (zl.ndim - 1)) * z_half
            push = a_edge.reshape(mshape) * z_half[src_a]
            rho_new = rho_l + push.astype(rho_l.dtype)
            buf_new = mkr * rho_l + (1 - mkr) * buf_l
            return z_new, rho_new, buf_new

        trip = jax.tree.map(track, state.z, g_new, state.g_prev,
                            state.rho, state.rho_buf)
        is3 = lambda q: isinstance(q, tuple)
        z_new = jax.tree.map(lambda t: t[0], trip, is_leaf=is3)
        rho_new = jax.tree.map(lambda t: t[1], trip, is_leaf=is3)
        buf_new = jax.tree.map(lambda t: t[2], trip, is_leaf=is3)

        return ProtocolState(
            step=state.step + 1, x=x_new, z=z_new, g_prev=g_new,
            rho=rho_new, rho_buf=buf_new, mail_v=mail_v, m=m), losses

    return round_fn


def _run_impl(round_fn, state, batches, n, e_pad, rounds, loss_prob, seed,
              is_oracle=False):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    for t in range(rounds):
        masks = None
        if loss_prob > 0:
            masks = jnp.asarray(rng.uniform(size=e_pad) >= loss_prob,
                                jnp.float32)
        keys = jax.random.split(jax.random.fold_in(key, t), n)
        out = round_fn(state, batches, keys, masks)
        state = out[0]
    return state


# ------------------------------------------------------------------ #
# backend equivalence on random topologies with random loss masks
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name,n", TOPOS)
@pytest.mark.parametrize("loss_prob", [0.0, 0.4])
@pytest.mark.slow
def test_backends_match_prerefactor_round(name, n, loss_prob):
    topo = get_topology(name, n)
    spec = edge_arrays(topo)
    p = 9
    grad_fn, batches = quad_problem(n, p, seed=n)
    params = {"w": jnp.zeros((p,), jnp.float32)}
    robust = loss_prob > 0
    key = jax.random.PRNGKey(0)
    st0 = init_node_state(spec, params, grad_fn, batches, key, robust=robust)

    oracle = jax.jit(make_prerefactor_round(spec, grad_fn, gamma=0.05,
                                            robust=robust))
    r_jnp = jax.jit(make_rfast_round(spec, grad_fn, gamma=0.05,
                                     robust=robust, impl="jnp"))
    r_pal = jax.jit(make_rfast_round(spec, grad_fn, gamma=0.05,
                                     robust=robust, impl="pallas"))

    args = (st0, batches, n, spec.e_pad, 12, loss_prob, 7)
    s_or = _run_impl(oracle, *args)
    s_j = _run_impl(r_jnp, *args)
    s_p = _run_impl(r_pal, *args)

    for f in ("x", "z", "rho", "rho_buf"):
        a = np.asarray(getattr(s_or, f)["w"])
        # impl="jnp" IS the pre-refactor math: bit-equal
        np.testing.assert_array_equal(a, np.asarray(getattr(s_j, f)["w"]),
                                      err_msg=f"jnp {name} {f}")
        # the fused kernel path agrees to fp32 tolerance
        np.testing.assert_allclose(a, np.asarray(getattr(s_p, f)["w"]),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"pallas {name} {f}")


def test_backends_match_with_momentum():
    topo = get_topology("binary_tree", 6)
    spec = edge_arrays(topo)
    p = 5
    grad_fn, batches = quad_problem(6, p, seed=2)
    params = {"w": jnp.zeros((p,), jnp.float32)}
    key = jax.random.PRNGKey(1)
    st0 = init_node_state(spec, params, grad_fn, batches, key,
                          robust=True, momentum=0.7)
    mk_args = dict(gamma=0.03, robust=True, momentum=0.7)
    oracle = jax.jit(make_prerefactor_round(spec, grad_fn, **mk_args))
    r_jnp = jax.jit(make_rfast_round(spec, grad_fn, impl="jnp", **mk_args))
    r_pal = jax.jit(make_rfast_round(spec, grad_fn, impl="pallas",
                                     **mk_args))
    args = (st0, batches, 6, spec.e_pad, 10, 0.3, 3)
    s_or, s_j, s_p = (_run_impl(r, *args) for r in (oracle, r_jnp, r_pal))
    for f in ("x", "z", "m"):
        a = np.asarray(getattr(s_or, f)["w"])
        np.testing.assert_array_equal(a, np.asarray(getattr(s_j, f)["w"]))
        np.testing.assert_allclose(a, np.asarray(getattr(s_p, f)["w"]),
                                   rtol=2e-5, atol=2e-5)


def test_schedule_gamma_and_losses_metrics():
    """Both backends accept a schedule for gamma and report same losses."""
    topo = get_topology("directed_ring", 4)
    spec = edge_arrays(topo)
    grad_fn, batches = quad_problem(4, 3, seed=5)
    params = {"w": jnp.zeros((3,), jnp.float32)}
    st = init_node_state(spec, params, grad_fn, batches,
                         jax.random.PRNGKey(0))
    sched = lambda step: 0.1 / (1.0 + 0.1 * step)
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    outs = {}
    for im in ("jnp", "pallas"):
        rf = jax.jit(make_rfast_round(spec, grad_fn, gamma=sched, impl=im))
        _, metrics = rf(st, batches, keys, None)
        assert metrics["losses"].shape == (4,)
        outs[im] = float(metrics["loss"])
    assert outs["jnp"] == pytest.approx(outs["pallas"], rel=1e-6)


# ------------------------------------------------------------------ #
# Lemma 3 through CommPlan (both backends, random masks)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_tracked_mass_invariant_through_commplan(impl):
    topo = get_topology("binary_tree", 7)
    plan = build_comm_plan(topo)
    grad_fn, batches = quad_problem(7, 4, seed=3)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    key = jax.random.PRNGKey(4)
    state = init_node_state(plan, params, grad_fn, batches, key, robust=True)
    rf = jax.jit(make_rfast_round(plan, grad_fn, gamma=0.02, robust=True,
                                  impl=impl))
    rng = np.random.default_rng(6)
    for _ in range(30):
        masks = jnp.asarray(rng.uniform(size=plan.e_pad) > 0.4, jnp.float32)
        state, _ = rf(state, batches, jax.random.split(key, 7), masks)
        mass = np.asarray(protocol_tracked_mass(state)["w"])
        gsum = np.asarray(state.g_prev["w"].sum(0))
        np.testing.assert_allclose(mass, gsum, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# CommPlan representation consistency
# ------------------------------------------------------------------ #
def _dense_from_edge_arrays(plan, n):
    W = np.zeros((n, n)); A = np.zeros((n, n))
    W[np.arange(n), np.arange(n)] = plan.w_diag
    A[np.arange(n), np.arange(n)] = plan.a_diag
    for e in range(plan.n_edges_w):
        W[plan.dst_w[e], plan.src_w[e]] = plan.w_edge[e]
    for e in range(plan.n_edges_a):
        A[plan.dst_a[e], plan.src_a[e]] = plan.a_edge[e]
    return W, A


def _dense_from_node_tables(plan, n):
    W = np.zeros((n, n)); A = np.zeros((n, n))
    W[np.arange(n), np.arange(n)] = plan.w_diag
    A[np.arange(n), np.arange(n)] = plan.a_diag
    for i in range(n):
        for k in range(plan.kw):
            if plan.in_w_wt[i, k] > 0:
                W[i, plan.in_w_src[i, k]] = plan.in_w_wt[i, k]
        for k in range(plan.ko):
            if plan.out_a_val[i, k] > 0:
                e = plan.out_a_epos[i, k]
                A[plan.dst_a[e], i] = plan.out_a_wt[i, k]
    return W, A


def _dense_from_slots(plan, n):
    W = np.zeros((n, n)); A = np.zeros((n, n))
    W[np.arange(n), np.arange(n)] = plan.w_diag
    A[np.arange(n), np.arange(n)] = plan.a_diag
    for s, es in enumerate(plan.slots_w):
        for (j, i) in es:
            W[i, j] = plan.w_in_table[s, i]
    for s, es in enumerate(plan.slots_a):
        for (j, i) in es:
            A[i, j] = plan.a_out_table[s, j]
    return W, A


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("n", [3, 6, 9])
def test_commplan_representations_agree(name, n):
    """Dense edge arrays, matching slot tables, and per-node neighbour
    tables all reconstruct the same (W, A)."""
    topo = get_topology(name, n)
    plan = build_comm_plan(topo)
    assert plan.e_pad % plan.n == 0
    assert plan.e_pad >= max(plan.n_edges_w, plan.n_edges_a)
    # padded tail entries carry zero weight
    assert np.all(plan.w_edge[plan.n_edges_w:] == 0)
    assert np.all(plan.a_edge[plan.n_edges_a:] == 0)
    for rebuild in (_dense_from_edge_arrays, _dense_from_node_tables,
                    _dense_from_slots):
        W, A = rebuild(plan, n)
        np.testing.assert_allclose(W, topo.W, atol=1e-6, err_msg=name)
        np.testing.assert_allclose(A, topo.A, atol=1e-6, err_msg=name)
    # each A-edge owned by exactly one (node, out-slot) and one in-slot
    owned = sorted(plan.out_a_epos[plan.out_a_val > 0].tolist())
    assert owned == list(range(plan.n_edges_a))
    received = sorted(plan.in_a_epos[plan.in_a_val > 0].tolist())
    assert received == list(range(plan.n_edges_a))


def test_matchings_unique_src_dst():
    for name, n in TOPOS:
        topo = get_topology(name, n)
        for edges in (topo.edges_W(), topo.edges_A()):
            slots = matchings(edges)
            assert sorted(e for s in slots for e in s) == sorted(edges)
            for s in slots:
                assert len({j for j, _ in s}) == len(s)
                assert len({i for _, i in s}) == len(s)
