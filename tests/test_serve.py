"""Serving engine tests: incremental-decode correctness, the
compiled-executable cache pin (zero recompiles at steady state AND
across a live weight swap), checkpoint manifest atomicity, and the full
publish -> poll -> hot-swap loop against ``launch/train.py``.
"""
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.models.config import ModelConfig
from repro.models.transformer import (decode_step, forward, init_params,
                                      prefill_cache)
from repro.serve import (Request, Scheduler, ServeEngine, WeightStore,
                         cache as serve_cache, make_workload)
from tests.helpers.recompiles import assert_no_recompiles

TINY = ModelConfig(name="serve-tiny", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab=64)

# decoder-only text families with a decode path (attn incl. MLA, ssm,
# hybrid); enc-dec/frontend archs have no incremental text-only decode
DECODER_ARCHS = ["olmo-1b", "llama3-8b", "deepseek-v2-236b",
                 "falcon-mamba-7b", "hymba-1.5b"]


def _teacher_forced_check(cfg, *, S=16, S_prompt=6, seed=0,
                          rtol=2e-3, atol=2e-3):
    """prefill_cache + K x decode_step logits must match ONE
    teacher-forced forward pass at every decoded position."""
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, S), 0,
                              cfg.vocab)
    ref = forward(cfg, params, toks)[0]          # (B, S, V)

    cache, logits = prefill_cache(cfg, params, toks[:, :S_prompt], S)
    np.testing.assert_allclose(logits[:, 0], ref[:, S_prompt - 1],
                               rtol=rtol, atol=atol)
    for t in range(S_prompt, S):                 # teacher-forced decode
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(ref[:, t]),
            rtol=rtol, atol=atol,
            err_msg=f"{cfg.name}: decode position {t}")


@pytest.mark.slow
@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_incremental_decode_matches_teacher_forced(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe_experts:
        # capacity-bounded MoE drops tokens as a function of sequence
        # LENGTH, so a 6-token prefill routes differently from a
        # 16-token forward by design; lift the capacity bound so the
        # routing (and thus the equivalence) is length-independent
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    _teacher_forced_check(cfg)


@pytest.mark.parametrize("attention", ["gqa", "mla"])
def test_incremental_decode_mid_sequence_slot_reuse(attention):
    """Windowed attention with C=6 < S=16: ring slots are overwritten
    mid-sequence (position p and p+6 share a slot), and the incremental
    logits still match the window-masked teacher-forced forward."""
    cfg = dataclasses.replace(
        TINY, name=f"serve-tiny-{attention}", attention=attention,
        attn_window=6, kv_lora_rank=16 if attention == "mla" else 0,
        qk_rope_dim=8)
    _teacher_forced_check(cfg, S=16, S_prompt=4)


def _tiny_engine(params, *, batch=4, buckets=(4, 8, 16), **kw):
    return ServeEngine(TINY, WeightStore(params), batch=batch,
                       max_len=32, buckets=buckets, **kw)


def _reference_greedy(cfg, params, prompt, gen, max_len=32):
    cache, logits = prefill_cache(cfg, params,
                                  jnp.asarray(prompt)[None], max_len)
    t = int(jnp.argmax(logits[0, 0]))
    out = [t]
    for _ in range(gen - 1):
        logits, cache = decode_step(cfg, params, cache,
                                    jnp.asarray([[t]]))
        t = int(jnp.argmax(logits[0, 0]))
        out.append(t)
    return out


def test_engine_matches_single_request_decode():
    """Continuous batching is a scheduling optimization, not a model
    change: every request's greedy tokens equal a dedicated B=1
    prefill_cache + decode_step loop."""
    params = init_params(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, TINY.vocab, size=int(
                        rng.integers(1, 14))).astype(np.int32),
                    gen=int(rng.integers(1, 6)), arrive_s=0.0)
            for i in range(12)]
    serve_cache.clear()
    eng = _tiny_engine(params)
    eng.run(reqs)
    for r in reqs:
        assert r.done and r.tokens == _reference_greedy(
            TINY, params, r.prompt, r.gen), f"request {r.rid}"


def test_steady_state_cache_pin_and_zero_recompile_swap():
    """>=100 mixed-length requests settle the executable cache at
    exactly 1 decode + n_buckets prefill entries; a live weight swap
    with requests in flight then adds ZERO entries and drops nothing."""
    params = init_params(TINY, jax.random.PRNGKey(0))
    eng = _tiny_engine(params, swap_mode="immediate")
    store = eng.store
    reqs = make_workload(110, vocab=TINY.vocab, max_prompt=16, max_gen=4,
                         seed=3)
    assert len({len(r.prompt) for r in reqs}) > 3   # genuinely mixed

    with assert_no_recompiles(expect_entries=4, cache=serve_cache) as rec:
        eng.run(reqs)
    assert all(r.done for r in reqs)
    assert rec.misses == 4 and rec.hits > 100

    # phase 2: same engine, warm cache, live swap mid-flight (fixed
    # gen=5 so requests provably span the flip step)
    params2 = jax.tree.map(lambda a: a * 0.9, params)
    rng = np.random.default_rng(4)
    more = [Request(rid=1000 + i,
                    prompt=rng.integers(0, TINY.vocab, size=int(
                        rng.integers(1, 16))).astype(np.int32),
                    gen=5, arrive_s=0.0) for i in range(30)]
    with assert_no_recompiles(expect_entries=0, fresh=False,
                              cache=serve_cache) as rec2:
        sched = Scheduler(more)
        eng._t0 = time.perf_counter()
        while eng.in_flight == 0:
            eng.step(sched)
        in_flight_rids = {r.rid for r in eng._slot_req if r is not None}
        assert in_flight_rids                       # swap lands mid-batch
        store.offer(params2, step=7, published_at=time.time())
        while len(sched) or eng.in_flight or store.staged:
            eng.step(sched)
    assert rec2.misses == 0 and rec2.hits > 0
    assert store.swaps and store.step == 7
    # the flip landed while the primed batch was still in flight
    assert store.swaps[0]["engine_step"] <= max(
        r.done_step for r in more if r.rid in in_flight_rids)
    # nothing dropped: the in-flight batch finished, on the new weights
    assert all(r.done for r in more)
    served_steps = {r.weights_step for r in more}
    assert served_steps >= {7}                      # new admissions swap


def test_drain_mode_finishes_in_flight_on_old_weights():
    """swap_mode='drain': once a checkpoint is staged, admissions pause
    and every in-flight request finishes on the OLD weights; the flip
    lands on the first empty step and later admissions serve the new."""
    params = init_params(TINY, jax.random.PRNGKey(0))
    eng = _tiny_engine(params, batch=2, swap_mode="drain")
    store = eng.store
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, TINY.vocab, size=4).astype(np.int32), gen=6, arrive_s=0.0)
        for i in range(6)]
    sched = Scheduler(reqs)
    eng._t0 = time.perf_counter()
    while eng.in_flight < 2:
        eng.step(sched)
    old_rids = {r.rid for r in eng._slot_req if r is not None}
    store.offer(jax.tree.map(lambda a: a * 0.9, params), step=3,
                published_at=time.time())
    while len(sched) or eng.in_flight or store.staged:
        eng.step(sched)
    assert store.swaps and store.step == 3
    flip_step = store.swaps[0]["engine_step"]
    for r in reqs:
        assert r.done
        if r.rid in old_rids:
            assert r.weights_step == -1 and r.done_step <= flip_step
        else:
            assert r.weights_step == 3 and r.admit_step >= flip_step


def test_engine_rejects_non_attention_archs():
    cfg = get_config("falcon-mamba-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="decoder-only attention"):
        ServeEngine(cfg, WeightStore(params))


def test_bucket_for_and_overflow():
    params = init_params(TINY, jax.random.PRNGKey(0))
    eng = _tiny_engine(params, buckets=(4, 8))
    assert [eng.bucket_for(s) for s in (1, 4, 5, 8)] == [4, 4, 8, 8]
    with pytest.raises(ValueError, match="exceeds largest bucket"):
        eng.bucket_for(9)
    unbucketized = _tiny_engine(params, buckets=None)
    assert unbucketized.bucket_for(13) == 13


# ------------------------------------------------------------------- #
# checkpoint manifest / atomicity
# ------------------------------------------------------------------- #
def test_ckpt_manifest_written_and_read(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    ckpt.save_checkpoint(d, 12, tree)
    man = ckpt.read_manifest(d)
    assert man["step"] == 12 and man["file"] == "step_0000000012.npz"
    assert man["leaves"] == 1 and man["time"] <= time.time()
    assert ckpt.latest_step(d) == 12
    ckpt.save_checkpoint(d, 20, tree)
    assert ckpt.read_manifest(d)["step"] == 20
    back = ckpt.load_checkpoint(d, tree)
    np.testing.assert_array_equal(back["w"], tree["w"])
    # no leftover tmp files from the atomic writes
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_ckpt_rejects_torn_npz(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.zeros((4, 4), np.float32)}
    path = ckpt.save_checkpoint(d, 3, tree)
    blob = open(path, "rb").read()
    with open(path, "wb") as fh:           # simulate a torn writer
        fh.write(blob[: len(blob) // 2])
    with pytest.raises(ValueError, match="torn or partial checkpoint"):
        ckpt.load_checkpoint(d, tree, step=3)


def test_ckpt_manifest_pointing_at_missing_file(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.zeros(3, np.float32)}
    path = ckpt.save_checkpoint(d, 3, tree)
    os.remove(path)
    with pytest.raises(ValueError, match="points at missing"):
        ckpt.read_manifest(d)


def test_ckpt_unreadable_manifest(tmp_path):
    d = str(tmp_path)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, ckpt.MANIFEST), "w") as fh:
        fh.write("{not json")
    with pytest.raises(ValueError, match="unreadable checkpoint manifest"):
        ckpt.read_manifest(d)


def test_latest_step_legacy_fallback_without_manifest(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.zeros(3, np.float32)}
    ckpt.save_checkpoint(d, 7, tree)
    os.remove(os.path.join(d, ckpt.MANIFEST))
    assert ckpt.latest_step(d) == 7        # regex fallback still works


def test_weightstore_poll_flip(tmp_path):
    d = str(tmp_path)
    params = init_params(TINY, jax.random.PRNGKey(0))
    newer = jax.tree.map(lambda a: a + 1.0, params)
    store = WeightStore(params, step=2)
    assert store.poll(d) is False          # empty dir: nothing staged
    ckpt.save_checkpoint(d, 2, params)
    assert store.poll(d) is False          # same step: no reload
    ckpt.save_checkpoint(d, 6, newer)
    assert store.poll(d) is True and store.staged
    assert store.step == 2                 # active untouched until flip
    assert store.flip(at_step=11) is True
    assert store.step == 6 and not store.staged
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(store.params)[0]),
        np.asarray(jax.tree.leaves(newer)[0]))
    assert store.flip() is False           # nothing staged: no-op
    store.offer(params, step=4, published_at=0.0)
    assert not store.staged                # older step: rejected


# ------------------------------------------------------------------- #
# the full loop: train --publish-dir -> poll -> hot-swap -> lower loss
# ------------------------------------------------------------------- #
@pytest.mark.slow
def test_publish_serve_hot_swap_e2e(tmp_path):
    from repro.core.paramvec import ravel
    from repro.data.objectives import make_lm_problem
    from repro.launch import train

    pub = str(tmp_path / "pub")
    res = train.main(["--arch", "llama3-8b", "--reduced", "--nodes", "3",
                      "--steps", "12", "--batch-per-node", "2",
                      "--seq", "16", "--scenario", "straggler",
                      "--log-every", "4", "--publish-dir", pub])
    published = res["published"]
    assert len(published) >= 2             # >=2 checkpoints published
    assert ckpt.read_manifest(pub)["step"] == published[-1]

    cfg = get_config("llama3-8b").reduced()
    template = init_params(cfg, jax.random.PRNGKey(0))
    trees = {k: ckpt.load_checkpoint(pub, template, step=k)
             for k in published}

    # replay: serve starts on the FIRST checkpoint; the LAST is
    # re-published while requests are in flight, forcing a live swap
    live = str(tmp_path / "live")
    ckpt.save_checkpoint(live, published[0], trees[published[0]])
    store = WeightStore(jax.device_put(trees[published[0]]),
                        step=published[0])
    serve_cache.clear()
    eng = ServeEngine(cfg, store, batch=4, max_len=48, buckets=(4, 8),
                      swap_mode="drain", poll_every=2, ckpt_dir=live)
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5,
                                               ).astype(np.int32),
                    gen=8, arrive_s=0.0) for i in range(12)]
    sched = Scheduler(reqs)
    eng._t0 = time.perf_counter()
    while eng.in_flight < 4:
        eng.step(sched)
    in_flight_rids = {r.rid for r in eng._slot_req if r is not None}
    ckpt.save_checkpoint(live, published[-1], trees[published[-1]])
    with assert_no_recompiles(expect_entries=0, fresh=False,
                              cache=serve_cache):
        while len(sched) or eng.in_flight or store.staged:
            eng.step(sched)

    # the swap happened live and dropped nothing
    assert store.swaps and store.step == published[-1]
    assert all(r.done for r in reqs)
    assert all(r.done for r in reqs if r.rid in in_flight_rids)
    served = {r.weights_step for r in reqs}
    assert served == {published[0], published[-1]}

    # later checkpoints serve strictly lower eval loss
    prob = make_lm_problem(cfg, 3, batch_per_node=2, seq_len=16, seed=0)
    losses = [float(prob.mean_loss(ravel(prob.spec, trees[k])))
              for k in published]
    assert all(b < a for a, b in zip(losses, losses[1:])), losses


@pytest.mark.slow
def test_serve_cli_smoke(tmp_path):
    """The rebuilt CLI drives the engine end to end (and its RNG streams
    are split per consumer: params vs traffic)."""
    from repro.launch import serve

    serve_cache.clear()                    # isolate from earlier engines
    out = serve.main(["--arch", "llama3-8b", "--reduced", "--batch", "2",
                      "--requests", "8", "--max-prompt", "6",
                      "--max-gen", "3", "--buckets", "4,8"])
    assert out["served"] == 8
    # 1 decode + at most one prefill executable per configured bucket
    assert 2 <= out["cache"]["entries"] <= 3
