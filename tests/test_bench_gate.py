"""The benchmark regression gate: errored and vanished rows must fail
alongside >threshold regressions (they used to be silently skipped)."""
import importlib.util
import json
import pathlib

_RUN_PY = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" / "run.py"
_spec = importlib.util.spec_from_file_location("bench_run_for_test", _RUN_PY)
bench_run = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_run)


def _row(suite, name, us, derived=""):
    return {"suite": suite, "name": name, "us_per_call": us,
            "derived": derived}


def _write_baseline(tmp_path, rows):
    path = tmp_path / "base.json"
    path.write_text(json.dumps({"rows": rows}))
    return str(path)


def test_gate_flags_regressions_errors_and_missing(tmp_path):
    base = _write_baseline(tmp_path, [
        _row("sim", "fast", 100.0),
        _row("sim", "vanished", 50.0),
        _row("unrun_suite", "other", 10.0),
    ])
    records = [
        _row("sim", "fast", 140.0),                 # +40% regression
        _row("sim", "broken", None, "ERROR:Boom"),  # errored this run
        _row("sim", "brand_new", 5.0),              # new row: not a problem
    ]
    problems = bench_run._compare(records, base, 0.25)
    kinds = sorted(p["problem"] for p in problems)
    assert kinds == ["errored", "missing", "regression"]
    missing = next(p for p in problems if p["problem"] == "missing")
    assert (missing["suite"], missing["name"]) == ("sim", "vanished")
    # suites that did not run are not reported as missing
    assert not any(p.get("name") == "other" for p in problems)


def test_gate_passes_clean_run(tmp_path):
    base = _write_baseline(tmp_path, [_row("sim", "fast", 100.0)])
    records = [_row("sim", "fast", 110.0)]   # +10% < 25% threshold
    assert bench_run._compare(records, base, 0.25) == []


def test_gate_ignores_intentional_nan_rows(tmp_path):
    """Correctness-only rows record nan us by design (e.g.
    kernels/protocol/round_jnp_vs_pallas with derived maxerr=...) — only
    rows whose derived starts with ERROR: gate as errored."""
    base = _write_baseline(tmp_path, [
        _row("kernels", "check", None, "maxerr=0.0e+00")])
    records = [_row("kernels", "check", None, "maxerr=0.0e+00")]
    assert bench_run._compare(records, base, 0.25) == []
    records = [_row("kernels", "check", None, "ERROR:Boom:bad")]
    probs = bench_run._compare(records, base, 0.25)
    assert [p["problem"] for p in probs] == ["errored"]


def test_gate_skips_missing_check_when_run_meta_differs(tmp_path):
    """--impl / --quick subsets legitimately drop rows the baseline has
    (e.g. the jnp rows of a both-impls kernels baseline): the missing
    gate must only fire when the run settings match the baseline's."""
    path = tmp_path / "base.json"
    path.write_text(json.dumps({
        "meta": {"quick": False, "impl": "both"},
        "rows": [_row("kernels", "round_jnp", 10.0),
                 _row("kernels", "round_pallas", 20.0)],
    }))
    # pallas-only run: the jnp row is absent and per-call times are not
    # comparable (different settings) — neither missing nor the apparent
    # "regression" may fire
    records = [_row("kernels", "round_pallas", 90.0)]
    assert bench_run._compare(records, str(path), 0.25,
                              run_meta={"quick": False,
                                        "impl": "pallas"}) == []
    # matching meta: both the vanished row and the regression fail
    probs = bench_run._compare(records, str(path), 0.25,
                               run_meta={"quick": False, "impl": "both"})
    assert sorted(p["problem"] for p in probs) == ["missing", "regression"]


def test_gate_structural_mode_ignores_timing_regressions(tmp_path):
    """--structural (the CI gate): errored and missing rows still fail,
    arbitrary slowdowns do not — shared CI runners are too noisy for
    the timing threshold."""
    base = _write_baseline(tmp_path, [
        _row("sim", "fast", 100.0),
        _row("sim", "vanished", 50.0),
    ])
    records = [
        _row("sim", "fast", 1000.0),                # 10x slower: ignored
        _row("sim", "broken", None, "ERROR:Boom"),  # still gates
    ]
    probs = bench_run._compare(records, base, 0.25, structural=True)
    assert sorted(p["problem"] for p in probs) == ["errored", "missing"]
    clean = [_row("sim", "fast", 1000.0), _row("sim", "vanished", 50.0)]
    assert bench_run._compare(clean, base, 0.25, structural=True) == []


def test_gate_ignores_zero_or_errored_baseline_rows(tmp_path):
    base = _write_baseline(tmp_path, [
        _row("sim", "was_broken", None),
        _row("sim", "was_zero", 0.0),
    ])
    records = [_row("sim", "was_broken", 10.0), _row("sim", "was_zero", 9.0)]
    assert bench_run._compare(records, base, 0.25) == []


def test_perf_gate_ratio_of_ratios(tmp_path):
    """--perf-gate compares each *_pallas_* row's ratio to its jnp/ref
    counterpart against the SAME ratio in the baseline — absolute host
    speed cancels, only relative pallas drift fails."""
    base = _write_baseline(tmp_path, [
        _row("kernels", "protocol/round_jnp_8x64k", 100.0),
        _row("kernels", "protocol/round_pallas_8x64k", 100.0),  # ratio 1.0
        _row("kernels", "kernel/rfast_commit_ref_1M", 50.0),
        _row("kernels", "kernel/rfast_commit_pallas_1M", 100.0),  # ratio 2.0
    ])
    # a uniformly 3x slower host with identical ratios passes
    ok = [
        _row("kernels", "protocol/round_jnp_8x64k", 300.0),
        _row("kernels", "protocol/round_pallas_8x64k", 300.0),
        _row("kernels", "kernel/rfast_commit_ref_1M", 150.0),
        _row("kernels", "kernel/rfast_commit_pallas_1M", 300.0),
    ]
    assert bench_run._perf_gate(ok, base, 0.25) == []
    # pallas drifting from 1.0x to 1.5x its counterpart fails, even on
    # the faster host; the 2.0x->2.1x row stays inside the threshold
    bad = [
        _row("kernels", "protocol/round_jnp_8x64k", 50.0),
        _row("kernels", "protocol/round_pallas_8x64k", 75.0),
        _row("kernels", "kernel/rfast_commit_ref_1M", 50.0),
        _row("kernels", "kernel/rfast_commit_pallas_1M", 105.0),
    ]
    assert bench_run._perf_gate(bad, base, 0.25) == \
        ["protocol/round_pallas_8x64k"]


def test_perf_gate_skips_uncovered_rows(tmp_path):
    """Rows without a counterpart or without a baseline ratio are
    reported but never gated."""
    base = _write_baseline(tmp_path, [
        _row("kernels", "protocol/round_jnp_8x64k", 100.0),
    ])
    records = [
        # no jnp/ref counterpart in this run
        _row("kernels", "kernel/only_pallas_1M", 500.0),
        # counterpart exists but the baseline has no such pair
        _row("kernels", "protocol/round_jnp_8x1M", 100.0),
        _row("kernels", "protocol/round_pallas_8x1M", 900.0),
        # correctness-only rows (nan us) never participate
        _row("kernels", "protocol/round_jnp_vs_pallas_8x64k", None,
             "maxerr=0.0e+00"),
    ]
    assert bench_run._perf_gate(records, base, 0.25) == []


def test_gate_structural_requires_dynamic_rows(tmp_path):
    """--structural additionally requires the dynamic-graph families
    (showdown/root_failover/*, churn/*) whenever the showdown suite ran
    — even against a baseline that predates those rows."""
    base = _write_baseline(tmp_path, [
        _row("showdown", "showdown/straggler/R-FAST", 100.0)])
    # suite ran but produced no failover/churn rows: both prefixes fail
    records = [_row("showdown", "showdown/straggler/R-FAST", 100.0)]
    probs = bench_run._compare(records, base, 0.25, structural=True)
    assert sorted(p["name"] for p in probs
                  if p["problem"] == "required-missing") == \
        ["churn/", "showdown/root_failover/"]
    # an ERRORED failover row does not satisfy the requirement
    records += [_row("showdown", "showdown/root_failover/R-FAST", None,
                     "ERROR:Boom"),
                _row("showdown", "churn/churn/R-FAST", 50.0)]
    probs = bench_run._compare(records, base, 0.25, structural=True)
    assert [p["name"] for p in probs
            if p["problem"] == "required-missing"] == \
        ["showdown/root_failover/"]
    # healthy rows for both prefixes: requirement satisfied
    records[-2] = _row("showdown", "showdown/root_failover/R-FAST", 60.0,
                       "vtime=130.0")
    probs = bench_run._compare(records, base, 0.25, structural=True)
    assert not any(p["problem"] == "required-missing" for p in probs)
    # suites that did not run are never required
    other = [_row("sim", "fast", 1.0)]
    base2 = _write_baseline(tmp_path, [_row("sim", "fast", 1.0)])
    assert bench_run._compare(other, base2, 0.25, structural=True) == []


def test_gate_structural_requires_mesh_scaling_rows(tmp_path):
    """--structural requires the mesh-mapped production-scale rows:
    scaling/n63..n255 + lm100m/* when the scaling suite ran, and
    sweep/fleet_sharded_* when the sweep suite ran."""
    base = _write_baseline(tmp_path, [_row("scaling", "scaling/n3", 10.0)])
    records = [_row("scaling", "scaling/n3", 10.0),
               _row("scaling", "scaling/n63", 20.0, "devices=1")]
    probs = bench_run._compare(records, base, 0.25, structural=True)
    assert sorted(p["name"] for p in probs
                  if p["problem"] == "required-missing") == \
        ["lm100m/", "scaling/n127", "scaling/n255"]
    records += [_row("scaling", "scaling/n127", 20.0),
                _row("scaling", "scaling/n255", 20.0),
                _row("scaling", "lm100m/wavefront_mesh", 9e6,
                     "p=134217728")]
    probs = bench_run._compare(records, base, 0.25, structural=True)
    assert not any(p["problem"] == "required-missing" for p in probs)

    base_sw = _write_baseline(tmp_path, [
        _row("sweep", "sweep/fleet_n7_S8", 5.0)])
    recs = [_row("sweep", "sweep/fleet_n7_S8", 5.0)]
    probs = bench_run._compare(recs, base_sw, 0.25, structural=True)
    assert [p["name"] for p in probs
            if p["problem"] == "required-missing"] == \
        ["sweep/fleet_sharded_"]
    recs += [_row("sweep", "sweep/fleet_sharded_d1", 5.0,
                  "speedup_vs_d1=1.00x")]
    probs = bench_run._compare(recs, base_sw, 0.25, structural=True)
    assert not any(p["problem"] == "required-missing" for p in probs)
