"""Shared test configuration.

Installs a minimal ``hypothesis`` fallback into ``sys.modules`` when the
real package is absent, so ``tests/test_kernels.py`` and
``tests/test_properties.py`` collect and run everywhere (CI images without
dev deps used to error the whole pytest run at collection time).

The fallback implements just the surface this repo uses — ``given`` /
``settings`` / ``strategies.{integers,floats,sampled_from,booleans}`` —
by drawing ``max_examples`` deterministic pseudo-random examples per test.
No shrinking, no example database: install the real package
(``pip install -r requirements-dev.txt``) for full property testing.
"""
from __future__ import annotations

import random
import sys
import types


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=None, max_value=None):
        lo = 0 if min_value is None else int(min_value)
        hi = (1 << 16) if max_value is None else int(max_value)
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))

    def given(*gargs, **gkwargs):
        def deco(fn):
            # NOTE: the wrapper must expose a ZERO-ARG signature (no
            # functools.wraps/__wrapped__), otherwise pytest would try to
            # resolve the strategy parameters as fixtures.
            def wrapper():
                n = int(getattr(wrapper, "_hypo_max_examples", 20))
                rng = random.Random(0)
                for _ in range(n):
                    a = [s.draw(rng) for s in gargs]
                    kw = {k: s.draw(rng) for k, s in gkwargs.items()}
                    fn(*a, **kw)
            wrapper.__name__ = getattr(fn, "__name__", "wrapped")
            wrapper.__doc__ = getattr(fn, "__doc__", None)
            wrapper.is_hypothesis_fallback = True
            return wrapper
        return deco

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._hypo_max_examples = max_examples
            return fn
        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    st.booleans = booleans

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__version__ = "0.0-fallback"

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()
