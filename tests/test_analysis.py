"""repro.analysis: the linter lints, and each RF code fires on exactly
the bug class it owns.

Three layers of pinning:

* CLEAN — real plans from the registry matrix (and their transform
  compositions) produce zero diagnostics, bit-for-bit roundtrips hold,
  and the engine wiring (``verify_plans=True``) passes end to end.
* MUTATION — for every diagnostic code, a minimal surgical corruption
  of an otherwise-clean artifact makes its owning pass report exactly
  that code and nothing else.  This is what keeps the codes *stable*:
  a refactor that silently widens or narrows a check trips here.
* WIRING — ``check_or_raise`` raises :class:`PlanInvariantError`, the
  topology builders blame themselves by name, and ``audit_engines``
  stays clean over the shipped engines.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import CODES, PlanInvariantError, planlint
from repro.analysis.planlint import unflatten_plans
from repro.core import binary_tree, get_scenario, run_rfast, run_sweep
from repro.core.plan import build_comm_plan, pad_comm_plan
from repro.core.schedule import (_WAVE_FIELDS, build_wavefront_plan,
                                 concat_plans, flatten_plans, pad_plan,
                                 slice_plan, stack_plans)
from repro.core.topology import get_topology

jax.config.update("jax_enable_x64", False)

N = 7
K = 96


def codes(diags):
    return sorted({d.code for d in diags})


def _wf_setup(topo_name="binary_tree", scenario="uniform", seed=0, n=N):
    topo = get_topology(topo_name, n)
    sched = get_scenario(scenario, n).realize(topo, K, seed=seed).schedule
    comm = build_comm_plan(topo)
    H = int(sched.D) + 2
    wf = build_wavefront_plan(sched, comm, H)
    return topo, sched, comm, H, wf


def _fleet_setup(seed=0, n=N):
    """Two heterogeneous lanes through the sweep engine's exact plumbing:
    pad_comm_plan -> build_wavefront_plan(e_a=) -> stack -> flatten."""
    names = ("binary_tree", "line")
    topos = [get_topology(t, n) for t in names]
    comms = [build_comm_plan(t) for t in topos]
    kw = max(c.kw for c in comms)
    ka = max(c.ka for c in comms)
    ko = max(c.ko for c in comms)
    padded = [pad_comm_plan(c, kw=kw, ka=ka, ko=ko) for c in comms]
    scheds = [get_scenario("uniform", n).realize(t, K, seed=seed + s).schedule
              for s, t in enumerate(topos)]
    e_a = max(max(1, c.n_edges_a) for c in padded)
    H = max(int(s.D) + 2 for s in scheds)
    wfs = [build_wavefront_plan(s, c, H, e_a=e_a)
           for s, c in zip(scheds, padded)]
    stacked = stack_plans(wfs)
    return padded, scheds, H, stacked, flatten_plans(stacked)


# ------------------------------------------------------------------ #
# catalog
# ------------------------------------------------------------------ #
def test_code_catalog_complete():
    assert sorted(CODES) == [f"RF10{i}" for i in range(1, 7)] \
        + [f"RF20{i}" for i in range(1, 7)]
    for info in CODES.values():
        assert info.owner and info.title and info.invariant
        assert info.motivation  # every code cites the bug that earned it


# ------------------------------------------------------------------ #
# clean plans stay clean (property layer)
# ------------------------------------------------------------------ #
@settings(max_examples=8, deadline=None)
@given(
    topo_name=st.sampled_from(["binary_tree", "line", "directed_ring",
                               "undirected_ring", "exponential",
                               "robust_tree"]),
    scenario=st.sampled_from(["uniform", "straggler", "packet_loss"]),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_transform_compositions_stay_clean(topo_name, scenario, seed):
    """pad/slice/concat over any realized plan: zero diagnostics, and the
    composed plan still matches the schedule it came from."""
    topo, sched, comm, H, wf = _wf_setup(topo_name, scenario, seed)
    e_a = max(1, comm.n_edges_a)
    assert planlint.lint_comm_plan(comm, topo) == []
    assert planlint.lint_wavefront_plan(
        wf, comm=comm, schedule=sched, H=H) == []
    pp = pad_plan(wf, width=wf.width + 2, n_waves=wf.n_waves + 3,
                  e_a=e_a + 4)
    assert planlint.lint_wavefront_plan(
        pp, comm=comm, schedule=sched, H=H) == []
    mid = max(1, pp.n_waves // 2)
    rejoined = concat_plans([slice_plan(pp, 0, mid),
                             slice_plan(pp, mid, pp.n_waves)])
    assert planlint.lint_wavefront_plan(
        rejoined, comm=comm, schedule=sched, H=H) == []


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_flatten_roundtrip_bit_for_bit(seed):
    """unflatten_plans(flatten_plans(stacked)) == stacked exactly, for
    every table except the aggregate-only event_start/sizes."""
    _, _, H, stacked, flat = _fleet_setup(seed)
    back = unflatten_plans(flat, stacked.agent.shape[0])
    for f in _WAVE_FIELDS:
        if f in ("event_start", "sizes"):
            continue
        a, b = np.asarray(getattr(stacked, f)), np.asarray(getattr(back, f))
        np.testing.assert_array_equal(a, b, err_msg=f)
    assert planlint.lint_flatten(stacked, flat) == []
    assert planlint.lint_wavefront_plan(flat, H=H) == []


# ------------------------------------------------------------------ #
# mutation layer: each code fires, and only it
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def wf_env():
    return _wf_setup()


def _mutate(wf, **arrs):
    return dataclasses.replace(wf, **arrs)


def test_rf101_duplicate_lane_write_write_race(wf_env):
    topo, sched, comm, H, wf = wf_env
    n = topo.n
    ag = np.asarray(wf.agent)
    w = next(w for w in range(wf.n_waves) if (ag[w] != n).sum() >= 2)
    l0, l1 = np.nonzero(ag[w] != n)[0][:2]
    arrs = {}
    for f in _WAVE_FIELDS:
        a = np.array(getattr(wf, f))
        if a.ndim >= 2:
            a[w, l1] = a[w, l0]
            arrs[f] = a
    diags = planlint.lint_wavefront_plan(
        _mutate(wf, **arrs), comm=comm, schedule=sched, H=H)
    assert codes(diags) == ["RF101"], diags


def test_rf102_ring_slot_alias(wf_env):
    topo, sched, comm, H, wf = wf_env
    rs = np.array(wf.rslot_v)
    wi = np.asarray(wf.w_in)
    w, l, c = [x[0] for x in np.nonzero(wi != 0)]
    rs[w, l, c] = (rs[w, l, c] + 1) % H
    diags = planlint.lint_wavefront_plan(
        _mutate(wf, rslot_v=rs), comm=comm, schedule=sched, H=H)
    assert codes(diags) == ["RF102"], diags


def test_rf103_out_of_range_agent(wf_env):
    topo, sched, comm, H, wf = wf_env
    n = topo.n
    ag = np.array(wf.agent)
    w = next(w for w in range(wf.n_waves) if (ag[w] != n).any())
    l = np.nonzero(ag[w] != n)[0][0]
    ag[w, l] = n + 3
    diags = planlint.lint_wavefront_plan(
        _mutate(wf, agent=ag), comm=comm, schedule=sched, H=H)
    assert codes(diags) == ["RF103"], diags


def test_rf104_flatten_offset_corruption():
    _, _, _, stacked, flat = _fleet_setup()
    agf = np.array(flat.agent)
    # a live slot whose lane-local agent is not the last node, so +1
    # stays in-range within the block but breaks the bijection
    wv, sl = [x[0] for x in np.nonzero((agf != flat.n) & (agf % N < N - 1))]
    agf[wv, sl] += 1
    diags = planlint.lint_flatten(
        stacked, dataclasses.replace(flat, agent=agf))
    assert codes(diags) == ["RF104"], diags


def test_rf105_mass_conservation_broken(wf_env):
    topo, _, comm, _, _ = wf_env
    we = np.array(comm.w_edge)
    we[0] += 0.25
    diags = planlint.lint_comm_plan(
        dataclasses.replace(comm, w_edge=we), topo)
    assert codes(diags) == ["RF105"], diags


def test_rf106_epoch_coverage_gap():
    et = get_scenario("churn", N).realize_epochs(
        get_topology("robust_tree", N), 1400, seed=0)
    assert planlint.lint_epoch_trace(et) == []
    eps = list(et.epochs)
    eps[1] = dataclasses.replace(eps[1], joined=np.zeros(N, bool))
    diags = planlint.lint_epoch_trace(
        dataclasses.replace(et, epochs=tuple(eps)))
    assert codes(diags) == ["RF106"], diags


def test_rf201_callback_in_scan():
    from repro.analysis import jaxlint

    def body(c, x):
        y = jax.pure_callback(lambda v: np.asarray(v) * 2,
                              jax.ShapeDtypeStruct((), jnp.float32), x)
        return c + y, y

    cj = jax.make_jaxpr(
        lambda xs: jax.lax.scan(body, jnp.float32(0), xs))(jnp.ones(4))
    assert codes(jaxlint.audit_jaxpr(cj, subject="m")) == ["RF201"]


def test_rf202_f64_promotion():
    from jax.experimental import enable_x64

    from repro.analysis import jaxlint
    with enable_x64():
        cj = jax.make_jaxpr(lambda x: x * np.float64(1.5))(np.float64(2.0))
    assert codes(jaxlint.audit_jaxpr(cj, subject="m")) == ["RF202"]


def test_rf203_materialized_broadcast():
    from repro.analysis import jaxlint
    g = lambda x: (jnp.broadcast_to(x[None, None, :],
                                    (8, 4, x.shape[0])) * 2.0).sum()
    cj = jax.make_jaxpr(g)(jnp.ones(32))
    assert codes(jaxlint.audit_jaxpr(
        cj, subject="m", broadcast_elems_threshold=64)) == ["RF203"]
    # same jaxpr, default threshold: too small to flag
    assert jaxlint.audit_jaxpr(cj, subject="m") == []


def test_rf204_unhonorable_donation():
    from repro.analysis import jaxlint
    h = jax.jit(lambda s: s[:1].sum(), donate_argnums=(0,))
    diags = jaxlint.audit_donation(h, (jnp.ones((4, 4)),), (0,),
                                   subject="m")
    assert codes(diags) == ["RF204"]


def test_rf205_dispatch_cache_churn():
    from repro.analysis import jaxlint
    from repro.kernels.rfast_update import dispatch

    state = {"i": 0}

    def churn():
        state["i"] += 1
        dispatch.lookup(("k", state["i"]), lambda: (lambda: None))()

    diags = jaxlint.audit_dispatch(churn, subject="m", expect_entries=1)
    assert codes(diags) == ["RF205"]

    def steady():
        dispatch.lookup(("k",), lambda: (lambda: None))()

    assert jaxlint.audit_dispatch(steady, subject="m") == []


def test_rf205_serve_cache_clean_and_unbucketized_mutation():
    """The serving executable cache passes the RF205 audit with length
    bucketing on, and the mutation — ``buckets=None``, so every distinct
    prompt length compiles its own prefill executable — fires it."""
    from repro.analysis import jaxlint

    diags, audited = jaxlint.audit_serve_cache()
    assert diags == []
    assert audited == ["serve_engine[cache]"]

    diags, _ = jaxlint.audit_serve_cache(buckets=None)
    assert codes(diags) == ["RF205"]
    assert "cache key varies" in diags[0].message


def test_rf206_state_sized_collective_in_mesh_body():
    from jax.sharding import PartitionSpec as P

    from repro.analysis import jaxlint
    from repro.core.runtime_sharded import _shard_map

    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    nodes = jnp.zeros((1, 10, 4, 8), jnp.float32)   # (D, S_loc*n, 4, p)
    threshold = 10 * 4 * 8 * 4                       # full-width bytes

    # MUTATION: the "accidentally replicated" body — all_gather the
    # whole packed node state over the param axis before using it
    def bad(st):
        full = jax.lax.all_gather(st[0], "model", axis=2, tiled=True)
        return (full.sum(2) * 2.0)[None]

    spec = P("data", None, None, "model")
    cj = jax.make_jaxpr(_shard_map(
        bad, mesh, (spec,), P("data", None, None),
        ("data", "model")))(nodes)
    diags = jaxlint.audit_mesh_collectives(
        cj, subject="m", state_bytes_threshold=threshold)
    assert codes(diags) == ["RF206"]
    assert diags[0].data["primitive"] == "all_gather"

    # the designed flow — gather ONE of the four node slots (the mixed
    # iterates, threshold/4 bytes) — stays below the line
    def good(st):
        x = jax.lax.all_gather(st[0, :, 0], "model", axis=1, tiled=True)
        return (st * x.sum())

    cj = jax.make_jaxpr(_shard_map(
        good, mesh, (spec,), spec, ("data", "model")))(nodes)
    assert jaxlint.audit_mesh_collectives(
        cj, subject="m", state_bytes_threshold=threshold) == []

    # a state-sized psum is replication traffic too, all_gather or not
    def psum_bad(st):
        return st + jax.lax.psum(st, "model")

    cj = jax.make_jaxpr(_shard_map(
        psum_bad, mesh, (spec,), spec, ("data", "model")))(nodes)
    diags = jaxlint.audit_mesh_collectives(
        cj, subject="m", state_bytes_threshold=threshold)
    assert codes(diags) == ["RF206"]
    assert diags[0].data["primitive"] == "psum"


# ------------------------------------------------------------------ #
# wiring
# ------------------------------------------------------------------ #
def test_check_or_raise_wraps_diagnostics(wf_env):
    topo, _, comm, _, _ = wf_env
    we = np.array(comm.w_edge)
    we[0] += 0.25
    diags = planlint.lint_comm_plan(
        dataclasses.replace(comm, w_edge=we), topo)
    with pytest.raises(PlanInvariantError) as ei:
        planlint.check_or_raise(diags, "test")
    assert codes(ei.value.diagnostics) == ["RF105"]
    assert "RF105" in str(ei.value)
    planlint.check_or_raise([], "test")  # clean is a no-op


def test_engines_verify_plans_flag():
    """verify_plans=True on the real engines over real plans: no raise,
    same trajectory as the unverified run."""
    n, p = 5, 4
    rng = np.random.default_rng(0)
    C = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    gfn = lambda i, x, key: x - C[i]
    x0 = jnp.zeros((n, p), jnp.float32)
    topo = binary_tree(n)
    sched = get_scenario("uniform", n).realize(topo, 80, seed=0).schedule
    st_v, _ = run_rfast(topo, sched, gfn, x0, 1e-2, seed=0,
                        verify_plans=True)
    st_p, _ = run_rfast(topo, sched, gfn, x0, 1e-2, seed=0)
    np.testing.assert_array_equal(np.asarray(st_v.x), np.asarray(st_p.x))
    topos = [binary_tree(n), get_topology("line", n)]
    scheds = [get_scenario("uniform", n).realize(t, 80, seed=s).schedule
              for s, t in enumerate(topos)]
    run_sweep(topos, scheds, gfn, x0, 1e-2, seeds=[0, 1],
              verify_plans=True)


def test_builder_errors_name_the_builder(monkeypatch):
    import repro.core.topology as T

    orig = T._row_stochastic_from_in_edges

    def broken(n, in_edges):
        W = orig(n, in_edges)
        W[0] *= 2.0
        return W

    monkeypatch.setattr(T, "_row_stochastic_from_in_edges", broken)
    with pytest.raises(ValueError, match=r"'binary_tree' \(n=5\)"):
        T.binary_tree(5)


@pytest.mark.slow
def test_run_plan_matrix_quick_subset_clean():
    from repro.analysis.runner import run_plan_matrix
    diags, stats = run_plan_matrix(
        n=5, K=64, K_epochs=600, seeds=(0,),
        scenarios=("uniform", "churn"),
        topologies=("binary_tree", "robust_tree"))
    assert codes(diags) == [], [d.to_json() for d in diags]
    assert stats["wavefront_plans"] > 0 and stats["fleets"] > 0
    assert stats["epoch_traces"] > 0


@pytest.mark.slow
def test_audit_engines_clean():
    from repro.analysis import jaxlint
    diags, audited = jaxlint.audit_engines(n=5, p=8, K=48)
    assert codes(diags) == [], [d.to_json() for d in diags]
    assert len(audited) >= 8, audited
