"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rfast_update import dispatch
from repro.kernels.rfast_update.grid import block_pad_width, commit_grid
from repro.kernels.rfast_update.ops import rfast_commit, rfast_update
from repro.kernels.rfast_update.ref import rfast_commit_ref
from repro.kernels.ssm_scan.ops import selective_scan

RNG = np.random.default_rng(0)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


# ------------------------------------------------------------------ #
# rfast_update
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("P", [37, 1000, 32768, 100_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rfast_update_sweep(P, dtype):
    Kw, Ka, Ko = 2, 3, 2
    kw = dict(
        x=_arr(P, dtype), z=_arr(P, dtype), g_new=_arr(P, dtype),
        g_old=_arr(P, dtype), v_in=_arr((Kw, P), dtype),
        w_in=jnp.asarray([0.25, 0.25]), rho_in=_arr((Ka, P), dtype),
        rho_buf=_arr((Ka, P), dtype), mask=jnp.asarray([1.0, 0.0, 1.0]),
        rho_out=_arr((Ko, P), dtype), a_out=jnp.asarray([0.3, 0.2]),
        gamma=0.01, w_self=0.5, a_self=0.5)
    ref = rfast_update(**kw, impl="ref")
    # interpret=True pins the kernel-oracle path (the None default would
    # resolve to the jnp emulation off-TPU, making the check vacuous)
    pal = rfast_update(**kw, impl="pallas", interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    for r, p in zip(ref, pal):
        np.testing.assert_allclose(np.asarray(r, np.float32),
                                   np.asarray(p, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(P=st.integers(1, 5000), Kw=st.integers(1, 4), Ka=st.integers(1, 4),
       Ko=st.integers(1, 4), seed=st.integers(0, 100))
def test_rfast_update_property(P, Kw, Ka, Ko, seed):
    r = np.random.default_rng(seed)
    a = lambda *s: jnp.asarray(r.normal(0, 1, s), jnp.float32)
    kw = dict(x=a(P), z=a(P), g_new=a(P), g_old=a(P), v_in=a(Kw, P),
              w_in=jnp.asarray(r.uniform(0, .5, Kw), jnp.float32),
              rho_in=a(Ka, P), rho_buf=a(Ka, P),
              mask=jnp.asarray(r.integers(0, 2, Ka), jnp.float32),
              rho_out=a(Ko, P),
              a_out=jnp.asarray(r.uniform(0, .5, Ko), jnp.float32),
              gamma=float(r.uniform(0, .1)), w_self=0.5, a_self=0.5)
    ref = rfast_update(**kw, impl="ref")
    pal = rfast_update(**kw, impl="pallas", interpret=True)
    for x, y in zip(ref, pal):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# fleet-grid commit kernel + shape-specialized dispatch
# ------------------------------------------------------------------ #
def _grid_case(P, B=5, Ka=3, Ko=2, seed=0, dtype=jnp.float32):
    """Random flat sources + gather tables, and the per-lane ref answer."""
    r = np.random.default_rng(seed)
    a = lambda *s: jnp.asarray(r.normal(0, 1, s), dtype)
    Nz, Nri, Nr = B * 4, 40, 16
    src = dict(z_src=a(Nz, P), g_new=a(B, P), go_src=a(Nz, P),
               ri_src=a(Nri, P), rb_src=a(Nr, P), ro_src=a(Nr, P))
    idx = dict(
        idx_z=jnp.asarray(r.integers(0, Nz, B), jnp.int32),
        idx_g=jnp.asarray(r.integers(0, Nz, B), jnp.int32),
        idx_ri=jnp.asarray(r.integers(0, Nri, (B, Ka)), jnp.int32),
        idx_rb=jnp.asarray(r.integers(0, Nr, (B, Ka)), jnp.int32),
        idx_ro=jnp.asarray(r.integers(0, Nr, (B, Ko)), jnp.int32))
    par = dict(a_self=a(B), mask=jnp.asarray(r.integers(0, 2, (B, Ka)),
                                             jnp.float32), a_out=a(B, Ko))
    refs = []
    for b in range(B):
        refs.append(rfast_commit_ref(
            src["z_src"][idx["idx_z"][b]], src["g_new"][b],
            src["go_src"][idx["idx_g"][b]],
            src["ri_src"][np.array(idx["idx_ri"][b])],
            src["rb_src"][np.array(idx["idx_rb"][b])],
            par["mask"][b], src["ro_src"][np.array(idx["idx_ro"][b])],
            par["a_out"][b], a_self=par["a_self"][b]))
    return dict(**idx, **par, **src), refs


@pytest.mark.parametrize("P,modes", [
    (37, ("emulate",)),                    # ragged: emulate only
    (1000, ("emulate",)),
    (32768, ("interpret", "emulate")),     # one block: kernel oracle too
    (100_001, ("emulate",)),
])
def test_commit_grid_matches_ref(P, modes):
    kw, refs = _grid_case(P)
    for mode in modes:
        z_o, ro_o, rb_o = commit_grid(mode=mode, **kw)
        for b, (zr, ror, rbr) in enumerate(refs):
            for got, want in ((z_o[b], zr), (ro_o[b], ror), (rb_o[b], rbr)):
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=1e-5, atol=1e-5,
                                           err_msg=f"mode={mode} lane={b}")


def test_commit_grid_clamps_sentinel_rows():
    """Out-of-range (drop-sentinel) indices clamp instead of crashing; a
    zero mask makes the garbage reads inert in z'."""
    kw, refs = _grid_case(256)
    kw["idx_ri"] = jnp.full_like(kw["idx_ri"], 10_000)
    kw["mask"] = jnp.zeros_like(kw["mask"])
    z_o, _, _ = commit_grid(mode="emulate", **kw)
    # with mask=0 the recv term vanishes: z' = a_self*(z + gn - go)
    want = kw["a_self"][:, None] * (
        kw["z_src"][kw["idx_z"]] + kw["g_new"] - kw["go_src"][kw["idx_g"]])
    np.testing.assert_allclose(np.asarray(z_o), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_commit_grid_ragged_blocks_raise_in_kernel_modes():
    kw, _ = _grid_case(1000)
    with pytest.raises(ValueError, match="block_pad_width"):
        commit_grid(mode="interpret", **kw)
    assert block_pad_width(1000) == 32768
    assert block_pad_width(32768) == 32768
    assert block_pad_width(32769) == 2 * 32768


def test_commit_grid_rejects_unknown_mode():
    kw, _ = _grid_case(128)
    with pytest.raises(ValueError, match="mode"):
        commit_grid(mode="fast", **kw)


def test_dispatch_cache_counters_and_clear():
    dispatch.clear()
    assert dispatch.stats() == {"hits": 0, "misses": 0, "entries": 0}
    kw, _ = _grid_case(512)
    commit_grid(mode="emulate", **kw)
    s = dispatch.stats()
    assert s["misses"] == 1 and s["hits"] == 0 and s["entries"] == 1
    # identical signature -> cache hit, no new entry
    commit_grid(mode="emulate", **kw)
    s = dispatch.stats()
    assert s["misses"] == 1 and s["hits"] == 1 and s["entries"] == 1
    # different shape signature -> a second entry
    kw2, _ = _grid_case(640)
    commit_grid(mode="emulate", **kw2)
    s = dispatch.stats()
    assert s["misses"] == 2 and s["entries"] == 2
    dispatch.clear()
    assert dispatch.stats() == {"hits": 0, "misses": 0, "entries": 0}


def test_dispatch_resolve_mode():
    assert dispatch.resolve_mode(True) == "interpret"
    assert dispatch.resolve_mode(False) == "compiled"
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.resolve_mode(None) == ("compiled" if on_tpu
                                           else "emulate")


@pytest.mark.parametrize("P", [37, 1000, 100_001])
def test_rfast_commit_pallas_default_routes_grid(P):
    """rfast_commit(impl='pallas') with the autodetected mode matches the
    ref on ragged widths (the B=1 grid path, no block padding on CPU)."""
    r = np.random.default_rng(3)
    a = lambda *s: jnp.asarray(r.normal(0, 1, s), jnp.float32)
    Ka, Ko = 3, 2
    kw = dict(z=a(P), g_new=a(P), g_old=a(P), rho_in=a(Ka, P),
              rho_buf=a(Ka, P),
              mask=jnp.asarray([1.0, 0.0, 1.0]), rho_out=a(Ko, P),
              a_out=jnp.asarray([0.3, 0.2]), a_self=0.5)
    ref = rfast_commit(**kw, impl="ref")
    pal = rfast_commit(**kw, impl="pallas")
    orc = rfast_commit(**kw, impl="pallas", interpret=True)
    for want, got, got2 in zip(ref, pal, orc):
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(want), np.asarray(got2),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 512, 4, 1, 128),    # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, D, causal, window, dtype):
    q, k, v = _arr((B, S, H, D), dtype), _arr((B, S, KV, D), dtype), \
        _arr((B, S, KV, D), dtype)
    r = flash_attention(q, k, v, causal=causal, window=window, impl="ref")
    p = flash_attention(q, k, v, causal=causal, window=window, impl="pallas",
                        bq=128, bk=128)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(r, np.float32),
                               np.asarray(p, np.float32), rtol=tol, atol=tol)


@pytest.mark.slow
def test_flash_attention_block_sizes():
    q, k, v = _arr((1, 256, 2, 64)), _arr((1, 256, 2, 64)), _arr((1, 256, 2, 64))
    r = flash_attention(q, k, v, impl="ref")
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        p = flash_attention(q, k, v, impl="pallas", bq=bq, bk=bk)
        np.testing.assert_allclose(np.asarray(r), np.asarray(p),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ #
# ssm scan
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("B,S,di,N,chunk,bd", [
    (1, 64, 16, 8, 16, 16),
    (2, 128, 64, 16, 32, 32),
    (1, 256, 32, 16, 256, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(B, S, di, N, chunk, bd, dtype):
    u = _arr((B, S, di), dtype)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, S, di)), dtype)
    A = -jnp.asarray(RNG.uniform(0.5, 2, (di, N)), jnp.float32)
    Bc, Cc = _arr((B, S, N), dtype), _arr((B, S, N), dtype)
    D = _arr((di,))
    yr, hr = selective_scan(u, dt, A, Bc, Cc, D, impl="ref")
    yp, hp = selective_scan(u, dt, A, Bc, Cc, D, impl="pallas",
                            chunk=chunk, bd=bd)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yp), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(hp), rtol=tol,
                               atol=tol)


def test_ssm_scan_chunking_invariance():
    """Chunk size must not change the result (carry correctness)."""
    B, S, di, N = 1, 128, 16, 8
    u = _arr((B, S, di))
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, S, di)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2, (di, N)), jnp.float32)
    Bc, Cc, D = _arr((B, S, N)), _arr((B, S, N)), _arr((di,))
    outs = [selective_scan(u, dt, A, Bc, Cc, D, impl="pallas", chunk=c,
                           bd=16)[0] for c in (8, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# flash attention backward (custom VJP with Pallas dq/dkv kernels)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
@pytest.mark.parametrize("B,H,S,D", [(1, 2, 128, 32), (2, 2, 256, 64)])
@pytest.mark.slow
def test_flash_attention_backward(B, H, S, D, causal, window):
    from repro.kernels.flash_attention.backward import flash_attention_vjp
    from repro.kernels.flash_attention.ref import attention_ref
    rng = np.random.default_rng(1)
    mk = lambda: jnp.asarray(rng.normal(0, 1, (B, H, S, D)), jnp.float32)
    q, k, v, w = mk(), mk(), mk(), mk()

    def f_flash(q_, k_, v_):
        return jnp.sum(flash_attention_vjp(
            q_, k_, v_, causal, window, None, 64, 64, True) * w)

    def f_ref(q_, k_, v_):
        o = attention_ref(q_.transpose(0, 2, 1, 3),
                          k_.transpose(0, 2, 1, 3),
                          v_.transpose(0, 2, 1, 3),
                          causal=causal, window=window)
        return jnp.sum(o.transpose(0, 2, 1, 3) * w)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
