"""Wavefront-batched simulator vs the event-serial oracle, and the
commit-only fused kernel vs the full kernel.

The wavefront engine (delta histories + host-resolved stale reads +
vmapped lanes) must realize Algorithm 2's exact semantics: final states
equal to the one-event-per-step snapshot engine to fp32 tolerance on
randomized schedules with stragglers, packet loss, and crash windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (binary_tree, directed_ring, exponential,
                        get_topology, generate_schedule, run_rfast,
                        tracked_mass)
from repro.core.plan import build_comm_plan
from repro.core.schedule import build_wavefront_plan
from repro.kernels.rfast_update.ops import rfast_update

jax.config.update("jax_enable_x64", False)


def quad_grad_fn(n: int, p: int, *, noise: float = 0.1, seed: int = 0):
    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)
    S = jnp.asarray(rng.uniform(0.5, 2.0, (n, 1)), jnp.float32)

    def gfn(i, x, key):
        g = S[i] * (x - C[i])
        if noise > 0:
            g = g + noise * jax.random.normal(key, x.shape)
        return g

    return gfn


# randomized-schedule matrix: stragglers, loss, crash windows, big fanout
SCENARIOS = [
    pytest.param(dict(builder=binary_tree, n=7, loss=0.0, compute=None,
                      failures=None, latency=0.3, seed=0), id="uniform"),
    pytest.param(dict(builder=directed_ring, n=5, loss=0.3, compute=None,
                      failures=None, latency=0.7, seed=1), id="loss"),
    pytest.param(dict(builder=binary_tree, n=7, loss=0.0,
                      compute=[1.0] * 6 + [4.0], failures=None,
                      latency=0.5, seed=2), id="straggler"),
    pytest.param(dict(builder=exponential, n=8, loss=0.15,
                      compute=[1.0] * 7 + [3.0],
                      failures=[(2, 30.0, 90.0)], latency=0.6, seed=3),
                 id="loss+straggler+crash"),
]


@pytest.mark.slow
@pytest.mark.parametrize("sc", SCENARIOS)
def test_wavefront_matches_event_serial(sc):
    n, p, K = sc["n"], 6, 600
    topo = sc["builder"](n)
    gfn = quad_grad_fn(n, p)
    sched = generate_schedule(topo, K, loss_prob=sc["loss"],
                              latency=sc["latency"],
                              compute_time=sc["compute"],
                              failures=sc["failures"], seed=sc["seed"])
    x0 = jnp.zeros((n, p), jnp.float32)
    # eval chunking exercises the wave-padding path in both modes
    s_ev, _ = run_rfast(topo, sched, gfn, x0, 0.02, mode="event",
                        eval_every=150)
    s_wf, _ = run_rfast(topo, sched, gfn, x0, 0.02, mode="wavefront",
                        eval_every=150)
    assert int(s_wf.k) == int(s_ev.k) == K
    for f in ("x", "v", "z", "g_prev", "rho", "rho_buf"):
        np.testing.assert_allclose(
            np.asarray(getattr(s_wf, f)), np.asarray(getattr(s_ev, f)),
            rtol=2e-5, atol=2e-5, err_msg=f)
    # Lemma 3 holds on the wavefront state too
    np.testing.assert_allclose(
        np.asarray(tracked_mass(s_wf)),
        np.asarray(s_wf.g_prev.sum(axis=0)), rtol=1e-4, atol=1e-4)


def test_wavefront_plan_invariants():
    """Waves cover every event exactly once, in order; agents are distinct
    within a wave; every consumed stamp predates its wave's start."""
    n, K = 7, 800
    topo = binary_tree(n)
    sched = generate_schedule(topo, K, loss_prob=0.1, latency=0.8, seed=5)
    plan = build_comm_plan(topo)
    wf = build_wavefront_plan(sched, plan, int(sched.D) + 2,
                              break_every=250)
    assert wf.sizes.sum() == K
    covered = []
    for w in range(wf.n_waves):
        size = int(wf.sizes[w])
        lanes = wf.kidx[w, :size]
        covered.extend(lanes.tolist())
        agents = wf.agent[w, :size]
        assert len(set(agents.tolist())) == size, "duplicate agent in wave"
        # padding lanes carry the sentinel agent
        assert np.all(wf.agent[w, size:] == n)
        start = int(wf.event_start[w])
        # waves never span a forced (eval) boundary
        assert start // 250 == (start + size - 1) // 250
        for k in lanes:
            a = int(sched.agent[k])
            for e in range(plan.n_edges_w):
                if plan.dst_w[e] == a and plan.w_edge[e] != 0:
                    assert sched.stamp_v[k, e] <= start
            for e in range(plan.n_edges_a):
                if plan.dst_a[e] == a:
                    assert sched.stamp_rho[k, e] <= start
    assert covered == list(range(K)), "events must be covered in order"
    # forced breaks at eval boundaries
    for b in range(250, K, 250):
        assert b in set(wf.event_start.tolist())


def test_wavefront_deterministic_round_robin():
    """Round-robin (Remark 2) compiles to full-width waves and still
    matches the oracle."""
    from repro.core import round_robin_schedule
    n, p = 5, 4
    topo = directed_ring(n)
    gfn = quad_grad_fn(n, p, noise=0.0)
    sched = round_robin_schedule(topo, 10)
    x0 = jnp.asarray(np.random.default_rng(0).normal(0, 1, (n, p)),
                     jnp.float32)
    s_ev, _ = run_rfast(topo, sched, gfn, x0, 0.05, mode="event")
    s_wf, _ = run_rfast(topo, sched, gfn, x0, 0.05, mode="wavefront")
    np.testing.assert_allclose(np.asarray(s_wf.x), np.asarray(s_ev.x),
                               rtol=2e-5, atol=2e-5)


def test_wavefront_pallas_commit_matches_jnp():
    """impl="pallas" (lanes committed through the fused rfast_commit
    kernel on the flat buffer) realizes the same trajectory as the jnp
    scatter path and the event oracle."""
    n, p, K = 7, 6, 250
    topo = binary_tree(n)
    gfn = quad_grad_fn(n, p)
    sched = generate_schedule(topo, K, loss_prob=0.15, latency=0.5, seed=4)
    x0 = jnp.zeros((n, p), jnp.float32)
    s_j, _ = run_rfast(topo, sched, gfn, x0, 0.02, mode="wavefront",
                       eval_every=100)
    s_p, _ = run_rfast(topo, sched, gfn, x0, 0.02, mode="wavefront",
                       eval_every=100, impl="pallas")
    s_e, _ = run_rfast(topo, sched, gfn, x0, 0.02, mode="event")
    for f in ("x", "v", "z", "g_prev", "rho", "rho_buf"):
        np.testing.assert_allclose(
            np.asarray(getattr(s_p, f)), np.asarray(getattr(s_j, f)),
            rtol=2e-5, atol=2e-5, err_msg=f"pallas vs jnp: {f}")
        np.testing.assert_allclose(
            np.asarray(getattr(s_p, f)), np.asarray(getattr(s_e, f)),
            rtol=2e-5, atol=2e-5, err_msg=f"pallas vs event: {f}")
    # the event oracle rejects the kernel backend explicitly
    with pytest.raises(ValueError):
        run_rfast(topo, sched, gfn, x0, 0.02, mode="event", impl="pallas")


# ------------------------------------------------------------------ #
# commit-only kernel vs full kernel
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("P,Kw,Ka,Ko", [(37, 1, 2, 3), (1000, 2, 3, 1),
                                        (32768, 3, 1, 2)])
def test_commit_matches_full_kernel(impl, P, Kw, Ka, Ko):
    r = np.random.default_rng(P + Kw)
    a = lambda *s: jnp.asarray(r.normal(0, 1, s), jnp.float32)
    kw = dict(x=a(P), z=a(P), g_new=a(P), g_old=a(P), v_in=a(Kw, P),
              w_in=jnp.asarray(r.uniform(0, .5, Kw), jnp.float32),
              rho_in=a(Ka, P), rho_buf=a(Ka, P),
              mask=jnp.asarray(r.integers(0, 2, Ka), jnp.float32),
              rho_out=a(Ko, P),
              a_out=jnp.asarray(r.uniform(0, .5, Ko), jnp.float32),
              gamma=0.02, w_self=0.5, a_self=0.4)
    full = rfast_update(**kw, impl=impl)
    commit = rfast_update(**kw, impl=impl, outputs="commit")
    assert len(commit) == 3
    # commit returns (z', rho_out', rho_buf') == full[2:]
    for c, f in zip(commit, full[2:]):
        np.testing.assert_allclose(np.asarray(c), np.asarray(f),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_commit_kernel_protocol_round_random_topologies():
    """The pallas protocol round (now commit-only) still matches the jnp
    backend on random topologies under random loss masks."""
    from repro.core.runtime import init_node_state, make_rfast_round
    rng = np.random.default_rng(0)
    for name, n in [("exponential", 8), ("mesh2d", 9), ("binary_tree", 7)]:
        topo = get_topology(name, n)
        plan = build_comm_plan(topo)
        p = 40
        C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)

        def grad_fn(params, batch, key):
            del key
            d = params["w"] - batch
            return 0.5 * jnp.sum(d * d), {"w": d}

        params = {"w": jnp.zeros((p,), jnp.float32)}
        key = jax.random.PRNGKey(1)
        keys = jax.random.split(key, n)
        masks = jnp.asarray(rng.uniform(size=plan.e_pad) > 0.4, jnp.float32)
        outs = {}
        for impl in ("jnp", "pallas"):
            state = init_node_state(plan, params, grad_fn, C, key,
                                    robust=True)
            rf = jax.jit(make_rfast_round(plan, grad_fn, gamma=0.01,
                                          robust=True, impl=impl))
            for step in range(3):
                state, _ = rf(state, C, keys, masks)
            outs[impl] = state
        for f in ("x", "z", "g_prev", "rho", "rho_buf"):
            np.testing.assert_allclose(
                np.asarray(getattr(outs["jnp"], f)["w"]),
                np.asarray(getattr(outs["pallas"], f)["w"]),
                rtol=1e-4, atol=1e-4, err_msg=f"{name}:{f}")


def test_donated_round_updates_in_place_semantics():
    """donate=True rounds must produce the same trajectory as undonated
    ones (state rebound every step, old buffers never reused)."""
    from repro.core.runtime import init_node_state, make_rfast_round
    n, p = 5, 16
    topo = directed_ring(n)
    plan = build_comm_plan(topo)
    rng = np.random.default_rng(2)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)

    def grad_fn(params, batch, key):
        del key
        d = params["w"] - batch
        return 0.5 * jnp.sum(d * d), {"w": d}

    params = {"w": jnp.zeros((p,), jnp.float32)}
    key = jax.random.PRNGKey(3)
    keys = jax.random.split(key, n)
    finals = {}
    for donate in (False, True):
        state = init_node_state(plan, params, grad_fn, C, key)
        rf = make_rfast_round(plan, grad_fn, gamma=0.05, donate=donate)
        if not donate:
            rf = jax.jit(rf)
        for _ in range(4):
            state, _ = rf(state, C, keys, None)
        finals[donate] = np.asarray(state.x["w"])
    np.testing.assert_allclose(finals[False], finals[True],
                               rtol=1e-6, atol=1e-6)
