"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    TOPOLOGIES, get_topology, generate_schedule, round_robin_schedule,
    run_rfast, tracked_mass,
)

TOPO_NAMES = sorted(set(TOPOLOGIES) - {"parameter_server"})


@settings(max_examples=20, deadline=None)
@given(
    name=st.sampled_from(sorted(TOPOLOGIES)),
    n=st.integers(min_value=2, max_value=16),
)
def test_builders_always_satisfy_assumptions(name, n):
    topo = get_topology(name, n)   # __post_init__ validates Assumptions 1-2
    assert topo.roots()
    # all nonzero weights bounded below (Assumption 1i second clause)
    for M in (topo.W, topo.A):
        nz = M[M > 0]
        assert nz.min() >= 1.0 / (2 * n)


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(TOPO_NAMES),
    n=st.integers(min_value=3, max_value=9),
    loss=st.floats(min_value=0.0, max_value=0.6),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_schedule_is_valid_assumption_3(name, n, loss, seed):
    topo = get_topology(name, n)
    K = 40 * n
    sched = generate_schedule(topo, K, loss_prob=loss, latency=1.0, seed=seed)
    # (i) every node activates infinitely often with bounded gaps
    assert sched.T >= n
    assert set(np.unique(sched.agent)) == set(range(n))
    # (ii) bounded delays AT CONSUMPTION (edges into the active node);
    # stamps never exceed the current iteration
    dst_w = np.array([i for _, i in topo.edges_W()] or [0])
    dst_a = np.array([i for _, i in topo.edges_A()] or [0])
    for k in range(K):
        assert np.all(sched.stamp_v[k] <= k)
        assert np.all(sched.stamp_rho[k] <= k)
        a = sched.agent[k]
        assert np.all((k - sched.stamp_v[k])[dst_w == a] <= sched.D)
        assert np.all((k - sched.stamp_rho[k])[dst_a == a] <= sched.D)
    # monotone per-edge stamps (largest-received semantics)
    assert np.all(np.diff(sched.stamp_v, axis=0) >= 0)
    assert np.all(np.diff(sched.stamp_rho, axis=0) >= 0)
    # virtual time strictly progresses on each node's own clock
    for i in range(n):
        ti = sched.times[sched.agent == i]
        assert np.all(np.diff(ti) > 0)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(TOPO_NAMES),
    n=st.integers(min_value=3, max_value=8),
    loss=st.floats(min_value=0.0, max_value=0.5),
    noise=st.floats(min_value=0.0, max_value=0.5),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_mass_conservation_lemma3(name, n, loss, noise, seed):
    """Lemma 3 holds for ANY topology/schedule/loss/noise combination."""
    import jax

    topo = get_topology(name, n)
    p, K = 4, 25 * n
    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)

    def gfn(i, x, key):
        g = x - C[i]
        return g + noise * jax.random.normal(key, x.shape) if noise else g

    sched = generate_schedule(topo, K, loss_prob=loss, latency=1.5,
                              compute_time=rng.uniform(0.5, 3.0, n),
                              seed=seed)
    state, _ = run_rfast(topo, sched, gfn, jnp.zeros((n, p)), gamma=0.01,
                         seed=seed)
    np.testing.assert_allclose(
        np.asarray(tracked_mass(state)),
        np.asarray(state.g_prev.sum(axis=0)),
        rtol=2e-4, atol=2e-4,
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=2, max_value=12),
       rounds=st.integers(min_value=1, max_value=5))
def test_round_robin_delay_bound(n, rounds):
    """Remark 2: synchronous schedule has D <= 2n - 2 and T = n."""
    topo = get_topology("directed_ring", n)
    sched = round_robin_schedule(topo, rounds)
    assert sched.T == n
    assert sched.D <= 2 * n - 2
    for k in range(sched.K):
        assert np.all(sched.stamp_v[k] <= k)
