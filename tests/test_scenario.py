"""The unified virtual-time engine: golden compat, clock properties,
loss/straggler/crash models, and the named-scenario registry."""
import numpy as np
import pytest

from repro.core import (
    GilbertElliott, NetworkScenario, SCENARIOS, binary_tree, directed_ring,
    exponential, generate_schedule, get_scenario, undirected_ring,
)


# ------------------------------------------------------------------ #
# golden: the compat shim reproduces the pre-refactor generator
# bit-for-bit (same seed -> identical Schedule arrays)
# ------------------------------------------------------------------ #
def _pre_refactor_generate_schedule(topo, K, *, compute_time=None,
                                    jitter=0.2, latency=0.1, loss_prob=0.0,
                                    D_max=None, seed=0, failures=None):
    """Verbatim copy of ``schedule.generate_schedule`` as of PR 2 (the
    last pre-scenario revision) — the golden oracle.  Returns the raw
    arrays (agent, stamp_v, stamp_rho, times, max_delay)."""
    rng = np.random.default_rng(seed)
    n = topo.n
    if compute_time is None:
        compute_time = np.ones(n)
    compute_time = np.asarray(compute_time, dtype=np.float64)
    if D_max is None:
        D_max = 4 * n + 16

    edges_w = topo.edges_W()
    edges_a = topo.edges_A()
    out_w = {i: [] for i in range(n)}
    out_a = {i: [] for i in range(n)}
    in_w = {i: [] for i in range(n)}
    in_a = {i: [] for i in range(n)}
    for e, (j, i) in enumerate(edges_w):
        out_w[j].append(e)
        in_w[i].append(e)
    for e, (j, i) in enumerate(edges_a):
        out_a[j].append(e)
        in_a[i].append(e)

    arrivals_w = [[] for _ in edges_w]
    arrivals_a = [[] for _ in edges_a]
    best_w = np.zeros(len(edges_w), dtype=np.int64)
    best_a = np.zeros(len(edges_a), dtype=np.int64)

    clocks = rng.uniform(0.0, 1.0, n) * compute_time
    for (fn_, t0_, t1_) in (failures or []):
        if clocks[fn_] >= t0_:
            clocks[fn_] = max(clocks[fn_], t1_)
    agent = np.zeros(K, dtype=np.int32)
    stamp_v = np.zeros((K, max(1, len(edges_w))), dtype=np.int32)
    stamp_rho = np.zeros((K, max(1, len(edges_a))), dtype=np.int32)
    times = np.zeros(K, dtype=np.float64)
    max_delay = 0

    for k in range(K):
        a = int(np.argmin(clocks))
        now = float(clocks[a])
        agent[k] = a
        times[k] = now

        for e in in_w[a]:
            q = arrivals_w[e]
            keep = []
            for (t_arr, s) in q:
                if t_arr <= now:
                    if s > best_w[e]:
                        best_w[e] = s
                else:
                    keep.append((t_arr, s))
            arrivals_w[e][:] = keep
            if k - best_w[e] > D_max:
                best_w[e] = k - D_max
        for e in in_a[a]:
            q = arrivals_a[e]
            keep = []
            for (t_arr, s) in q:
                if t_arr <= now:
                    if s > best_a[e]:
                        best_a[e] = s
                else:
                    keep.append((t_arr, s))
            arrivals_a[e][:] = keep
            if k - best_a[e] > D_max:
                best_a[e] = k - D_max

        stamp_v[k] = best_w if len(edges_w) else 0
        stamp_rho[k] = best_a if len(edges_a) else 0
        for e in in_w[a]:
            max_delay = max(max_delay, k - int(best_w[e]))
        for e in in_a[a]:
            max_delay = max(max_delay, k - int(best_a[e]))

        for e in out_w[a]:
            if rng.uniform() >= loss_prob:
                arrivals_w[e].append((now + rng.exponential(latency), k + 1))
        for e in out_a[a]:
            if rng.uniform() >= loss_prob:
                arrivals_a[e].append((now + rng.exponential(latency), k + 1))

        clocks[a] = now + compute_time[a] * (1.0 + rng.uniform(-jitter, jitter))
        for (fn_, t0_, t1_) in (failures or []):
            if fn_ == a and t0_ <= clocks[a] < t1_:
                clocks[a] = t1_

    return agent, stamp_v, stamp_rho, times, max(1, max_delay)


GOLDEN_CASES = [
    ("plain", binary_tree(7), 500, {}),
    ("lossy", directed_ring(5), 400,
     dict(seed=3, loss_prob=0.3, latency=0.5)),
    ("straggler", exponential(8), 600,
     dict(seed=7, compute_time=[1, 1, 1, 4, 1, 1, 1, 1], jitter=0.35)),
    ("crash", binary_tree(7), 800,
     dict(seed=11, loss_prob=0.1, failures=[(2, 50.0, 90.0)], D_max=40)),
]


@pytest.mark.parametrize("name,topo,K,kw",
                         GOLDEN_CASES, ids=[c[0] for c in GOLDEN_CASES])
def test_compat_shim_matches_pre_refactor_bit_for_bit(name, topo, K, kw):
    agent, stamp_v, stamp_rho, times, D = _pre_refactor_generate_schedule(
        topo, K, **kw)
    sched = generate_schedule(topo, K, **kw)
    np.testing.assert_array_equal(sched.agent, agent)
    np.testing.assert_array_equal(sched.stamp_v, stamp_v)
    np.testing.assert_array_equal(sched.stamp_rho, stamp_rho)
    np.testing.assert_array_equal(sched.times, times)   # exact, not approx
    assert sched.D == D


def test_shim_scenario_kwarg_equals_direct_realize():
    topo = binary_tree(7)
    sc = NetworkScenario(latency=0.4, loss=0.2)
    a = generate_schedule(topo, 300, scenario=sc, seed=5)
    b = sc.realize(topo, 300, seed=5).schedule
    np.testing.assert_array_equal(a.agent, b.agent)
    np.testing.assert_array_equal(a.times, b.times)
    with pytest.raises(ValueError):
        generate_schedule(topo, 10, scenario=sc, loss_prob=0.5)


# ------------------------------------------------------------------ #
# clock properties: strictly increasing, straggler-monotone
# ------------------------------------------------------------------ #
def test_event_and_sync_clocks_strictly_increasing():
    topo = binary_tree(7)
    sc = get_scenario("straggler", 7)
    sched = sc.realize(topo, 2000, seed=0).schedule
    assert np.all(np.diff(sched.times) > 0)
    times = sc.sync_round_times(topo, 200, seed=0)
    assert np.all(np.diff(times) > 0) and times[0] > 0


def test_straggler_monotone_under_shared_scenario():
    """Slowing one node can only slow the clocks: the sync barrier is
    pointwise later (same seed, same draw structure), the event clock's
    horizon stretches, and the straggler wakes less often."""
    n, topo = 8, binary_tree(8)
    uni = get_scenario("uniform", n)
    strag = get_scenario("straggler", n)   # last node 4x slow

    t_uni = uni.sync_round_times(topo, 150, seed=0)
    t_str = strag.sync_round_times(topo, 150, seed=0)
    assert np.all(t_str >= t_uni)

    s_uni = uni.realize(topo, 3000, seed=0).schedule
    s_str = strag.realize(topo, 3000, seed=0).schedule
    assert s_str.times[-1] > s_uni.times[-1]
    counts = np.bincount(s_str.agent, minlength=n)
    assert counts[-1] < counts[:-1].min()   # the straggler wakes least


def test_time_varying_straggler_windows():
    """flaky_straggler: the last node is 6x slow only inside its windows —
    its wake rate collapses there and recovers outside."""
    n = 6
    sc = get_scenario("flaky_straggler", n)
    sched = sc.realize(binary_tree(n), 4000, seed=1).schedule
    t, a = sched.times, sched.agent
    in_win = ((t >= 100) & (t < 300)) | ((t >= 600) & (t < 800))
    # windows cover enough of the horizon to measure
    assert in_win.sum() > 200 and (~in_win).sum() > 200
    rate_in = (a[in_win] == n - 1).mean()
    rate_out = (a[~in_win] == n - 1).mean()
    assert rate_in < 0.5 * rate_out, (rate_in, rate_out)


# ------------------------------------------------------------------ #
# loss and crash models
# ------------------------------------------------------------------ #
def test_gilbert_elliott_bursty_loss():
    """Same ~20% stationary loss as Bernoulli, but concentrated in
    bursts: long loss runs exist that Bernoulli essentially never has."""
    def longest_loss_run(ok):
        worst = run = 0
        for v in ok:
            run = 0 if v else run + 1
            worst = max(worst, run)
        return worst

    topo = directed_ring(2)   # one A-edge per node: per-edge streams
    ge = NetworkScenario(gilbert_elliott=GilbertElliott(p_gb=0.025, p_bg=0.1))
    be = NetworkScenario(loss=0.2)
    K = 4000
    tr_ge = ge.realize(topo, K, seed=2)
    tr_be = be.realize(topo, K, seed=2)

    # per-edge outcome stream = rows where that edge's sender was active
    def edge_stream(tr, e, src):
        rows = tr.schedule.agent == src
        return tr.send_ok_a[rows, e]

    src_of = [j for (j, i) in topo.edges_A()]
    loss_ge = 1 - np.concatenate(
        [edge_stream(tr_ge, e, s) for e, s in enumerate(src_of)]).mean()
    assert 0.1 < loss_ge < 0.35, loss_ge   # near the stationary 20%
    burst_ge = max(longest_loss_run(edge_stream(tr_ge, e, s))
                   for e, s in enumerate(src_of))
    burst_be = max(longest_loss_run(edge_stream(tr_be, e, s))
                   for e, s in enumerate(src_of))
    assert burst_ge >= 10           # mean burst length 1/p_bg = 10
    assert burst_be <= 8            # P(run of 9 at p=.2) ~ 1e-6 per start


def test_crash_window_silences_node_on_both_clocks():
    n = 7
    sc = NetworkScenario(latency=0.3, failures=((3, 40.0, 120.0),))
    sched = sc.realize(binary_tree(n), 3000, seed=4).schedule
    t = sched.times[sched.agent == 3]
    assert not np.any((t > 41.0) & (t < 119.0))
    # the barrier stalls: no sync round completes inside the window
    times = sc.sync_round_times(binary_tree(n), 100, seed=4)
    assert not np.any((times > 41.0) & (times < 119.0))
    # but rounds resume after recovery
    assert np.any(times > 120.0)


def test_per_edge_latency_override_slows_that_edge():
    topo = binary_tree(7)
    e = topo.edges_W().index((0, 1))
    slow = NetworkScenario(edge_latency={(0, 1): 9.0}, latency=0.1)
    base = NetworkScenario(latency=0.1)
    k = np.arange(400)
    stale_slow = (k - slow.realize(topo, 400, seed=0)
                  .schedule.stamp_v[:, e]).mean()
    stale_base = (k - base.realize(topo, 400, seed=0)
                  .schedule.stamp_v[:, e]).mean()
    assert stale_slow > 1.5 * stale_base, (stale_slow, stale_base)


def test_send_outcomes_only_for_active_agent():
    sc = NetworkScenario(loss=0.3)
    tr = sc.realize(binary_tree(7), 500, seed=0)
    out_w = {i: [] for i in range(7)}
    for e, (j, _i) in enumerate(binary_tree(7).edges_W()):
        out_w[j].append(e)
    for k in range(500):
        a = int(tr.schedule.agent[k])
        ok_edges = np.nonzero(tr.send_ok_w[k])[0]
        assert all(e in out_w[a] for e in ok_edges)


# ------------------------------------------------------------------ #
# registry
# ------------------------------------------------------------------ #
def test_named_scenarios_realize_everywhere():
    for name in SCENARIOS:
        sc = get_scenario(name, 6)
        assert sc.name == name
        tr = sc.realize(undirected_ring(6), 200, seed=0)
        assert tr.schedule.K == 200
        assert np.all(np.diff(tr.schedule.times) > 0)
        times = sc.sync_round_times(undirected_ring(6), 20, seed=0)
        assert np.all(np.diff(times) > 0)
    with pytest.raises(KeyError):
        get_scenario("nope", 4)


# ------------------------------------------------------------------ #
# dynamic membership: epoch timelines (PR 7)
# ------------------------------------------------------------------ #
def test_get_scenario_error_lists_names():
    with pytest.raises(KeyError) as ei:
        get_scenario("definitely-not-a-scenario", 4)
    msg = str(ei.value)
    for name in SCENARIOS:
        assert name in msg


def test_every_scenario_realizes_with_common_root_at_7():
    """Registry-wide fast-tier validation: every SCENARIOS entry (a)
    realizes a frozen trace, (b) realizes an epoch timeline, and (c)
    every epoch's topology satisfies Assumption 2 on its survivors."""
    from repro.core import robust_tree
    topo = robust_tree(7)
    for name in SCENARIOS:
        sc = get_scenario(name, 7)
        tr = sc.realize(topo, 300, seed=0)
        assert tr.schedule.K == 300, name
        et = sc.realize_epochs(topo, 300, seed=0)
        assert sum(ep.K for ep in et.epochs) == 300, name
        for ep in et.epochs:
            assert ep.topology.common_roots, (name, ep.t0)
            assert ep.root in ep.topology.common_roots


def test_static_scenario_epochs_bit_identical_to_realize():
    sc = get_scenario("straggler", 7)
    topo = binary_tree(7)
    tr = sc.realize(topo, 400, seed=5)
    et = sc.realize_epochs(topo, 400, seed=5)
    assert len(et.epochs) == 1 and not et.dynamic
    ep = et.epochs[0]
    assert ep.topology is topo          # no renormalization noise
    for f in ("agent", "stamp_v", "stamp_rho", "times"):
        np.testing.assert_array_equal(getattr(ep.trace.schedule, f),
                                      getattr(tr.schedule, f), err_msg=f)
    np.testing.assert_array_equal(ep.trace.send_ok_w, tr.send_ok_w)
    np.testing.assert_array_equal(ep.trace.send_ok_a, tr.send_ok_a)


def test_root_failover_timeline_re_elects():
    from repro.core import robust_tree
    sc = get_scenario("root_failover", 8)
    et = sc.realize_epochs(robust_tree(8), 1200, seed=1)
    assert len(et.epochs) == 2 and et.dynamic
    e0, e1 = et.epochs
    assert e0.root == 0 and not e0.departed.any()
    assert e1.root != 0 and e1.departed[0]
    assert not e1.topology.active_mask()[0]
    assert e1.k0 == e0.K and e0.k0 == 0
    assert e1.t0 == 30.0
    # global virtual time keeps increasing across the boundary
    assert float(e1.trace.schedule.times[0]) > 0.0


def test_churn_timeline_three_epochs():
    from repro.core import robust_tree
    sc = get_scenario("churn", 7)
    et = sc.realize_epochs(robust_tree(7), 1400, seed=0)
    assert len(et.epochs) == 3
    e0, e1, e2 = et.epochs
    # epoch 0 runs without the late joiner, epoch 1 has everyone,
    # epoch 2 lost the leaver
    assert not e0.topology.active_mask().all()
    assert e1.topology.active_mask().all()
    assert e1.joined.any() and e2.departed.any()
    assert sum(ep.K for ep in et.epochs) == 1400
    # joins/leaves never fire inside an epoch's own schedule: every
    # epoch's agents are members of its topology
    for ep in et.epochs:
        act = ep.topology.active_mask()
        assert act[ep.trace.schedule.agent].all()


def test_membership_degrades_to_crash_windows_when_frozen():
    """realize() on a dynamic scenario must stay runnable: a leaver
    goes permanently silent, a joiner is silent before its join."""
    from repro.core import robust_tree
    sc = get_scenario("churn", 7)
    tr = sc.realize(robust_tree(7), 1400, seed=0)
    agents = np.asarray(tr.schedule.agent)
    times = np.asarray(tr.schedule.times)
    joiner, leaver = 5, 6
    assert not np.any(times[agents == joiner] < 40.0)
    assert not np.any(times[agents == leaver] > 90.0)


def test_everyone_leaves_raises():
    sc = NetworkScenario(leaves=tuple((i, 1.0) for i in range(4)),
                         name="doom")
    with pytest.raises(ValueError):
        sc.realize(binary_tree(4), 4000, seed=0)


def test_regional_failure_draw_is_correlated():
    """One Bernoulli draw fells the whole rack: within a realized trace
    the rack members are either all silent in the window or all alive."""
    sc = get_scenario("regional_failure", 7)
    rack = sc.regional_failures[1][0]          # the p=0.5 window
    t0, t1 = sc.regional_failures[1][1], sc.regional_failures[1][2]
    fired = notfired = 0
    for seed in range(8):
        tr = sc.realize(undirected_ring(7), 2500, seed=seed)
        agents = np.asarray(tr.schedule.agent)
        times = np.asarray(tr.schedule.times)
        inwin = (times >= t0) & (times < t1)
        silent = [not np.any(inwin & (agents == i)) for i in rack]
        assert all(silent) or not any(silent), (seed, silent)
        fired += all(silent); notfired += not any(silent)
    assert fired and notfired, "p=0.5 window should fire sometimes"
