"""Topology + weight matrix tests (Assumptions 1-2)."""
import numpy as np
import pytest

from repro.core import topology as T


ALL_BUILDERS = ["binary_tree", "line", "directed_ring", "undirected_ring",
                "exponential", "mesh2d", "parameter_server"]


@pytest.mark.parametrize("name", ALL_BUILDERS)
@pytest.mark.parametrize("n", [3, 7, 15])
def test_stochasticity_and_roots(name, n):
    topo = T.get_topology(name, n)
    assert np.allclose(topo.W.sum(axis=1), 1.0)
    assert np.allclose(topo.A.sum(axis=0), 1.0)
    assert np.all(np.diag(topo.W) > 0)
    assert np.all(np.diag(topo.A) > 0)
    assert topo.roots(), "Assumption 2 violated: no common root"


def test_binary_tree_root_is_zero():
    topo = T.binary_tree(7)
    assert 0 in topo.roots()
    # tree: every non-root has exactly one in-neighbor in W
    for i in range(1, 7):
        assert len(topo.in_neighbors_W(i)) == 1


def test_tree_graphs_are_not_strongly_connected():
    """Assumption 2 is weaker than strong connectivity (Remark 1)."""
    topo = T.binary_tree(7)
    # node 0 (root) is NOT reachable from leaves in G(W)
    assert len(T.spanning_tree_roots(topo.W)) == 1
    # but the reversed push graph has the same single root
    assert T.common_roots(topo.W, topo.A) == [0]


def test_validate_rejects_bad_matrices():
    n = 4
    W = np.full((n, n), 1.0 / n)
    A = np.full((n, n), 1.0 / n)
    T.validate_weights(W, A)  # fine
    bad = W.copy(); bad[0, 0] = 0.0; bad[0, 1] = 2.0 / n
    with pytest.raises(ValueError):
        T.validate_weights(bad, A)
    with pytest.raises(ValueError):
        T.validate_weights(W, W * 0.9)  # not column stochastic
    # no common root: two disconnected self-loop components
    W2 = np.eye(n); A2 = np.eye(n)
    with pytest.raises(ValueError):
        T.validate_weights(W2, A2)


def test_edges_convention():
    topo = T.directed_ring(4)
    # ring: j -> j+1; so in W, node i pulls from i-1
    assert (0, 1) in topo.edges_W()
    assert (3, 0) in topo.edges_W()
    assert topo.in_neighbors_W(2) == [1]
    assert topo.out_neighbors_A(2) == [3]


def test_ps_structure_common_roots():
    topo = T.parameter_server(8, n_servers=2)
    roots = topo.roots()
    assert set(roots) >= {0, 1}


# ------------------------------------------------------------------ #
# spanning_tree_roots: fast sweep vs brute-force oracle (PR 7)
# ------------------------------------------------------------------ #
def test_roots_n1():
    M = np.ones((1, 1))
    assert T.spanning_tree_roots(M) == [0]
    assert T.spanning_tree_roots_dense(M) == [0]
    assert T.common_roots(M, M) == [0]


def test_roots_disconnected():
    # two self-loop components: nobody reaches everybody
    M = np.eye(4)
    assert T.spanning_tree_roots(M) == []
    assert T.spanning_tree_roots_dense(M) == []
    # two 2-cycles, still disconnected
    M = np.eye(4)
    M[0, 1] = M[1, 0] = M[2, 3] = M[3, 2] = 0.5
    assert T.spanning_tree_roots(M) == []
    assert T.common_roots(M, M) == []


def test_roots_multi_root_dag():
    # diamond DAG with two sources: 0 -> 2, 1 -> 2, 2 -> 3.
    # M[i, j] > 0 means edge j -> i (receiver row), so no single node
    # reaches all others: sources 0 and 1 cannot reach each other.
    M = np.eye(4)
    M[2, 0] = M[2, 1] = M[3, 2] = 1.0
    assert T.spanning_tree_roots(M) == []
    # add 0 -> 1 and node 0 becomes the unique root
    M2 = M.copy()
    M2[1, 0] = 1.0
    assert T.spanning_tree_roots(M2) == [0]
    assert T.spanning_tree_roots_dense(M2) == [0]


def test_common_roots_transpose_convention():
    """common_roots(W, A) intersects G(W) roots with G(A^T) roots: a
    chain 0->1->2 in W but the REVERSED chain in A (2->...->0, i.e.
    A[i,j]>0 with j sender) must still yield root 0, because the push
    graph is judged on A^T."""
    n = 3
    W = np.eye(n)
    for i in range(1, n):
        W[i, i - 1] = 1.0          # pull from the left: root 0
    A = np.eye(n)
    for i in range(1, n):
        A[i - 1, i] = 1.0          # push right-to-left in G(A)
    assert T.spanning_tree_roots(W) == [0]
    # G(A) alone roots at 2; the A^T convention flips it back to 0
    assert T.spanning_tree_roots(A) == [2]
    assert T.common_roots(W, A) == [0]


def test_roots_fast_matches_oracle_random():
    rng = np.random.default_rng(7)
    for _ in range(150):
        n = int(rng.integers(1, 12))
        M = np.eye(n)
        mask = rng.random((n, n)) < rng.uniform(0.05, 0.5)
        M[mask] = 1.0
        assert (T.spanning_tree_roots(M)
                == T.spanning_tree_roots_dense(M)), M


def test_roots_active_submask():
    topo = T.get_topology("robust_tree", 7)
    act = topo.active_mask().copy()
    act[0] = False
    sub = T.subgraph_topology(topo, act)
    assert sub.common_roots  # sibling rung keeps the skeleton rooted
    assert 0 not in sub.common_roots


# ------------------------------------------------------------------ #
# robust_tree + per-epoch rebuilds (PR 7)
# ------------------------------------------------------------------ #
def test_robust_tree_properties():
    for n in (2, 3, 7, 8, 15):
        topo = T.robust_tree(n)
        assert np.allclose(topo.W.sum(axis=1), 1.0)
        assert np.allclose(topo.A.sum(axis=0), 1.0)
        assert np.all(np.diag(topo.W) > 0)
        assert topo.roots() == [0], "node 0 is the sole common root"


def test_robust_tree_survives_root_departure():
    topo = T.robust_tree(8)
    act = topo.active_mask().copy()
    act[0] = False
    new = T.epoch_topology(topo, act, prefer=0)
    roots = new.common_roots
    assert roots and 0 not in roots
    assert set(roots) <= {1, 2}, "the sibling rung pair takes over"
    # the rebuilt graph still satisfies Assumptions 1-2 on survivors
    idx = np.nonzero(act)[0]
    assert np.allclose(new.W[np.ix_(idx, idx)].sum(axis=1), 1.0)
    assert np.allclose(new.A[np.ix_(idx, idx)].sum(axis=0), 1.0)


def test_binary_tree_root_departure_unrecoverable_vs_retree():
    """Plain binary_tree minus its root splits G(W); epoch_topology must
    fall back to the undirected-skeleton re-tree (which binary_tree
    supports only when the skeleton stays connected — it does not, so
    the rebuild raises)."""
    topo = T.binary_tree(7)
    act = topo.active_mask().copy()
    act[0] = False
    with pytest.raises(ValueError, match="Assumption 2 unrecoverable"):
        T.epoch_topology(topo, act)


def test_epoch_topology_static_is_subgraph():
    topo = T.robust_tree(7)
    act = topo.active_mask()
    new = T.epoch_topology(topo, act)
    assert np.allclose(new.W, topo.W) and np.allclose(new.A, topo.A)
