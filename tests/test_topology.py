"""Topology + weight matrix tests (Assumptions 1-2)."""
import numpy as np
import pytest

from repro.core import topology as T


ALL_BUILDERS = ["binary_tree", "line", "directed_ring", "undirected_ring",
                "exponential", "mesh2d", "parameter_server"]


@pytest.mark.parametrize("name", ALL_BUILDERS)
@pytest.mark.parametrize("n", [3, 7, 15])
def test_stochasticity_and_roots(name, n):
    topo = T.get_topology(name, n)
    assert np.allclose(topo.W.sum(axis=1), 1.0)
    assert np.allclose(topo.A.sum(axis=0), 1.0)
    assert np.all(np.diag(topo.W) > 0)
    assert np.all(np.diag(topo.A) > 0)
    assert topo.roots(), "Assumption 2 violated: no common root"


def test_binary_tree_root_is_zero():
    topo = T.binary_tree(7)
    assert 0 in topo.roots()
    # tree: every non-root has exactly one in-neighbor in W
    for i in range(1, 7):
        assert len(topo.in_neighbors_W(i)) == 1


def test_tree_graphs_are_not_strongly_connected():
    """Assumption 2 is weaker than strong connectivity (Remark 1)."""
    topo = T.binary_tree(7)
    # node 0 (root) is NOT reachable from leaves in G(W)
    assert len(T.spanning_tree_roots(topo.W)) == 1
    # but the reversed push graph has the same single root
    assert T.common_roots(topo.W, topo.A) == [0]


def test_validate_rejects_bad_matrices():
    n = 4
    W = np.full((n, n), 1.0 / n)
    A = np.full((n, n), 1.0 / n)
    T.validate_weights(W, A)  # fine
    bad = W.copy(); bad[0, 0] = 0.0; bad[0, 1] = 2.0 / n
    with pytest.raises(ValueError):
        T.validate_weights(bad, A)
    with pytest.raises(ValueError):
        T.validate_weights(W, W * 0.9)  # not column stochastic
    # no common root: two disconnected self-loop components
    W2 = np.eye(n); A2 = np.eye(n)
    with pytest.raises(ValueError):
        T.validate_weights(W2, A2)


def test_edges_convention():
    topo = T.directed_ring(4)
    # ring: j -> j+1; so in W, node i pulls from i-1
    assert (0, 1) in topo.edges_W()
    assert (3, 0) in topo.edges_W()
    assert topo.in_neighbors_W(2) == [1]
    assert topo.out_neighbors_A(2) == [3]


def test_ps_structure_common_roots():
    topo = T.parameter_server(8, n_servers=2)
    roots = topo.roots()
    assert set(roots) >= {0, 1}
