"""Global-view simulator tests: exactness, invariants, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    binary_tree, directed_ring, exponential, get_topology,
    generate_schedule, round_robin_schedule,
    run_rfast, tracked_mass,
)
from repro.core.baselines import run_push_pull_sync
from repro.data import make_logistic_problem

jax.config.update("jax_enable_x64", False)


def quad_grad_fn(n: int, p: int, *, noise: float = 0.0, seed: int = 0):
    """Deterministic-heterogeneous quadratic: f_i = 0.5|x - c_i|^2 * s_i."""
    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)
    S = jnp.asarray(rng.uniform(0.5, 2.0, (n, 1)), jnp.float32)

    def gfn(i, x, key):
        g = S[i] * (x - C[i])
        if noise > 0:
            g = g + noise * jax.random.normal(key, x.shape)
        return g

    x_star = (S * C).sum(0) / S.sum(0)
    return gfn, x_star


# ------------------------------------------------------------------ #
# Remark 2: round-robin schedule == lockstep synchronous R-FAST
# ------------------------------------------------------------------ #
def sync_rfast_reference(topo, grad_fn, x0, gamma, rounds):
    """Numpy lockstep Algorithm 1 with τ = t (Remark 2 semantics)."""
    n = topo.n
    W, A = topo.W, topo.A
    x = np.array(x0, np.float64)
    p = x.shape[1]
    v = np.zeros((n, p))
    dummy = jax.random.PRNGKey(0)
    g_prev = np.stack([np.asarray(grad_fn(i, jnp.asarray(x[i], jnp.float32),
                                          dummy), np.float64)
                       for i in range(n)])
    z = g_prev.copy()
    ea = topo.edges_A()
    rho = {e: np.zeros(p) for e in ea}      # held at sender
    rho_buf = {e: np.zeros(p) for e in ea}  # held at receiver

    for _t in range(rounds):
        v_new = x - gamma * z                       # S1 for all nodes
        x_new = np.zeros_like(x)
        for i in range(n):
            x_new[i] = W[i, i] * v_new[i]
            for j in topo.in_neighbors_W(i):
                x_new[i] += W[i, j] * v[j]          # τ = t: previous round's v
        z_new = np.zeros_like(z)
        rho_new = {e: rho[e].copy() for e in ea}
        buf_new = {e: rho_buf[e].copy() for e in ea}
        g_new = np.zeros_like(g_prev)
        for i in range(n):
            g_new[i] = np.asarray(
                grad_fn(i, jnp.asarray(x_new[i], jnp.float32), dummy),
                np.float64)
            z_half = z[i] + g_new[i] - g_prev[i]
            for j in topo.in_neighbors_A(i):
                z_half = z_half + rho[(j, i)] - rho_buf[(j, i)]
                buf_new[(j, i)] = rho[(j, i)].copy()
            z_new[i] = A[i, i] * z_half
            for j in topo.out_neighbors_A(i):
                rho_new[(i, j)] = rho_new[(i, j)] + A[j, i] * z_half
        x, v, z, g_prev = x_new, v_new, z_new, g_new
        rho, rho_buf = rho_new, buf_new
    return x


@pytest.mark.parametrize("builder", [binary_tree, directed_ring])
@pytest.mark.slow
def test_round_robin_matches_sync_reference(builder):
    n, p, rounds = 5, 6, 12
    topo = builder(n)
    gfn, _ = quad_grad_fn(n, p)
    x0 = jnp.asarray(np.random.default_rng(1).normal(0, 1, (n, p)),
                     jnp.float32)
    sched = round_robin_schedule(topo, rounds)
    state, _ = run_rfast(topo, sched, gfn, x0, gamma=0.05)
    ref = sync_rfast_reference(topo, gfn, np.asarray(x0), 0.05, rounds)
    np.testing.assert_allclose(np.asarray(state.x), ref, rtol=2e-4, atol=2e-5)


# ------------------------------------------------------------------ #
# Lemma 3: mass conservation under arbitrary delays AND packet loss
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("loss", [0.0, 0.3])
@pytest.mark.parametrize("builder", [binary_tree, directed_ring, exponential])
@pytest.mark.slow
def test_mass_conservation(builder, loss):
    n, p, K = 7, 5, 400
    topo = builder(n)
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    sched = generate_schedule(topo, K, loss_prob=loss, latency=0.7,
                              compute_time=[1.0] * (n - 1) + [3.0], seed=3)
    x0 = jnp.zeros((n, p), jnp.float32)
    state, _ = run_rfast(topo, sched, gfn, x0, gamma=0.02)
    lhs = np.asarray(tracked_mass(state))
    rhs = np.asarray(state.g_prev.sum(axis=0))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# Convergence: strongly convex => tight neighborhood of x*
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name,K", [("binary_tree", 6000), ("line", 6000),
                                    ("directed_ring", 6000),
                                    ("exponential", 12000), ("mesh2d", 6000)])
@pytest.mark.slow
def test_convergence_all_topologies(name, K):
    """Paper Fig. 4a: R-FAST converges on all five topologies."""
    n, p = 7, 8
    topo = get_topology(name, n)
    gfn, x_star = quad_grad_fn(n, p)   # deterministic => exact convergence
    sched = generate_schedule(topo, K, latency=0.5, seed=0)
    x0 = jnp.zeros((n, p), jnp.float32)
    state, _ = run_rfast(topo, sched, gfn, x0, gamma=0.03)
    err = np.linalg.norm(np.asarray(state.x) - np.asarray(x_star)[None],
                         axis=1).max()
    assert err < 1e-2, f"{name}: err={err}"


def test_convergence_under_packet_loss():
    n, p, K = 7, 8, 9000
    topo = binary_tree(n)
    gfn, x_star = quad_grad_fn(n, p)
    sched = generate_schedule(topo, K, loss_prob=0.25, latency=0.5, seed=1)
    x0 = jnp.zeros((n, p), jnp.float32)
    state, _ = run_rfast(topo, sched, gfn, x0, gamma=0.03)
    err = np.linalg.norm(np.asarray(state.x) - np.asarray(x_star)[None],
                         axis=1).max()
    assert err < 2e-2, f"err={err}"


def test_heterogeneity_free_fixed_point():
    """Gradient tracking kills the data-heterogeneity bias (Remark 7):
    with deterministic gradients the fixed point is x*, independent of how
    heterogeneous the c_i are (unlike D-PSGD which biases)."""
    n, p, K = 5, 4, 8000
    topo = directed_ring(n)
    rng = np.random.default_rng(5)
    # extremely heterogeneous optima
    C = jnp.asarray(rng.normal(0, 10, (n, p)), jnp.float32)

    def gfn(i, x, key):
        return x - C[i]

    x_star = C.mean(0)
    sched = generate_schedule(topo, K, latency=0.4, seed=2)
    state, _ = run_rfast(topo, sched, gfn, jnp.zeros((n, p)), gamma=0.04)
    err = np.abs(np.asarray(state.x) - np.asarray(x_star)[None]).max()
    assert err < 5e-2, f"err={err}"


# ------------------------------------------------------------------ #
# Logistic regression (paper §VI-A): loss decreases to near-optimal
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_logistic_regression_training():
    n = 7
    prob = make_logistic_problem(n, m=700, d=20, batch=16,
                                 heterogeneous=True, seed=0)
    topo = binary_tree(n)
    sched = generate_schedule(topo, 4000, latency=0.5, seed=0)
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    state, _ = run_rfast(topo, sched, prob.grad_fn(), x0, gamma=5e-3)
    x_star = prob.optimum()
    f_star = float(prob.mean_loss(x_star))
    f_end = float(prob.mean_loss(jnp.asarray(state.x).mean(0)))
    assert f_end < f_star + 0.05, (f_end, f_star)
    assert float(prob.accuracy(jnp.asarray(state.x).mean(0))) > 0.9


# ------------------------------------------------------------------ #
# Sync push-pull baseline sanity (eq. 2)
# ------------------------------------------------------------------ #
def test_push_pull_sync_geometric():
    n, p = 5, 6
    topo = directed_ring(n)
    gfn, x_star = quad_grad_fn(n, p)
    x0 = jnp.zeros((n, p), jnp.float32)
    x, _ = run_push_pull_sync(topo, gfn, x0, gamma=0.08, rounds=800)
    err = np.linalg.norm(np.asarray(x) - np.asarray(x_star)[None], axis=1).max()
    assert err < 1e-3, err


@pytest.mark.slow
def test_multi_root_parameter_server_topology():
    """Appendix G / Fig. 15: multiple common roots (PS-like structure with
    3 servers) — R-FAST converges over it."""
    from repro.core import parameter_server
    n, p, K = 9, 6, 9000
    topo = parameter_server(n, n_servers=3)
    assert len(topo.roots()) >= 3
    gfn, x_star = quad_grad_fn(n, p)
    sched = generate_schedule(topo, K, latency=0.4, seed=4)
    state, _ = run_rfast(topo, sched, gfn, jnp.zeros((n, p)), gamma=0.03)
    err = np.linalg.norm(np.asarray(state.x) - np.asarray(x_star)[None],
                         axis=1).max()
    assert err < 2e-2, err


@pytest.mark.slow
def test_node_crash_and_recovery():
    """Beyond-paper robustness probe: a node crashes for a long window
    (bounded downtime => Assumption 3 with a larger realized T); the
    running-sum ρ delivers the accumulated mass on recovery and the
    system still converges to x*."""
    n, p, K = 7, 6, 14000
    topo = binary_tree(n)
    gfn, x_star = quad_grad_fn(n, p)
    sched = generate_schedule(topo, K, latency=0.4, seed=6,
                              failures=[(3, 100.0, 400.0)])
    # node 3 really is silent inside the window
    t = sched.times[sched.agent == 3]
    assert not np.any((t > 101.0) & (t < 399.0))
    state, _ = run_rfast(topo, sched, gfn, jnp.zeros((n, p)), gamma=0.03)
    # mass conservation survived the outage
    np.testing.assert_allclose(
        np.asarray(tracked_mass(state)),
        np.asarray(state.g_prev.sum(axis=0)), rtol=1e-4, atol=1e-4)
    err = np.linalg.norm(np.asarray(state.x) - np.asarray(x_star)[None],
                         axis=1).max()
    assert err < 2e-2, err
