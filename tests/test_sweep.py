"""Fleet-lane sweep engine: padding inertness + per-lane equivalence.

The sweep engine (`run_sweep`) runs S independent experiments as one
compiled program by normalizing CommPlans to common degree maxima
(`pad_comm_plan`), padding WavefrontPlans to shared wave/width/ρ-layout
maxima (`pad_plan`), and stacking them (`stack_plans`).  Two families of
guarantees are pinned here:

* padding is INERT — padded waves, lanes, and ρ rows commit zero delta,
  so a padded plan realizes exactly the trajectory of the unpadded one;
* each fleet lane matches an individual ``run_rfast`` wavefront run of
  the same (scenario, seed, topology) to fp32 tolerance, across a
  randomized matrix that includes crash/recovery windows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (NetworkScenario, binary_tree, directed_ring,
                        exponential, get_scenario, realize_batch,
                        run_rfast, run_sweep, undirected_ring)
from repro.core.plan import build_comm_plan, pad_comm_plan
from repro.core.schedule import (build_wavefront_plan, pad_plan,
                                 stack_plans)
from repro.core.simulator import (init_state, pack_state,
                                  rfast_wavefront_scan, wave_inputs)
from tests.test_simulator import quad_grad_fn

jax.config.update("jax_enable_x64", False)


def _trees_close(a, b, *, rtol=0.0, atol=1e-7, msg=""):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol,
                                   err_msg=f"{msg}{name}")


# ------------------------------------------------------------------ #
# padding inertness
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("seed,loss", [(0, 0.0), (7, 0.2)])
def test_padded_waves_and_lanes_commit_zero_delta(seed, loss, impl):
    """pad_plan'ed waves/lanes/ρ-rows are no-op commits: running the
    padded plan from the same packed state yields the same final state
    (real-lane arithmetic is untouched — per-lane ops never reduce
    across lanes, and every padded commit scatters to a drop sentinel).
    ``impl='pallas'`` pins the same inertness through the fleet-grid
    commit path (sentinel lanes clamp their gather rows in-kernel)."""
    n, p, K = 7, 5, 300
    topo = binary_tree(n)
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    sc = NetworkScenario(latency=0.4, loss=loss)
    sched = sc.realize(topo, K, seed=seed).schedule
    plan = build_comm_plan(topo)
    H = int(sched.D) + 2
    wf = build_wavefront_plan(sched, plan, H)

    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    step_keys = jax.random.split(key, K)
    state0 = init_state(plan, jnp.zeros((n, p), jnp.float32), gfn,
                        init_key, H)
    runner = rfast_wavefront_scan(plan, gfn, 0.02, donate=False, impl=impl)

    base = runner(pack_state(state0), wave_inputs(wf, step_keys))

    # widen lanes + append all-padded waves
    wf_pad = pad_plan(wf, width=wf.width + 2, n_waves=wf.n_waves + 3)
    out = runner(pack_state(state0), wave_inputs(wf_pad, step_keys))
    _trees_close(out, base, msg="wave/lane pad: ")

    # ρ-layout padding: extra state rows are never touched
    e_a2 = wf.e_a + 3
    wf_rho = pad_plan(wf, e_a=e_a2)
    out2 = runner(pack_state(state0, e_a=e_a2),
                  wave_inputs(wf_rho, step_keys))
    e_a = wf.e_a
    np.testing.assert_allclose(np.asarray(out2.nodes),
                               np.asarray(base.nodes), atol=1e-7)
    np.testing.assert_allclose(np.asarray(out2.rho2[:e_a]),
                               np.asarray(base.rho2[:e_a]), atol=1e-7)
    np.testing.assert_allclose(np.asarray(out2.rho2[e_a2:e_a2 + e_a]),
                               np.asarray(base.rho2[e_a:]), atol=1e-7)
    np.testing.assert_allclose(np.asarray(out2.rho_hist[:, :e_a]),
                               np.asarray(base.rho_hist), atol=1e-7)
    # the pad rows themselves hold exactly zero (nothing ever scattered)
    assert not np.asarray(out2.rho2[e_a:e_a2]).any()
    assert not np.asarray(out2.rho_hist[:, e_a:]).any()


def test_stack_plans_shapes_and_sentinels():
    """Stacked fleet plans: common (S, n_waves, B, ...) shapes, per-lane
    event coverage preserved in order, tail padding carries sentinels."""
    n, K = 7, 400
    topos = [binary_tree(n), directed_ring(n), exponential(n)]
    plans = [build_comm_plan(t) for t in topos]
    kw = max(pl.kw for pl in plans)
    ka = max(pl.ka for pl in plans)
    ko = max(pl.ko for pl in plans)
    e_a = max(pl.n_edges_a for pl in plans)
    scheds = [get_scenario("uniform", n).realize(t, K, seed=s).schedule
              for s, t in enumerate(topos)]
    H = max(int(s.D) for s in scheds) + 2
    wfs = [build_wavefront_plan(sch, pad_comm_plan(pl, kw=kw, ka=ka, ko=ko),
                                H, e_a=e_a)
           for sch, pl in zip(scheds, plans)]
    fleet = stack_plans(wfs)
    S, NW, B = 3, max(w.n_waves for w in wfs), max(w.width for w in wfs)
    assert fleet.agent.shape == (S, NW, B)
    assert fleet.rslot_v.shape == (S, NW, B, kw)
    assert fleet.rho_gidx.shape == (S, NW, B, ko + ka)
    assert fleet.n_waves == NW and fleet.n_lanes == S
    assert (fleet.width, fleet.n, fleet.e_a, fleet.K) == (B, n, e_a, K)
    for s in range(S):
        sizes = fleet.sizes[s]
        assert sizes.sum() == K
        covered = [int(k) for w in range(NW)
                   for k in fleet.kidx[s, w, :sizes[w]]]
        assert covered == list(range(K))
        # every pad slot (wave tail or appended wave) is a sentinel lane
        lane_pad = np.arange(B)[None, :] >= sizes[:, None]
        assert np.all(fleet.agent[s][lane_pad] == n)
        assert np.all(fleet.kidx[s][lane_pad] == K)
        assert np.all(fleet.rho_gidx[s][lane_pad] == 2 * e_a)


def test_pad_comm_plan_inert_columns():
    plan = build_comm_plan(binary_tree(7))
    padded = pad_comm_plan(plan, kw=plan.kw + 2, ka=plan.ka + 1,
                           ko=plan.ko + 3)
    assert (padded.kw, padded.ka, padded.ko) == (plan.kw + 2, plan.ka + 1,
                                                 plan.ko + 3)
    assert not padded.in_w_wt[:, plan.kw:].any()
    assert not padded.in_a_val[:, plan.ka:].any()
    assert not padded.out_a_val[:, plan.ko:].any()
    # real columns untouched, dense edge arrays shared
    np.testing.assert_array_equal(padded.in_w_wt[:, :plan.kw], plan.in_w_wt)
    np.testing.assert_array_equal(padded.src_a, plan.src_a)
    with pytest.raises(ValueError):
        pad_comm_plan(plan, kw=plan.kw - 1)


# ------------------------------------------------------------------ #
# per-lane equivalence with run_rfast
# ------------------------------------------------------------------ #
def _lane_matches(state, sched, topo, gfn, seed, eval_every, metrics=None,
                  ref_kw=None):
    ref, ms_ref = run_rfast(topo, sched, gfn,
                            jnp.zeros(state.x.shape, jnp.float32), 0.02,
                            seed=seed, eval_every=eval_every,
                            **(ref_kw or {}))
    for f in ("x", "v", "z", "g_prev", "rho", "rho_buf"):
        np.testing.assert_allclose(
            np.asarray(getattr(state, f)), np.asarray(getattr(ref, f)),
            rtol=2e-5, atol=2e-5, err_msg=f"seed {seed}: {f}")
    return ms_ref


def test_run_sweep_matches_run_rfast_fast():
    """Two heterogeneous lanes (different topology AND scenario AND
    seed) reproduce their individual wavefront runs."""
    n, p, K = 5, 4, 160
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    topos = [binary_tree(n), directed_ring(n)]
    scs = [get_scenario("uniform", n), get_scenario("packet_loss", n)]
    seeds = [0, 4]
    scheds = [sc.realize(t, K, seed=s).schedule
              for sc, t, s in zip(scs, topos, seeds)]
    x0 = jnp.zeros((n, p), jnp.float32)
    states, _ = run_sweep(topos, scheds, gfn, x0, 0.02, seeds=seeds,
                          eval_every=80)
    for s in range(2):
        _lane_matches(states[s], scheds[s], topos[s], gfn, seeds[s], 80)


@pytest.mark.slow
def test_run_sweep_randomized_matrix():
    """The acceptance matrix: a randomized (scenario, seed, topology)
    fleet — uniform / straggler / packet_loss / crash_recovery windows —
    where every lane must match its individual run_rfast trajectory AND
    its per-chunk eval series."""
    n, p, K = 7, 6, 600
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    # crash windows sized to the realized horizon (K/n compute units)
    crash = NetworkScenario(
        latency=0.3, failures=((n - 1, 15.0, 40.0), (2, 55.0, 70.0)),
        name="crash_recovery")
    lanes = [
        (get_scenario("uniform", n), binary_tree(n), 0),
        (get_scenario("straggler", n), directed_ring(n), 11),
        (get_scenario("packet_loss", n), exponential(n), 5),
        (crash, binary_tree(n), 3),
        (crash, undirected_ring(n), 8),
    ]
    scheds = [sc.realize(t, K, seed=s).schedule for sc, t, s in lanes]
    x0 = jnp.zeros((n, p), jnp.float32)
    ev = 150

    def eval_fn(st, t):
        return {"xm": float(jnp.mean(st.x)), "t": t}

    states, metrics = run_sweep([t for _, t, _ in lanes], scheds, gfn, x0,
                                0.02, seeds=[s for _, _, s in lanes],
                                eval_every=ev, eval_fn=eval_fn)
    for i, (sc, topo, seed) in enumerate(lanes):
        ms_ref = _lane_matches(states[i], scheds[i], topo, gfn, seed, ev,
                               ref_kw={"eval_fn": eval_fn})
        assert len(metrics[i]) == len(ms_ref) == K // ev
        for a, b in zip(metrics[i], ms_ref):
            assert a["t"] == b["t"] and a["k"] == b["k"]
            assert abs(a["xm"] - b["xm"]) < 1e-4


def test_run_sweep_pallas_matches_jnp():
    """impl='pallas' (one fleet-grid commit launch per wave) realizes
    the same trajectories."""
    n, p, K = 5, 6, 120
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    topos = [binary_tree(n), directed_ring(n)]
    scheds = [get_scenario("uniform", n).realize(t, K, seed=s).schedule
              for s, t in enumerate(topos)]
    x0 = jnp.zeros((n, p), jnp.float32)
    s_j, _ = run_sweep(topos, scheds, gfn, x0, 0.02, seeds=[0, 1])
    s_p, _ = run_sweep(topos, scheds, gfn, x0, 0.02, seeds=[0, 1],
                       impl="pallas")
    for a, b in zip(s_j, s_p):
        for f in ("x", "v", "z", "g_prev", "rho", "rho_buf"):
            np.testing.assert_allclose(
                np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
                rtol=2e-5, atol=2e-5, err_msg=f)


@pytest.mark.slow
def test_run_sweep_pallas_randomized_matrix():
    """The tentpole acceptance matrix through the grid path: a
    randomized (topology × scenario × seed) fleet where every
    ``run_sweep(impl='pallas')`` lane must match its individual
    ``run_rfast`` trajectory."""
    n, p, K = 7, 6, 600
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    crash = NetworkScenario(
        latency=0.3, failures=((n - 1, 15.0, 40.0), (2, 55.0, 70.0)),
        name="crash_recovery")
    lanes = [
        (get_scenario("uniform", n), binary_tree(n), 2),
        (get_scenario("straggler", n), directed_ring(n), 13),
        (get_scenario("packet_loss", n), exponential(n), 6),
        (crash, undirected_ring(n), 9),
    ]
    scheds = [sc.realize(t, K, seed=s).schedule for sc, t, s in lanes]
    x0 = jnp.zeros((n, p), jnp.float32)
    states, _ = run_sweep([t for _, t, _ in lanes], scheds, gfn, x0, 0.02,
                          seeds=[s for _, _, s in lanes], eval_every=150,
                          impl="pallas")
    for i, (sc, topo, seed) in enumerate(lanes):
        _lane_matches(states[i], scheds[i], topo, gfn, seed, 150)


def test_run_sweep_pallas_single_dispatch_signature():
    """The dispatch contract: one fleet sweep resolves to ONE grid-launch
    signature (heterogeneous lanes are padded to shared maxima), and a
    re-run over the same schedules with different RNG seeds re-traces
    onto the cached entry — zero new misses."""
    from tests.helpers.recompiles import assert_no_recompiles

    n, p, K = 5, 6, 120
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    topos = [binary_tree(n), directed_ring(n), exponential(n)]
    scheds = [get_scenario("uniform", n).realize(t, K, seed=s).schedule
              for s, t in enumerate(topos)]
    x0 = jnp.zeros((n, p), jnp.float32)

    # one signature for the whole heterogeneous fleet: every chunk of
    # every lane rides the same padded wave shape
    with assert_no_recompiles(expect_entries=1) as rec:
        run_sweep(topos, scheds, gfn, x0, 0.02, seeds=[0, 1, 2],
                  impl="pallas")
    assert rec.misses == 1, rec

    # same schedules, new seeds: new trace, same cached launch
    with assert_no_recompiles(expect_entries=0, fresh=False) as rec2:
        run_sweep(topos, scheds, gfn, x0, 0.02, seeds=[7, 8, 9],
                  impl="pallas")
    assert rec2.misses == 0, rec2
    assert rec2.hits > 0, rec2


def test_wavefront_pallas_block_padded_p_is_inert():
    """The compiled-mode contract on CPU: zero-padding the flat
    parameter axis to a block multiple (pack_state(p_pad=...) +
    p_real=p threading) realizes the exact unpadded trajectory, and the
    pad tail stays identically zero."""
    from repro.kernels.rfast_update.grid import block_pad_width

    n, p, K = 5, 7, 150
    topo = binary_tree(n)
    gfn, _ = quad_grad_fn(n, p, noise=0.1)
    sched = get_scenario("uniform", n).realize(topo, K, seed=1).schedule
    plan = build_comm_plan(topo)
    H = int(sched.D) + 2
    wf = build_wavefront_plan(sched, plan, H)
    key = jax.random.PRNGKey(1)
    key, init_key = jax.random.split(key)
    step_keys = jax.random.split(key, K)
    state0 = init_state(plan, jnp.zeros((n, p), jnp.float32), gfn,
                        init_key, H)
    waves = wave_inputs(wf, step_keys)

    base = rfast_wavefront_scan(plan, gfn, 0.02, donate=False,
                                impl="pallas")(pack_state(state0), waves)
    # p_real must slice before grad_fn: quad_grad_fn rejects padded x
    Pp = block_pad_width(p)
    padded = rfast_wavefront_scan(
        plan, gfn, 0.02, donate=False, impl="pallas",
        p_real=p)(pack_state(state0, p_pad=Pp), waves)
    for name, a, b in zip(base._fields, base, padded):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b[..., :p]),
                                   rtol=1e-6, atol=1e-6, err_msg=name)
        assert not np.asarray(b[..., p:]).any(), name


def test_run_sweep_validation():
    n, p, K = 5, 4, 60
    gfn, _ = quad_grad_fn(n, p)
    topo = binary_tree(n)
    sched = get_scenario("uniform", n).realize(topo, K, seed=0).schedule
    x0 = jnp.zeros((n, p), jnp.float32)
    with pytest.raises(ValueError):      # node counts must agree
        run_sweep([topo, binary_tree(n + 2)], [sched, sched], gfn, x0, 0.02)
    short = get_scenario("uniform", n).realize(topo, K - 10, seed=0).schedule
    with pytest.raises(ValueError):      # K must agree
        run_sweep(topo, [sched, short], gfn, x0, 0.02)
    with pytest.raises(ValueError):      # one seed per lane
        run_sweep(topo, [sched, sched], gfn, x0, 0.02, seeds=[0])


def test_realize_batch_modes():
    n, K = 5, 40
    topo = binary_tree(n)
    tr = realize_batch(topo, K, scenario="uniform", seeds=(0, 1))
    assert len(tr) == 2 and all(t.schedule.K == K for t in tr)
    # seed 0 lane is bit-identical to a direct realize
    direct = get_scenario("uniform", n).realize(topo, K, seed=0)
    np.testing.assert_array_equal(tr[0].schedule.agent,
                                  direct.schedule.agent)
    sweep = realize_batch(topo, K, scenarios=("uniform", "straggler"),
                          seeds=(0, 1, 2))
    assert len(sweep) == 6               # scenario-major, seed-minor
    np.testing.assert_array_equal(sweep[0].schedule.agent,
                                  tr[0].schedule.agent)
    with pytest.raises(ValueError):
        realize_batch(topo, K, seeds=(0,))
    with pytest.raises(ValueError):
        realize_batch(topo, K, scenario="uniform",
                      scenarios=("straggler",))
