"""Fig. 4a: R-FAST convergence over five topologies (7 nodes), plus
simulator-engine throughput rows (wavefront vs event-serial).

The ``topology/*`` rows reproduce the paper figure (one full training run
per topology; us_per_call = wall/K of the whole run, compile included —
the end-to-end number a user sees).  The ``sim/*`` rows isolate the
engine hot loop: warmed, median-of-k timing of the compiled scan on the
same realized schedule, one row per execution mode, so the
wavefront-vs-snapshot speedup is recorded per scale."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import generate_schedule, get_topology
from repro.core.plan import build_comm_plan
from repro.core.schedule import build_wavefront_plan
from repro.core.simulator import (init_state, pack_state, rfast_scan,
                                  rfast_wavefront_scan, wave_inputs)
from .common import csv_row, logistic_setup, measure_us, run_rfast_logistic

TOPOLOGIES = ["binary_tree", "line", "directed_ring", "exponential",
              "mesh2d"]

# (n, d, m, K-divisor) per engine-throughput scale; n=31 is where the
# snapshot engine's O((n+E)·p) history traffic dominates its event cost
ENGINE_SCALES = [(7, 64, 2800, 1), (31, 256, 8680, 2)]


def _engine_rows(name: str, K: int) -> list[str]:
    rows = []
    for n, d, m, div in ENGINE_SCALES:
        Ks = max(500, K // div)
        prob = logistic_setup(n, d=d, m=m)
        gfn = prob.grad_fn()
        topo = get_topology(name, n)
        sched = generate_schedule(topo, Ks, latency=0.3, seed=0)
        plan = build_comm_plan(topo)
        H = int(sched.D) + 2
        key = jax.random.PRNGKey(0)
        step_keys = jax.random.split(key, Ks)
        state = init_state(plan, jnp.zeros((n, prob.p), jnp.float32),
                           gfn, key, H)

        wf = build_wavefront_plan(sched, plan, H)
        waves = wave_inputs(wf, step_keys)
        packed = pack_state(state)
        runner = rfast_wavefront_scan(plan, gfn, 5e-3, donate=False)
        us_wave = measure_us(runner, packed, waves, reps=3) / Ks

        # same schedule through the fused-grid commit (dispatch-resolved:
        # compiled on TPU, the jnp emulation twin on CPU) — the maxerr
        # keeps the grid path honest on real engine traffic
        runner_p = rfast_wavefront_scan(plan, gfn, 5e-3, donate=False,
                                        impl="pallas")
        us_wave_p = measure_us(runner_p, packed, waves, reps=3) / Ks
        werr = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(runner(packed, waves), runner_p(packed, waves)))

        chunk = rfast_scan(plan, gfn, 5e-3, H, donate=False)
        agent = jnp.asarray(sched.agent)
        sv = jnp.asarray(sched.stamp_v)
        sr = jnp.asarray(sched.stamp_rho)
        us_event = measure_us(chunk, state, agent, sv, sr, step_keys,
                              reps=3) / Ks

        rows.append(csv_row(
            f"sim/{name}_n{n}_wavefront", us_wave,
            f"speedup_vs_event={us_event / us_wave:.2f}x;"
            f"B={wf.width};waves={wf.n_waves};K={Ks}"))
        rows.append(csv_row(
            f"sim/{name}_n{n}_wavefront_pallas", us_wave_p,
            f"ratio_vs_jnp={us_wave_p / us_wave:.2f}x;"
            f"maxerr_vs_jnp={werr:.1e};B={wf.width};K={Ks}"))
        rows.append(csv_row(
            f"sim/{name}_n{n}_event", us_event,
            f"mode=event_serial_snapshot;K={Ks}"))
    return rows


def run(K: int = 12_000, n: int = 7) -> list[str]:
    prob = logistic_setup(n)
    rows = []
    for name in TOPOLOGIES:
        state, metrics, wall = run_rfast_logistic(prob, name, K)
        final = metrics[-1]
        rows.append(csv_row(
            f"topology/{name}", wall / K * 1e6,
            f"loss={final['loss']:.4f};acc={final['acc']:.3f}"))
    rows.extend(_engine_rows("binary_tree", K))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
