"""Fig. 4a: R-FAST convergence over five topologies (7 nodes)."""
from __future__ import annotations

from .common import csv_row, logistic_setup, run_rfast_logistic

TOPOLOGIES = ["binary_tree", "line", "directed_ring", "exponential",
              "mesh2d"]


def run(K: int = 12_000, n: int = 7) -> list[str]:
    prob = logistic_setup(n)
    rows = []
    for name in TOPOLOGIES:
        state, metrics, wall = run_rfast_logistic(prob, name, K)
        final = metrics[-1]
        rows.append(csv_row(
            f"topology/{name}", wall / K * 1e6,
            f"loss={final['loss']:.4f};acc={final['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
