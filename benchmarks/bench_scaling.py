"""Fig. 4b / Table III: time-to-target-loss vs number of nodes.

The paper reports near-linear scaling of time-to-loss with node count on
the binary tree; we measure virtual time to reach a fixed mean loss.
"""
from __future__ import annotations

from .common import (csv_row, logistic_setup,
                     run_rfast_logistic, time_to_loss)


def run(target: float = 0.30) -> list[str]:
    rows = []
    base_t = None
    for n in (3, 7, 15):
        prob = logistic_setup(n, batch=16)
        # same total work budget per node => K scales with n
        K = 2400 * n
        state, metrics, wall = run_rfast_logistic(
            prob, "binary_tree", K, eval_every=200)
        t = time_to_loss(metrics, target)
        if base_t is None:
            base_t = t
        rows.append(csv_row(
            f"scaling/n{n}", wall / K * 1e6,
            f"vtime_to_loss{target}={t:.1f};speedup_vs_n3={base_t/t:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
