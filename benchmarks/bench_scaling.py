"""Fig. 4b / Table III + production scale: node-count scaling rows.

Two regimes share this suite:

* ``scaling/n3..n15`` — the paper's time-to-target-loss measurement on
  the binary tree (virtual time to a fixed mean loss, K = 2400·n so the
  per-node work budget is constant).  Unchanged from the original rows.
* ``scaling/n63..n255`` — ENGINE throughput past the single-device
  ceiling: big topologies through the mesh-mapped fleet engine
  (``run_sweep(mesh=...)``, lanes on the ``data`` axis).  Time-to-loss
  at K = 2400·n would mean ~600k events at n=255, so these rows report
  wall µs per event instead (the quantity that scales with devices).
* ``lm100m/wavefront_mesh`` — the REAL ``configs/rfast_100m.py``
  transformer (~100M flat parameters) training end to end through the
  mesh-mapped wavefront engine with the parameter axis sharded over
  every available device (``param_shards = n_devices``) — the p >= 100M
  win condition.  On a forced-host-device CPU mesh this exercises the
  exact sharded program that runs on real accelerators.  Under
  ``--quick`` (CI smoke + the committed baseline) the 2-layer reduced
  variant runs instead, as ``lm100m/wavefront_mesh_reduced`` — the full
  row costs ~17 GB of packed state and ~10 min wall even at K=2.

Run standalone with forced host devices for the sharded rows (drop
``--quick`` for the full ~125M lm100m row)::

    python -m benchmarks.bench_scaling --quick --devices 4
"""
from __future__ import annotations

from .common import (csv_row, logistic_setup, run_rfast_logistic,
                     stopwatch, time_to_loss)


def _paper_rows(target: float) -> list[str]:
    rows = []
    base_t = None
    for n in (3, 7, 15):
        prob = logistic_setup(n, batch=16)
        # same total work budget per node => K scales with n
        K = 2400 * n
        state, metrics, wall = run_rfast_logistic(
            prob, "binary_tree", K, eval_every=200)
        t = time_to_loss(metrics, target)
        if base_t is None:
            base_t = t
        rows.append(csv_row(
            f"scaling/n{n}", wall / K * 1e6,
            f"vtime_to_loss{target}={t:.1f};speedup_vs_n3={base_t/t:.2f}"))
    return rows


def _mesh_rows(quick: bool) -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core import get_scenario, get_topology, run_sweep
    from repro.launch.mesh import make_sweep_mesh

    rows = []
    mesh = make_sweep_mesh()            # all devices on the lane axis
    ndev = mesh.devices.size
    S = 2                               # 2 seeds/lane-groups per row
    for n in (63, 127, 255):
        K = (2 if quick else 4) * n
        prob = logistic_setup(n, batch=8, m=max(1200, 8 * n))
        topo = get_topology("binary_tree", n)
        sc = get_scenario("uniform", n)
        scheds = [sc.realize(topo, K, seed=s).schedule for s in range(S)]
        x0 = jnp.zeros(prob.p, jnp.float32)
        with stopwatch() as sw:
            states, _ = run_sweep(topo, scheds, prob, x0, 5e-3,
                                  seeds=range(S), mesh=mesh)
            jax.block_until_ready(states[-1].x)
        rows.append(csv_row(
            f"scaling/n{n}", sw["s"] / (S * K) * 1e6,
            f"engine=run_sweep_mesh;devices={ndev};S={S};K={K}"))
    return rows


def _lm100m_rows(quick: bool) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.rfast_100m import get_config
    from repro.core import get_scenario, get_topology, run_sweep
    from repro.data import make_lm_problem
    from repro.launch.mesh import make_sweep_mesh

    n, K = 2, (2 if quick else 6)
    cfg = get_config()
    # quick (the CI smoke + committed baseline) runs the 2-layer reduced
    # variant: the full ~125M row needs ~17 GB of packed state + ~10 min
    # wall even at K=2 — a standalone full run is the real win condition:
    #   python -m benchmarks.bench_scaling --devices 4
    name = "lm100m/wavefront_mesh"
    if quick:
        cfg, name = cfg.reduced(), "lm100m/wavefront_mesh_reduced"
    prob = make_lm_problem(cfg, n, batch_per_node=1, seq_len=32,
                           eval_batch=2)
    ndev = len(jax.devices())
    # every device holds a 1/ndev slice of the ~100M flat axis
    mesh = make_sweep_mesh(lanes=1, param_shards=ndev)
    topo = get_topology("binary_tree", n)
    sched = get_scenario("uniform", n).realize(topo, K, seed=0).schedule
    x0 = jnp.asarray(prob.x0_flat, jnp.float32)
    with stopwatch() as sw:
        states, _ = run_sweep(topo, [sched], prob, x0, 1e-3, seeds=[0],
                              mesh=mesh)
        jax.block_until_ready(states[0].x)
    xbar = np.asarray(states[0].x).mean(0)
    loss = float(prob.mean_loss(jnp.asarray(xbar)))
    return [csv_row(
        name, sw["s"] / K * 1e6,
        f"p={prob.p};devices={ndev};param_shards={ndev};n={n};K={K};"
        f"loss={loss:.3f}")]


def run(target: float = 0.30, quick: bool = False) -> list[str]:
    rows = _paper_rows(target)
    rows += _mesh_rows(quick)
    rows += _lm100m_rows(quick)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force this many host-platform devices before "
                    "jax initializes (the CPU dev loop for the sharded "
                    "rows; ignored if a backend already initialized)")
    args = ap.parse_args()
    if args.devices:
        from repro.launch.xla_env import force_host_devices
        force_host_devices(args.devices)
    print("\n".join(run(quick=args.quick)))
