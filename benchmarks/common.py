"""Shared benchmark harness: paper §VI logistic-regression setup at
CPU-friendly scale, with virtual-time accounting for speed comparisons."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import generate_schedule, get_topology, run_rfast
from repro.data import make_logistic_problem


def logistic_setup(n: int, *, het: bool = True, d: int = 64, m: int = 2800,
                   batch: int = 16, seed: int = 0):
    prob = make_logistic_problem(n, m=m, d=d, batch=batch,
                                 heterogeneous=het, seed=seed)
    return prob


def time_to_loss(metrics: list[dict], target: float) -> float:
    """First virtual time at which mean loss <= target (inf if never)."""
    for m in metrics:
        if m["loss"] <= target:
            return m["t"]
    return float("inf")


def eval_fn_for(prob):
    def eval_fn(state_or_x, t):
        x = state_or_x.x if hasattr(state_or_x, "x") else state_or_x
        if isinstance(x, tuple):
            x = x[0]
        xb = jnp.asarray(x)
        if xb.ndim == 2:
            xb = xb.mean(0)
        return {"loss": float(prob.mean_loss(xb)),
                "acc": float(prob.accuracy(xb)), "t": t}
    return eval_fn


def run_rfast_logistic(prob, topo_name: str, K: int, *, gamma=5e-3,
                       compute_time=None, loss_prob=0.0, seed=0,
                       eval_every=500):
    n = prob.n
    topo = get_topology(topo_name, n)
    sched = generate_schedule(topo, K, compute_time=compute_time,
                              loss_prob=loss_prob, latency=0.3, seed=seed)
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    t0 = time.time()
    state, metrics = run_rfast(topo, sched, prob.grad_fn(), x0, gamma,
                               eval_every=eval_every,
                               eval_fn=eval_fn_for(prob), seed=seed)
    wall = time.time() - t0
    return state, metrics, wall


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
