"""Shared benchmark harness: paper §VI logistic-regression setup at
CPU-friendly scale, virtual-time accounting for speed comparisons, and
the suite-wide timing utilities (``perf_counter`` based, warmup separated
from measurement, median-of-k reporting)."""
from __future__ import annotations

import time
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (generate_schedule, get_topology, realize_batch,
                        run_rfast, run_sweep)
from repro.data import make_logistic_problem


# --------------------------------------------------------------------- #
# timing
# --------------------------------------------------------------------- #
def measure_us(fn, *args, warmup: int = 1, reps: int = 5, **kw) -> float:
    """Median wall time per call in µs.

    ``warmup`` calls run first (compile + caches) and are NOT measured;
    each of the ``reps`` measured calls is blocked on, and the median is
    reported so a stray scheduler hiccup cannot skew the row.
    """
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def measure_us_paired(fns: dict, *args, warmup: int = 1, reps: int = 5,
                      **kw) -> dict:
    """Median wall time per call in µs for SEVERAL callables, measured in
    interleaved rounds (one call of each per round, same arguments).

    Host speed drifts between measurement windows (turbo/thermal state,
    allocator pressure from earlier suites) — timing impl A's reps and
    then impl B's puts the drift entirely on one side and corrupts the
    A/B *ratio* the committed rows gate on.  Interleaving lands every
    drift regime on every callable equally, so ratios stay honest even
    when absolute numbers move.

    Every timed call starts COLD: the callables here share input
    arrays, so whichever one runs second finds them warm in LLC — a
    systematic bias worth 2x+ on shared-cache hosts, and no ordering
    scheme fixes it (mixed warm/cold samples are bimodal, so the
    median jumps regimes between runs).  A 64 MB host-memory sweep
    before each timed call evicts the shared state instead, making
    every sample the same (cold) measurement."""
    scrub = np.zeros(1 << 23, dtype=np.float64)          # 64 MB
    for fn in fns.values():
        for _ in range(max(1, warmup)):
            jax.block_until_ready(fn(*args, **kw))
    ts: dict = {k: [] for k in fns}
    for _ in range(max(1, reps)):
        for k, fn in fns.items():
            scrub += 1.0                                 # LLC eviction
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args, **kw))
            ts[k].append(time.perf_counter() - t0)
    return {k: float(np.median(v)) * 1e6 for k, v in ts.items()}


@contextmanager
def stopwatch():
    """``with stopwatch() as sw: ...`` — ``sw['s']`` holds elapsed seconds
    (``perf_counter``; for one-shot sections where median-of-k is not
    affordable, e.g. whole training runs)."""
    box: dict = {}
    t0 = time.perf_counter()
    try:
        yield box
    finally:
        box["s"] = time.perf_counter() - t0


def logistic_setup(n: int, *, het: bool = True, d: int = 64, m: int = 2800,
                   batch: int = 16, seed: int = 0):
    prob = make_logistic_problem(n, m=m, d=d, batch=batch,
                                 heterogeneous=het, seed=seed)
    return prob


def time_to_loss(metrics: list[dict], target: float) -> float:
    """First virtual time at which mean loss <= target (inf if never)."""
    for m in metrics:
        if m["loss"] <= target:
            return m["t"]
    return float("inf")


def time_to_sustained_loss(metrics: list[dict], target: float) -> float:
    """First virtual time from which mean loss STAYS <= target through
    the end of the run (inf if the last eval is still above).

    The dynamic-membership rows need this instead of the first-crossing
    metric: a crash/departure mid-run makes the trajectory non-monotone
    (a pre-crash dip can touch the target, then the disruption pushes
    the loss back up), and a frozen-plan run must not get credit for a
    transient it cannot hold."""
    t = float("inf")
    for m in metrics:
        if m["loss"] <= target:
            if not np.isfinite(t):
                t = m["t"]
        else:
            t = float("inf")
    return t


def eval_fn_for(prob):
    """Uniform eval hook: every algorithm hands over its *iterate* —
    an (n, p) per-node stack, a (p,) single model, or the R-FAST state."""
    def eval_fn(state_or_x, t):
        x = state_or_x.x if hasattr(state_or_x, "x") else state_or_x
        xb = jnp.asarray(x)
        if xb.ndim == 2:
            xb = xb.mean(0)
        return {"loss": float(prob.mean_loss(xb)),
                "acc": float(prob.accuracy(xb)), "t": t}
    return eval_fn


def _x0_for(prob):
    """Per-node start iterate: the provider's ``x0_flat`` when it has one
    (real models start at their init), else the zero vector (the convex
    objectives)."""
    x0_flat = getattr(prob, "x0_flat", None)
    if x0_flat is None:
        return jnp.zeros((prob.n, prob.p), jnp.float32)
    return jnp.tile(jnp.asarray(x0_flat, jnp.float32)[None], (prob.n, 1))


def run_rfast_problem(prob, topo_name: str, K: int, *, gamma=5e-3,
                      scenario=None, compute_time=None, loss_prob=0.0,
                      seed=0, eval_every=500, mode="wavefront"):
    """Run R-FAST on any GradProvider (LogisticProblem, LMProblem, ...);
    x0 comes from :func:`_x0_for`."""
    n = prob.n
    topo = get_topology(topo_name, n)
    if scenario is not None:
        if compute_time is not None or loss_prob != 0.0:
            raise ValueError("pass either scenario= or the legacy "
                             "compute_time/loss_prob kwargs, not both")
        sched = generate_schedule(topo, K, scenario=scenario, seed=seed)
    else:
        sched = generate_schedule(topo, K, compute_time=compute_time,
                                  loss_prob=loss_prob, latency=0.3, seed=seed)
    x0 = _x0_for(prob)
    with stopwatch() as sw:
        state, metrics = run_rfast(topo, sched, prob, x0, gamma,
                                   eval_every=eval_every,
                                   eval_fn=eval_fn_for(prob), seed=seed,
                                   mode=mode)
        jax.block_until_ready(state.x)
    return state, metrics, sw["s"]


# kept name: the logistic suites predate the substrate-generic runner
run_rfast_logistic = run_rfast_problem


def run_sweep_problem(prob, topo_name: str, K: int, *, scenario,
                      gamma=5e-3, seeds=(0, 1, 2), eval_every=500,
                      impl="jnp"):
    """Run a fleet of seeds of one (problem, topology, scenario) through
    the sweep engine: one compiled program, one seed per lane.

    Returns ``(states, metrics_lanes, wall_s)`` with one final state and
    one metrics list per seed — feed ``metrics_lanes`` through
    :func:`time_to_loss` per lane and report the median."""
    n = prob.n
    topo = get_topology(topo_name, n)
    traces = realize_batch(topo, K, scenario=scenario, seeds=seeds)
    scheds = [t.schedule for t in traces]
    x0 = _x0_for(prob)
    with stopwatch() as sw:
        states, metrics = run_sweep(topo, scheds, prob, x0, gamma,
                                    seeds=list(seeds),
                                    eval_every=eval_every,
                                    eval_fn=eval_fn_for(prob), impl=impl)
        jax.block_until_ready(states[-1].x)
    return states, metrics, sw["s"]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
