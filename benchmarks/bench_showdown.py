"""Head-to-head time-to-loss showdown (the paper's Fig. 5-6 claim):
R-FAST vs Ring-AllReduce / D-PSGD / S-AB / AD-PSGD / OSGP, every
algorithm on the SAME :class:`~repro.core.scenario.NetworkScenario`
virtual clock — identical stragglers, latency, loss bursts, and
crash/recovery windows, so the comparison is apples-to-apples.

Every time-to-loss row is a MULTI-SEED MEDIAN (the paper's claims are
statistical — AD-PSGD and the Assran et al. survey report the same way):
R-FAST runs its seeds as one fleet through the sweep engine
(``run_sweep``: one compiled program, one lane per seed), the baselines
loop their host-driven runs over the same seeds.

Two workload families:

* ``showdown/<scenario>/<algo>`` — the paper's §VI logistic regression.
* ``lm/<scenario>/<algo>`` (:func:`run_lm`) — the reduced transformer
  LM on the flat-parameter substrate: R-FAST trains through the
  wavefront engine over the scenario's event clock, the synchronous
  baselines consume the same flat ``grad_fn`` under the barrier clock.

Row derived fields: ``vtime=<median-time-to-target>;acc=<median-final>``
(+ ``loss=<median-final>`` for lm rows) ``;seeds=<count>``
``;ratio=<vtime/vtime_rfast>``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (get_scenario, get_topology, realize_epochs_batch,
                        run_sweep_epochs)
from repro.core.baselines import (run_adpsgd, run_dpsgd, run_osgp,
                                  run_ring_allreduce, run_sab)
from repro.data import make_lm_problem
from .common import (csv_row, eval_fn_for, logistic_setup,
                     run_sweep_problem, stopwatch, time_to_loss,
                     time_to_sustained_loss)

SCENARIO_NAMES = ("straggler", "packet_loss", "crash_recovery")
SEEDS = (0, 1, 2)


def _emit(rows, key, wall, calls, vts, finals, t_ref=None):
    """One median row: vts/finals are per-seed crossing times and final
    metric dicts; ``calls`` the fleet-wide event/round count the wall
    time amortizes over."""
    t = float(np.median(vts))
    derived = f"vtime={t:.1f}"
    for field in ("loss", "acc"):
        if field in finals[0]:
            derived += (f";{field}="
                        f"{float(np.median([m[field] for m in finals])):.3f}")
    derived += f";seeds={len(vts)}"
    if t_ref is not None:
        derived += (f";ratio={t / t_ref:.2f}"
                    if np.isfinite(t) and np.isfinite(t_ref) and t_ref > 0
                    else ";ratio=inf")
    rows.append(csv_row(key, wall / calls * 1e6, derived))
    return t


def _baseline_median(fn, args, sc, seeds, eval_fn, ev):
    """Per-seed host runs of one baseline; returns (wall, vts, finals)."""
    vts_raw, finals = [], []
    with stopwatch() as sw:
        for sd in seeds:
            _, ms = fn(*args, scenario=sc, seed=sd, eval_fn=eval_fn,
                       eval_every=ev)
            vts_raw.append(ms)
            finals.append(ms[-1])
    return sw["s"], vts_raw, finals


def run(target: float = 0.35, n: int = 8, rounds: int = 1000,
        gamma: float = 5e-3, scenarios: tuple[str, ...] = SCENARIO_NAMES,
        seeds: tuple[int, ...] = SEEDS) -> list[str]:
    rows = []
    prob = logistic_setup(n)
    gfn = prob.grad_fn()
    eval_fn = eval_fn_for(prob)
    K = rounds * n
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    topo_d = get_topology("directed_ring", n)
    topo_u = get_topology("undirected_ring", n)

    for sc_name in scenarios:
        sc = get_scenario(sc_name, n)

        # --- R-FAST (async, one fleet lane per seed) -------------------
        _, ms_lanes, wall = run_sweep_problem(prob, "binary_tree", K,
                                              gamma=gamma, scenario=sc,
                                              seeds=seeds,
                                              eval_every=max(200, K // 40))
        t_rfast = _emit(rows, f"showdown/{sc_name}/R-FAST",
                        wall, K * len(seeds),
                        [time_to_loss(ms, target) for ms in ms_lanes],
                        [ms[-1] for ms in ms_lanes])

        # --- synchronous baselines (the scenario's barrier clock) ------
        ev = max(10, rounds // 40)
        for name, fn, args in (
            ("Ring-AllReduce", run_ring_allreduce,
             (n, gfn, jnp.zeros(prob.p), gamma, rounds)),
            ("D-PSGD", run_dpsgd, (topo_u, gfn, x0, gamma, rounds)),
            ("S-AB", run_sab, (topo_d, gfn, x0, gamma, rounds)),
        ):
            wall, ms_seeds, finals = _baseline_median(fn, args, sc, seeds,
                                                      eval_fn, ev)
            _emit(rows, f"showdown/{sc_name}/{name}",
                  wall, rounds * len(seeds),
                  [time_to_loss(ms, target) for ms in ms_seeds],
                  finals, t_rfast)

        # --- asynchronous baselines (same event clock) ------------------
        for name, fn, topo in (("AD-PSGD", run_adpsgd, topo_u),
                               ("OSGP", run_osgp, topo_d)):
            wall, ms_seeds, finals = _baseline_median(
                fn, (topo, gfn, x0, gamma, K), sc, seeds, eval_fn,
                max(200, K // 40))
            _emit(rows, f"showdown/{sc_name}/{name}",
                  wall, K * len(seeds),
                  [time_to_loss(ms, target) for ms in ms_seeds],
                  finals, t_rfast)
    return rows


def run_dynamic(target: float = 4.5e-3, n: int = 8, rounds: int = 150,
                gamma: float = 2e-3,
                seeds: tuple[int, ...] = SEEDS) -> list[str]:
    """Dynamic-membership rows (the Assumption-2 robustness claim).

    * ``showdown/root_failover/R-FAST`` — the sole common root of
      ``robust_tree`` departs at t=30; the epochized engine
      (``run_sweep_epochs``) re-elects a surviving root, migrates the
      packed state, and keeps converging.  Median SUSTAINED
      time-to-loss across seeds (see
      :func:`~benchmarks.common.time_to_sustained_loss`): the
      crash makes trajectories non-monotone, so a row only counts
      a crossing it holds to the end of the run.
    * ``showdown/root_failover/R-FAST-frozen`` — the SAME scenario run
      through the frozen-plan engine (``realize()`` degrades the
      departure to a permanent crash window): part of the tracked
      gradient mass is stranded at the dead root, the survivors plateau
      above the target, and the row pins ``vtime=inf;ratio=inf`` — the
      failure mode the epochized engine removes.
    * ``churn/<scenario>/R-FAST`` — join/leave churn and correlated
      regional failures through the same epochized fleet.

    The target sits between the two regimes' plateaus (calibrated at
    this scale: frozen stalls at ~5e-3+, epochized descends through
    ~4e-3), so the frozen row is inf at any rounds >= 150.
    """
    rows = []
    prob = logistic_setup(n)
    eval_fn = eval_fn_for(prob)
    K = rounds * n
    ev = max(100, K // 40)
    x0 = jnp.zeros((n, prob.p), jnp.float32)
    topo = get_topology("robust_tree", n)

    def epochized(sc_name):
        sc = get_scenario(sc_name, n)
        traces = realize_epochs_batch(topo, K, scenario=sc, seeds=seeds)
        with stopwatch() as sw:
            _, ms_lanes = run_sweep_epochs(
                traces, prob, x0, gamma, seeds=list(seeds),
                eval_every=ev, eval_fn=eval_fn)
        return sw["s"], ms_lanes

    # --- root failover: epochized re-election vs frozen plan ----------
    wall, ms_lanes = epochized("root_failover")
    t_rfast = _emit(rows, "showdown/root_failover/R-FAST",
                    wall, K * len(seeds),
                    [time_to_sustained_loss(ms, target) for ms in ms_lanes],
                    [ms[-1] for ms in ms_lanes])
    _, ms_frozen, wall_f = run_sweep_problem(
        prob, "robust_tree", K, gamma=gamma,
        scenario=get_scenario("root_failover", n), seeds=seeds,
        eval_every=ev)
    _emit(rows, "showdown/root_failover/R-FAST-frozen",
          wall_f, K * len(seeds),
          [time_to_sustained_loss(ms, target) for ms in ms_frozen],
          [ms[-1] for ms in ms_frozen], t_rfast)

    # --- churn / regional failures (epochized only: the frozen engine
    # cannot express a join, it degrades to a crash window) ------------
    for sc_name in ("churn", "regional_failure"):
        wall, ms_lanes = epochized(sc_name)
        _emit(rows, f"churn/{sc_name}/R-FAST", wall, K * len(seeds),
              [time_to_sustained_loss(ms, target) for ms in ms_lanes],
              [ms[-1] for ms in ms_lanes])
    return rows


def run_lm(drop: float = 1.4, n: int = 4, rounds: int = 120,
           gamma: float = 2e-2, scenarios: tuple[str, ...] = SCENARIO_NAMES,
           seeds: tuple[int, ...] = SEEDS) -> list[str]:
    """``lm/<scenario>/<algo>`` time-to-loss rows on the reduced LM.

    Every algorithm starts from the same init and consumes the same
    flat-substrate gradients; the target is an absolute loss drop of
    ``drop`` nats from the shared initial eval loss (the Zipfian token
    marginal leaves real headroom below the uniform floor).  ``drop``
    must put the target well below the first few rounds' loss and every
    algorithm is evaluated every (equivalent-)round, so the vtime
    columns measure crossing times, not eval cadence.  R-FAST's seeds
    run as one sweep fleet; the sync trio loops the same seeds.
    """
    cfg = get_config("rfast-100m").reduced(max_d_model=64, vocab=128)
    prob = make_lm_problem(cfg, n, batch_per_node=4, seq_len=32,
                           eval_batch=16)
    gfn = prob.grad_fn()
    eval_fn = eval_fn_for(prob)
    K = rounds * n
    l0 = float(prob.mean_loss(prob.x0_flat))
    target = l0 - drop
    x0 = jnp.tile(prob.x0_flat[None], (n, 1))
    topo_d = get_topology("directed_ring", n)
    topo_u = get_topology("undirected_ring", n)

    rows = []
    for sc_name in scenarios:
        sc = get_scenario(sc_name, n)

        # --- R-FAST (async: the sweep engine on the event clock) -------
        _, ms_lanes, wall = run_sweep_problem(prob, "binary_tree", K,
                                              gamma=gamma, scenario=sc,
                                              seeds=seeds, eval_every=n)
        t_rfast = _emit(rows, f"lm/{sc_name}/R-FAST",
                        wall, K * len(seeds),
                        [time_to_loss(ms, target) for ms in ms_lanes],
                        [ms[-1] for ms in ms_lanes])

        # --- synchronous baselines (the scenario's barrier clock) ------
        for name, fn, args in (
            ("Ring-AllReduce", run_ring_allreduce,
             (n, gfn, prob.x0_flat, gamma, rounds)),
            ("D-PSGD", run_dpsgd, (topo_u, gfn, x0, gamma, rounds)),
            ("S-AB", run_sab, (topo_d, gfn, x0, gamma, rounds)),
        ):
            wall, ms_seeds, finals = _baseline_median(fn, args, sc, seeds,
                                                      eval_fn, 1)
            _emit(rows, f"lm/{sc_name}/{name}",
                  wall, rounds * len(seeds),
                  [time_to_loss(ms, target) for ms in ms_seeds],
                  finals, t_rfast)
    return rows


if __name__ == "__main__":
    print("\n".join(run() + run_dynamic() + run_lm()))
