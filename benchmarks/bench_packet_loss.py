"""§VI robustness: accuracy under packet loss — R-FAST's running-sum
tracking vs OSGP's push-sum (which loses gradient mass)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import NetworkScenario, get_topology
from repro.core.baselines import run_osgp
from .common import (csv_row, eval_fn_for, logistic_setup,
                     run_rfast_logistic, stopwatch)


def run(n: int = 7, K: int = 14_000, gamma: float = 5e-3) -> list[str]:
    rows = []
    prob = logistic_setup(n)
    eval_fn = eval_fn_for(prob)
    for loss_p in (0.0, 0.2, 0.4):
        # ONE scenario for both rows: same latency, same loss channel
        sc = NetworkScenario(latency=0.3, loss=loss_p)
        state, metrics, wall = run_rfast_logistic(
            prob, "binary_tree", K, gamma=gamma, scenario=sc)
        rows.append(csv_row(
            f"packet_loss/p{loss_p}/R-FAST", wall / K * 1e6,
            f"loss={metrics[-1]['loss']:.4f};acc={metrics[-1]['acc']:.3f}"))

        topo = get_topology("directed_ring", n)
        with stopwatch() as sw:
            _, ms = run_osgp(topo, prob.grad_fn(), jnp.zeros((n, prob.p)),
                             gamma, K, scenario=sc, eval_fn=eval_fn,
                             eval_every=2000)
        wall = sw["s"]
        rows.append(csv_row(
            f"packet_loss/p{loss_p}/OSGP", wall / K * 1e6,
            f"loss={ms[-1]['loss']:.4f};acc={ms[-1]['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
