"""Remark 7: ς-free convergence — R-FAST vs D-PSGD under IID and
label-sorted (fully heterogeneous) data partitions."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import NetworkScenario, get_topology
from repro.core.baselines import run_dpsgd
from .common import (csv_row, eval_fn_for, logistic_setup,
                     run_rfast_logistic, stopwatch)


def run(n: int = 7, K: int = 12_000, gamma: float = 5e-3) -> list[str]:
    rows = []
    sc = NetworkScenario(latency=0.3)   # shared clock for both rows
    for het in (False, True):
        tag = "het" if het else "iid"
        prob = logistic_setup(n, het=het)
        state, metrics, wall = run_rfast_logistic(prob, "directed_ring", K,
                                                  gamma=gamma, scenario=sc)
        rows.append(csv_row(
            f"heterogeneity/{tag}/R-FAST", wall / K * 1e6,
            f"loss={metrics[-1]['loss']:.4f};acc={metrics[-1]['acc']:.3f}"))

        topo = get_topology("undirected_ring", n)
        with stopwatch() as sw:
            _, ms = run_dpsgd(topo, prob.grad_fn(), jnp.zeros((n, prob.p)),
                              gamma, K // n, scenario=sc,
                              eval_fn=eval_fn_for(prob),
                              eval_every=K // n // 4)
        wall = sw["s"]
        rows.append(csv_row(
            f"heterogeneity/{tag}/D-PSGD", wall / (K // n) * 1e6,
            f"loss={ms[-1]['loss']:.4f};acc={ms[-1]['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
