"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness +
relative cost of ref vs fused; true perf numbers require TPU).

The ``impl="pallas"`` rows time whatever :mod:`repro.kernels.rfast_update.
dispatch` resolves to on this host — the compiled Mosaic grid kernel on
TPU, its jnp emulation twin on CPU — so the numbers measure the fleet-grid
*architecture* (flat gathers + one launch), never the Pallas interpreter.
Interpreter runs are kept solely as correctness cross-checks and are
pinned with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rfast_update import dispatch
from repro.kernels.rfast_update.grid import commit_grid
from repro.kernels.rfast_update.ops import rfast_commit, rfast_update
from repro.kernels.ssm_scan.ops import selective_scan
from .common import csv_row, measure_us, measure_us_paired


def _time(fn, *args, reps: int = 9, **kw):
    return measure_us(fn, *args, warmup=2, reps=reps, **kw)


def _size_label(p: int) -> str:
    return f"{p >> 20}M" if p >= 1 << 20 else f"{p >> 10}k"


def _protocol_round_rows(impl: str | None, *, p: int = 1 << 16,
                         reps: int = 9) -> list[str]:
    """End-to-end protocol round: the fused kernel in its real hot path.

    Times ``make_rfast_round`` with the requested backend(s) on a robust
    (masked) round over a binary tree, and cross-checks jnp vs pallas
    agreement — the wiring the ``--impl pallas`` train path exercises.
    """
    from repro.core import binary_tree
    from repro.core.plan import build_comm_plan
    from repro.core.runtime import init_node_state, make_rfast_round

    n = 8
    label = _size_label(p)
    topo = binary_tree(n)
    plan = build_comm_plan(topo)
    rng = np.random.default_rng(1)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)

    def grad_fn(params, batch, key):
        del key
        d = params["w"] - batch
        return 0.5 * jnp.sum(d * d), {"w": d}

    params = {"w": jnp.zeros((p,), jnp.float32)}
    key = jax.random.PRNGKey(0)
    state = init_node_state(plan, params, grad_fn, C, key, robust=True)
    keys = jax.random.split(key, n)
    masks = jnp.asarray(rng.uniform(size=plan.e_pad) > 0.3, jnp.float32)

    # An explicit --impl restricts execution to that backend (escape hatch
    # for platforms where the other one is broken or slow); the jnp-vs-
    # pallas cross-check row only runs when both backends are in play.
    impls = (impl,) if impl else ("jnp", "pallas")
    rows, outs, rfs = [], {}, {}
    for im in impls:
        rf = jax.jit(make_rfast_round(plan, grad_fn, gamma=0.01,
                                      robust=True, impl=im))
        outs[im] = rf(state, C, keys, masks)[0]
        rfs[im] = rf
    # interleaved rounds: the jnp/pallas ratio must not absorb host drift
    us_by = measure_us_paired(rfs, state, C, keys, masks,
                              warmup=2, reps=reps)
    for im in impls:
        note = f"impl={im}"
        if im == "pallas":
            note += f";mode={dispatch.resolve_mode(None)}"
        rows.append(csv_row(f"protocol/round_{im}_{n}x{label}",
                            us_by[im], note))
    if len(impls) == 2:
        err = max(float(jnp.abs(getattr(outs["jnp"], f)["w"]
                                - getattr(outs["pallas"], f)["w"]).max())
                  for f in ("x", "z", "rho", "rho_buf"))
        # agreement row, not a timing: nan -> null in the --json artifact
        rows.append(csv_row(f"protocol/round_jnp_vs_pallas_{n}x{label}",
                            float("nan"), f"maxerr={err:.1e}"))
    return rows


def _commit_grid_vs_vmap_row(rng, *, reps: int = 5) -> str:
    """The tentpole's win condition as one committed number: one fused
    fleet-grid launch vs the backend it replaced — a ``vmap`` of the
    per-node commit kernel (which, pre-dispatch-cache, always ran the
    Pallas interpreter off-TPU; that launch-per-node + interpreter cost
    is exactly what users paid).  A ``vmap`` of the jnp per-node ref
    over pre-gathered operands rides along as the interpreter-free
    floor (``vmap_ref_us``)."""
    B, P, Ka, Ko = 8, 1 << 20, 3, 2
    a = lambda *s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    z_src = a(B * 4, P)
    g_new = a(B, P)
    rho = a(16, P)
    buf = a(16, P)
    idx_z = jnp.arange(B, dtype=jnp.int32) * 4 + 2
    idx_g = idx_z + 1
    ints = lambda *s: jnp.asarray(rng.integers(0, 16, s), jnp.int32)
    idx_ri, idx_rb, idx_ro = ints(B, Ka), ints(B, Ka), ints(B, Ko)
    a_self = a(B)
    mask = jnp.asarray(rng.uniform(size=(B, Ka)) > 0.3, jnp.float32)
    a_out = a(B, Ko)

    grid_fn = jax.jit(lambda gn: commit_grid(
        idx_z, idx_g, idx_ri, idx_rb, idx_ro, a_self, mask, a_out,
        z_src, gn, z_src, rho, buf, buf))

    def one(impl, z, gn, go, ri, rb, m, ro, aw, asf):
        return rfast_commit(z, gn, go, ri, rb, m, ro, aw, a_self=asf,
                            impl=impl, interpret=True)

    gathered = lambda gn: (z_src[idx_z], gn, z_src[idx_g], rho[idx_ri],
                           buf[idx_rb], mask, buf[idx_ro], a_out, a_self)
    vmap_kern = jax.jit(lambda gn: jax.vmap(
        functools.partial(one, "pallas"))(*gathered(gn)))
    vmap_ref = jax.jit(lambda gn: jax.vmap(
        functools.partial(one, "ref"))(*gathered(gn)))

    us_by = measure_us_paired({"grid": grid_fn, "ref": vmap_ref}, g_new,
                              warmup=1, reps=reps)
    us_grid, us_ref = us_by["grid"], us_by["ref"]
    us_kern = measure_us(vmap_kern, g_new, warmup=1,
                         reps=min(2, reps))       # interpreter: seconds/call
    err = max(float(jnp.abs(g - v).max())
              for g, v in zip(grid_fn(g_new), vmap_kern(g_new)))
    return csv_row(
        "kernel/commit_grid_vs_vmap", us_grid,
        f"mode={dispatch.resolve_mode(None)};"
        f"speedup_vs_replaced_vmap={us_kern / us_grid:.1f}x;"
        f"vmap_kernel_us={us_kern:.0f};vmap_ref_us={us_ref:.0f};"
        f"maxerr={err:.1e};B={B};P={P}")


def run(impl: str | None = None, quick: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    big_reps = 3 if quick else 5
    rows = _protocol_round_rows(impl)
    rows += _protocol_round_rows(impl, p=1 << 20, reps=big_reps)

    P = 1 << 20
    a = lambda *s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    kw = dict(x=a(P), z=a(P), g_new=a(P), g_old=a(P), v_in=a(1, P),
              w_in=jnp.asarray([0.5]), rho_in=a(1, P), rho_buf=a(1, P),
              mask=jnp.asarray([1.0]), rho_out=a(1, P),
              a_out=jnp.asarray([0.5]), gamma=0.01, w_self=0.5, a_self=0.5)
    # interpret=True pins the Pallas-interpreter oracle: with the default
    # tri-state, impl="pallas" resolves to the jnp emulation on CPU and
    # the cross-check would be vacuous
    err = max(float(jnp.abs(r - p).max()) for r, p in zip(
        rfast_update(**kw, impl="ref"),
        rfast_update(**kw, impl="pallas", interpret=True)))

    # commit-only variant: drops the x'/v output streams (and the
    # x/v_in inputs feeding them) that the runtime discards — the
    # ref-impl timing delta shows the saved memory traffic on CPU too
    ck = dict(z=kw["z"], g_new=kw["g_new"], g_old=kw["g_old"],
              rho_in=kw["rho_in"], rho_buf=kw["rho_buf"], mask=kw["mask"],
              rho_out=kw["rho_out"], a_out=kw["a_out"], a_self=0.5)
    cerr = max(float(jnp.abs(r - p).max()) for r, p in zip(
        rfast_commit(**ck, impl="ref"),
        rfast_commit(**ck, impl="pallas", interpret=True)))

    # dispatch-resolved commit (grid at B=1): compiled Mosaic on TPU,
    # the emulation twin on CPU — the number the train path actually pays
    commit_pallas = jax.jit(
        lambda **c: rfast_commit(**c, impl="pallas", a_self=0.5))
    pk = {k: v for k, v in ck.items() if k != "a_self"}
    perr = max(float(jnp.abs(r - p).max()) for r, p in zip(
        rfast_commit(**ck, impl="ref"), commit_pallas(**pk)))
    # the three ratio-bearing timings run interleaved (see
    # measure_us_paired): saving_vs_full and ref_ratio gate on ratios
    us_by = measure_us_paired(
        {"full": lambda: rfast_update(**kw, impl="ref"),
         "commit": lambda: rfast_commit(**ck, impl="ref"),
         "pallas": lambda: commit_pallas(**pk)},
        warmup=2, reps=big_reps + 2)
    us_ref, us_commit, us_pallas = (us_by["full"], us_by["commit"],
                                    us_by["pallas"])
    rows.append(csv_row("kernel/rfast_update_ref_1M", us_ref,
                        f"pallas_interp_maxerr={err:.1e}"))
    rows.append(csv_row(
        "kernel/rfast_commit_ref_1M", us_commit,
        f"pallas_interp_maxerr={cerr:.1e};"
        f"saving_vs_full={us_ref / us_commit:.2f}x"))
    rows.append(csv_row(
        "kernel/rfast_commit_pallas_1M", us_pallas,
        f"mode={dispatch.resolve_mode(None)};maxerr_vs_ref={perr:.1e};"
        f"ref_ratio={us_pallas / us_commit:.2f}x"))

    rows.append(_commit_grid_vs_vmap_row(rng, reps=big_reps))

    q = a(1, 512, 4, 64)
    k = a(1, 512, 2, 64)
    v = a(1, 512, 2, 64)
    us = _time(flash_attention, q, k, v, impl="ref")
    err = float(jnp.abs(
        flash_attention(q, k, v, impl="ref")
        - flash_attention(q, k, v, impl="pallas")).max())
    rows.append(csv_row("kernel/flash_attention_ref_512", us,
                        f"pallas_interp_maxerr={err:.1e}"))

    B, S, di, N = 1, 512, 64, 16
    u = a(B, S, di)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (di, N)), jnp.float32)
    Bc, Cc, D = a(B, S, N), a(B, S, N), a(di)
    us = _time(selective_scan, u, dt, A, Bc, Cc, D, impl="ref")
    yr, _ = selective_scan(u, dt, A, Bc, Cc, D, impl="ref")
    yp, _ = selective_scan(u, dt, A, Bc, Cc, D, impl="pallas", chunk=128,
                           bd=64)
    rows.append(csv_row("kernel/ssm_scan_ref_512", us,
                        f"pallas_interp_maxerr={float(jnp.abs(yr-yp).max()):.1e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
