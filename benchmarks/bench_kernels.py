"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness +
relative cost of ref vs fused; true perf numbers require TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rfast_update.ops import rfast_commit, rfast_update
from repro.kernels.ssm_scan.ops import selective_scan
from .common import csv_row, measure_us


def _time(fn, *args, **kw):
    return measure_us(fn, *args, warmup=2, reps=9, **kw)


def _protocol_round_rows(impl: str | None) -> list[str]:
    """End-to-end protocol round: the fused kernel in its real hot path.

    Times ``make_rfast_round`` with the requested backend(s) on a robust
    (masked) round over a binary tree, and cross-checks jnp vs pallas
    agreement — the wiring the ``--impl pallas`` train path exercises.
    """
    from repro.core import binary_tree
    from repro.core.plan import build_comm_plan
    from repro.core.runtime import init_node_state, make_rfast_round

    n, p = 8, 1 << 16
    topo = binary_tree(n)
    plan = build_comm_plan(topo)
    rng = np.random.default_rng(1)
    C = jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32)

    def grad_fn(params, batch, key):
        del key
        d = params["w"] - batch
        return 0.5 * jnp.sum(d * d), {"w": d}

    params = {"w": jnp.zeros((p,), jnp.float32)}
    key = jax.random.PRNGKey(0)
    state = init_node_state(plan, params, grad_fn, C, key, robust=True)
    keys = jax.random.split(key, n)
    masks = jnp.asarray(rng.uniform(size=plan.e_pad) > 0.3, jnp.float32)

    # An explicit --impl restricts execution to that backend (escape hatch
    # for platforms where the other one is broken or slow); the jnp-vs-
    # pallas cross-check row only runs when both backends are in play.
    impls = (impl,) if impl else ("jnp", "pallas")
    rows, outs = [], {}
    for im in impls:
        rf = jax.jit(make_rfast_round(plan, grad_fn, gamma=0.01,
                                      robust=True, impl=im))
        outs[im] = rf(state, C, keys, masks)[0]
        us = _time(rf, state, C, keys, masks)
        rows.append(csv_row(f"protocol/round_{im}_{n}x{p>>10}k", us,
                            f"impl={im}"))
    if len(impls) == 2:
        err = max(float(jnp.abs(getattr(outs["jnp"], f)["w"]
                                - getattr(outs["pallas"], f)["w"]).max())
                  for f in ("x", "z", "rho", "rho_buf"))
        # agreement row, not a timing: nan -> null in the --json artifact
        rows.append(csv_row("protocol/round_jnp_vs_pallas", float("nan"),
                            f"maxerr={err:.1e}"))
    return rows


def run(impl: str | None = None) -> list[str]:
    rng = np.random.default_rng(0)
    rows = _protocol_round_rows(impl)

    P = 1 << 20
    a = lambda *s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    kw = dict(x=a(P), z=a(P), g_new=a(P), g_old=a(P), v_in=a(1, P),
              w_in=jnp.asarray([0.5]), rho_in=a(1, P), rho_buf=a(1, P),
              mask=jnp.asarray([1.0]), rho_out=a(1, P),
              a_out=jnp.asarray([0.5]), gamma=0.01, w_self=0.5, a_self=0.5)
    us_ref = _time(rfast_update, **kw, impl="ref")
    err = max(float(jnp.abs(r - p).max()) for r, p in zip(
        rfast_update(**kw, impl="ref"), rfast_update(**kw, impl="pallas")))
    rows.append(csv_row("kernel/rfast_update_ref_1M", us_ref,
                        f"pallas_interp_maxerr={err:.1e}"))

    # commit-only variant: drops the x'/v output streams (and the
    # x/v_in inputs feeding them) that the runtime discards — the
    # ref-impl timing delta shows the saved memory traffic on CPU too
    ck = dict(z=kw["z"], g_new=kw["g_new"], g_old=kw["g_old"],
              rho_in=kw["rho_in"], rho_buf=kw["rho_buf"], mask=kw["mask"],
              rho_out=kw["rho_out"], a_out=kw["a_out"], a_self=0.5)
    us_commit = _time(rfast_commit, **ck, impl="ref")
    cerr = max(float(jnp.abs(r - p).max()) for r, p in zip(
        rfast_commit(**ck, impl="ref"), rfast_commit(**ck, impl="pallas")))
    rows.append(csv_row(
        "kernel/rfast_commit_ref_1M", us_commit,
        f"pallas_interp_maxerr={cerr:.1e};"
        f"saving_vs_full={us_ref / us_commit:.2f}x"))

    q = a(1, 512, 4, 64)
    k = a(1, 512, 2, 64)
    v = a(1, 512, 2, 64)
    us = _time(flash_attention, q, k, v, impl="ref")
    err = float(jnp.abs(
        flash_attention(q, k, v, impl="ref")
        - flash_attention(q, k, v, impl="pallas")).max())
    rows.append(csv_row("kernel/flash_attention_ref_512", us,
                        f"pallas_interp_maxerr={err:.1e}"))

    B, S, di, N = 1, 512, 64, 16
    u = a(B, S, di)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (di, N)), jnp.float32)
    Bc, Cc, D = a(B, S, N), a(B, S, N), a(di)
    us = _time(selective_scan, u, dt, A, Bc, Cc, D, impl="ref")
    yr, _ = selective_scan(u, dt, A, Bc, Cc, D, impl="ref")
    yp, _ = selective_scan(u, dt, A, Bc, Cc, D, impl="pallas", chunk=128,
                           bd=64)
    rows.append(csv_row("kernel/ssm_scan_ref_512", us,
                        f"pallas_interp_maxerr={float(jnp.abs(yr-yp).max()):.1e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
