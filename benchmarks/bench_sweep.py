"""Fleet-lane sweep engine throughput: S independent experiments as ONE
compiled wavefront program (``run_sweep``) vs S sequential ``run_rfast``
calls — the compile is paid once and the per-wave math batches
``(S, B, p)``, which is what makes multi-seed rows affordable everywhere
else in the suite (see DESIGN.md §9).

Rows:

* ``sweep/seq_n<n>_S<S>``   — S sequential runs (per-event µs across the
  whole fleet; what a seed loop costs today).
* ``sweep/fleet_n<n>_S<S>`` — the same fleet through ``run_sweep``;
  derived carries the headline ``speedup_vs_sequential`` and the max
  per-lane deviation from the individual runs (a free correctness spot
  check on real benchmark traffic).
* ``sweep/mixed_n<n>_S<S>`` — a (topology × scenario) fleet, exercising
  degree padding and the ρ-layout remap across heterogeneous lanes.
* ``sweep/fleet_sharded_d<D>`` — the same fleet through the mesh-mapped
  engine (``run_sweep(mesh=...)``) with the lane axis spread over D
  devices; derived carries ``speedup_vs_d1``, the lane-throughput
  scaling the mesh exists for.  Which D values appear depends on the
  visible device count (forced host devices on CPU); ``d1`` always runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (get_scenario, get_topology, realize_batch,
                        run_rfast, run_sweep)
from .common import csv_row, logistic_setup, stopwatch


def _median_wall(fn, reps: int = 3) -> float:
    """Median wall seconds over ``reps`` calls.  Unlike measure_us there
    is NO separated warmup: the one-time compile is the point of the
    comparison (a seed loop pays it per run, the fleet once per call) —
    the median only guards against scheduler hiccups."""
    walls = []
    for _ in range(max(1, reps)):
        with stopwatch() as sw:
            fn()
        walls.append(sw["s"])
    return float(np.median(walls))


def run(S: int = 8, n: int = 7, K: int = 2000,
        gamma: float = 5e-3) -> list[str]:
    rows = []
    prob = logistic_setup(n)
    topo = get_topology("binary_tree", n)
    traces = realize_batch(topo, K, scenario=get_scenario("uniform", n),
                           seeds=range(S))
    scheds = [t.schedule for t in traces]
    x0 = jnp.zeros((n, prob.p), jnp.float32)

    # --- S sequential run_rfast calls (the pre-sweep seed loop) --------
    finals = []

    def sequential():
        finals.clear()
        for s, sched in enumerate(scheds):
            st, _ = run_rfast(topo, sched, prob, x0, gamma, seed=s)
            jax.block_until_ready(st.x)
            finals.append(np.asarray(st.x))

    t_seq = _median_wall(sequential)
    rows.append(csv_row(f"sweep/seq_n{n}_S{S}", t_seq / (S * K) * 1e6,
                        f"engine=run_rfast_x{S};K={K}"))

    # --- the same fleet as one compiled program ------------------------
    last = {}

    def fleet():
        states, _ = run_sweep(topo, scheds, prob, x0, gamma,
                              seeds=range(S))
        jax.block_until_ready(states[-1].x)
        last["states"] = states

    t_fleet = _median_wall(fleet)
    states = last["states"]
    maxerr = max(float(np.abs(np.asarray(states[s].x) - finals[s]).max())
                 for s in range(S))
    rows.append(csv_row(f"sweep/fleet_n{n}_S{S}", t_fleet / (S * K) * 1e6,
                        f"speedup_vs_sequential={t_seq / t_fleet:.2f}x;"
                        f"lane_maxerr_vs_run_rfast={maxerr:.1e};K={K}"))

    # --- the fleet again through the fused-grid commit -----------------
    def fleet_pallas():
        sts, _ = run_sweep(topo, scheds, prob, x0, gamma,
                           seeds=range(S), impl="pallas")
        jax.block_until_ready(sts[-1].x)
        last["states"] = sts

    t_fp = _median_wall(fleet_pallas)
    sts = last["states"]
    perr = max(float(np.abs(np.asarray(sts[s].x) - finals[s]).max())
               for s in range(S))
    rows.append(csv_row(f"sweep/fleet_pallas_n{n}_S{S}",
                        t_fp / (S * K) * 1e6,
                        f"ratio_vs_jnp_fleet={t_fp / t_fleet:.2f}x;"
                        f"lane_maxerr_vs_run_rfast={perr:.1e};K={K}"))

    # --- heterogeneous fleet: 3 topologies x 2 scenarios ---------------
    Km = max(200, K // 2)
    lane_topos, lane_scheds, lane_seeds = [], [], []
    for ti, tname in enumerate(("binary_tree", "directed_ring",
                                "exponential")):
        tp = get_topology(tname, n)
        for si, scn in enumerate(("straggler", "packet_loss")):
            seed = 10 * ti + si
            tr = get_scenario(scn, n).realize(tp, Km, seed=seed)
            lane_topos.append(tp)
            lane_scheds.append(tr.schedule)
            lane_seeds.append(seed)
    Sm = len(lane_scheds)

    def mixed():
        sts, _ = run_sweep(lane_topos, lane_scheds, prob, x0, gamma,
                           seeds=lane_seeds)
        jax.block_until_ready(sts[-1].x)

    t_mixed = _median_wall(mixed)
    rows.append(csv_row(f"sweep/mixed_n{n}_S{Sm}",
                        t_mixed / (Sm * Km) * 1e6,
                        f"topologies=3;scenarios=2;K={Km}"))

    # --- lane throughput vs device count (mesh-mapped engine) ----------
    from repro.launch.mesh import make_sweep_mesh
    ndev = len(jax.devices())
    ds = sorted({1} | {d for d in (2, 4, ndev) if 1 < d <= ndev})
    t_d1 = None
    for d in ds:
        mesh = make_sweep_mesh(lanes=d, param_shards=1)

        def fleet_sharded():
            sts, _ = run_sweep(topo, scheds, prob, x0, gamma,
                               seeds=range(S), mesh=mesh)
            jax.block_until_ready(sts[-1].x)

        t_d = _median_wall(fleet_sharded)
        if t_d1 is None:
            t_d1 = t_d
        rows.append(csv_row(f"sweep/fleet_sharded_d{d}",
                            t_d / (S * K) * 1e6,
                            f"devices={d};S={S};K={K};"
                            f"speedup_vs_d1={t_d1 / t_d:.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
