"""Benchmark suite driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a header comment per
section).  ``--quick`` shrinks iteration counts for CI.  ``--json PATH``
additionally writes the rows as structured JSON so perf trajectories can
be committed (e.g. ``BENCH_2026-07-30.json``) and diffed across PRs.
``--compare OLD.json`` diffs the fresh us_per_call numbers against such
a committed baseline and exits non-zero on >25% regressions (tune with
``--regression-threshold``) so CI can gate on perf.
``--perf-gate`` (opt-in, needs ``--compare``) gates the *pallas/jnp
ratio*: every ``*_pallas_*`` row's ratio to its ``*_jnp_*``/``*_ref_*``
counterpart is compared against the same ratio in the committed
baseline, and the run fails when it grew by more than
``--regression-threshold``.  Ratios-of-ratios cancel host speed, so the
gate holds the fused-dispatch contract even across machines.
``--impl`` selects the protocol backend timed by the kernels suite.
"""
from __future__ import annotations

import argparse
import json
import sys


def _row_to_record(suite: str, row: str) -> dict:
    import math
    name, us, derived = row.split(",", 2)
    try:
        us_val: float | None = float(us)
    except ValueError:
        us_val = None
    if us_val is not None and not math.isfinite(us_val):
        us_val = None        # keep the JSON artifact strictly parseable
    return {"suite": suite, "name": name, "us_per_call": us_val,
            "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: topologies,scaling,"
                         "straggler,packet_loss,heterogeneity,kernels,"
                         "showdown,sweep,serve")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--impl", default="",
                    help="protocol backend for the kernels-suite round "
                         "benchmark (default: both; see "
                         "repro.core.protocol.IMPLS)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write results as JSON (commit as "
                         "BENCH_*.json for perf trajectories)")
    ap.add_argument("--compare", default="", metavar="OLD.json",
                    help="diff us_per_call against a committed baseline "
                         "JSON and exit non-zero on regressions beyond "
                         "--regression-threshold")
    ap.add_argument("--regression-threshold", type=float, default=0.25,
                    help="fractional us_per_call increase treated as a "
                         "regression in --compare mode (default 0.25)")
    ap.add_argument("--perf-gate", action="store_true",
                    help="with --compare: fail when a *_pallas_* row's "
                         "ratio to its jnp/ref counterpart grew beyond "
                         "--regression-threshold vs the baseline's ratio "
                         "(host-speed invariant; opt-in)")
    ap.add_argument("--structural", action="store_true",
                    help="with --compare: gate only on errored and "
                         "missing rows, never on timing regressions "
                         "(for CI runners whose timings are too noisy "
                         "for the threshold)")
    ap.add_argument("--lint", action="store_true",
                    help="skip the benchmark suites and run the "
                         "repro.analysis plan-invariant linter + jaxpr "
                         "auditor over the full scenario x topology "
                         "matrix; JSON report to --json (or stdout), "
                         "exit 1 on any diagnostic")
    args = ap.parse_args()

    if args.lint:
        from repro.analysis.runner import run_all

        report = run_all(quick=args.quick,
                         progress=lambda m: print(f"[lint] {m}",
                                                  file=sys.stderr))
        doc = json.dumps(report, indent=2)
        if args.json:
            with open(args.json, "w") as fh:
                fh.write(doc + "\n")
        else:
            print(doc)
        n_diag = report["summary"]["diagnostics"]
        print(f"[lint] {n_diag} diagnostic(s)", file=sys.stderr)
        raise SystemExit(1 if n_diag else 0)

    from repro.core.protocol import IMPLS

    from . import (bench_heterogeneity, bench_kernels, bench_packet_loss,
                   bench_scaling, bench_serve, bench_showdown,
                   bench_straggler, bench_sweep, bench_topologies)

    if args.impl and args.impl not in IMPLS:
        ap.error(f"--impl must be one of {IMPLS}, got {args.impl!r}")
    if args.structural and not args.compare:
        ap.error("--structural only makes sense with --compare")
    if args.perf_gate and not args.compare:
        ap.error("--perf-gate needs --compare (the baseline supplies "
                 "the reference pallas/jnp ratios)")

    suites = {
        "topologies": lambda: bench_topologies.run(
            K=4000 if args.quick else 12_000),
        "scaling": lambda: bench_scaling.run(quick=args.quick),
        "straggler": lambda: bench_straggler.run(
            rounds=400 if args.quick else 1200),
        "packet_loss": lambda: bench_packet_loss.run(
            K=5000 if args.quick else 14_000),
        "heterogeneity": lambda: bench_heterogeneity.run(
            K=4000 if args.quick else 12_000),
        "kernels": lambda: bench_kernels.run(impl=args.impl or None,
                                             quick=args.quick),
        "showdown": lambda: bench_showdown.run(
            rounds=150 if args.quick else 1000)
        + bench_showdown.run_dynamic(rounds=150 if args.quick else 400)
        + bench_showdown.run_lm(rounds=40 if args.quick else 120),
        "sweep": lambda: bench_sweep.run(
            K=1200 if args.quick else 3000),
        "serve": lambda: bench_serve.run(quick=args.quick),
    }
    only = [s for s in args.only.split(",") if s]
    meta = {"quick": bool(args.quick), "impl": args.impl or "both",
            "only": only}
    print("name,us_per_call,derived")
    records: list[dict] = []
    failed = False
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            for row in fn():
                print(row, flush=True)
                records.append(_row_to_record(name, row))
        except Exception as e:  # noqa: BLE001
            failed = True
            row = f"{name},nan,ERROR:{type(e).__name__}:{e}"
            print(row)
            records.append(_row_to_record(name, row))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"meta": meta, "rows": records}, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(records)} rows to {args.json}", file=sys.stderr)
    if args.compare:
        problems = _compare(records, args.compare,
                            args.regression_threshold, run_meta=meta,
                            structural=args.structural)
        if problems:
            raise SystemExit(2)
    if args.perf_gate:
        if _perf_gate(records, args.compare, args.regression_threshold):
            raise SystemExit(3)
    if failed:
        raise SystemExit(1)


def _pallas_ratios(rows: list[dict]) -> dict:
    """Map each timed ``*_pallas_*`` row to its pallas/counterpart ratio
    (counterpart = the same-named ``*_jnp_*`` or ``*_ref_*`` row)."""
    by = {(r["suite"], r["name"]): r["us_per_call"] for r in rows}
    out = {}
    for (suite, name), us in by.items():
        if not us or "_pallas_" not in name:
            continue
        for alt in ("_jnp_", "_ref_"):
            base = by.get((suite, name.replace("_pallas_", alt)))
            if base:
                out[(suite, name)] = us / base
                break
    return out


def _perf_gate(records: list[dict], baseline_path: str,
               threshold: float) -> list[str]:
    """Opt-in (``--perf-gate``) pallas/jnp ratio gate.

    For every timed row whose name contains ``_pallas_`` (the fused
    dispatch path: ``protocol/round_pallas_*``, ``kernel/*_pallas_*``),
    compute its ratio to the same-named ``_jnp_``/``_ref_`` row from the
    SAME run, then compare with the identical ratio in the committed
    baseline JSON; fail when the ratio grew by more than ``threshold``.
    A ratio-of-ratios cancels absolute host speed, so the gate is valid
    on runners where raw-timing thresholds are meaningless.  Rows with
    no counterpart or no baseline ratio are reported, never gated."""
    with open(baseline_path) as f:
        base_ratios = _pallas_ratios(json.load(f)["rows"])
    now_ratios = _pallas_ratios(records)
    problems: list[str] = []
    print(f"# --- perf gate (pallas/jnp ratio drift <= +{threshold:.0%} "
          f"vs baseline) ---", file=sys.stderr)
    for (suite, name), ratio in sorted(now_ratios.items()):
        base = base_ratios.get((suite, name))
        if base is None:
            print(f"# {suite}/{name}: ratio {ratio:.2f}x (no baseline "
                  f"ratio — not gated)", file=sys.stderr)
            continue
        bad = ratio > base * (1 + threshold)
        print(f"# {suite}/{name}: ratio {ratio:.2f}x vs baseline "
              f"{base:.2f}x{' PERF-GATE FAIL' if bad else ''}",
              file=sys.stderr)
        if bad:
            problems.append(name)
    if problems:
        print(f"# perf gate FAILS: {len(problems)} pallas ratio(s) "
              f"regressed beyond +{threshold:.0%}", file=sys.stderr)
    else:
        print("# perf gate OK", file=sys.stderr)
    return problems


# Row-name prefixes every run of a suite must produce: the dynamic-graph
# robustness families (epochized root failover incl. the frozen-stall
# control row, and churn/regional failures), the mesh-mapped scaling
# rows past the single-device ceiling (n63..n255 + the 100M-parameter
# LM through the sharded wavefront engine), the lane-throughput sharding
# row, and the serving-engine rows (throughput, tail latency, tail
# latency through a live weight swap, and the staleness/loss pairing).
# The structural gate requires them even against baselines that predate
# the rows, so a future PR cannot silently drop the failover scenarios,
# the production-scale paths, or the serving loop.
REQUIRED_PREFIXES = {
    "showdown": ("showdown/root_failover/", "churn/"),
    "scaling": ("scaling/n63", "scaling/n127", "scaling/n255",
                "lm100m/"),
    "sweep": ("sweep/fleet_sharded_",),
    "serve": ("serve/reqs_per_s", "serve/p50_us", "serve/p99_us",
              "serve/swap_p99_us", "serve/staleness_vs_loss"),
}


def _compare(records: list[dict], baseline_path: str,
             threshold: float, run_meta: dict | None = None,
             structural: bool = False) -> list[dict]:
    """Diff ``records`` against a committed BENCH_*.json.

    Returns every row that should fail the gate: regressions beyond
    ``threshold``, rows that errored this run (derived ``ERROR:...`` —
    correctness-only rows intentionally record ``nan`` us and must NOT
    gate), and baseline rows that disappeared.  Regressions and vanished rows
    are only gated when the run's quick/impl settings match the
    baseline's recorded meta (quick changes per-call compile
    amortization, impl changes which rows exist), and vanished rows only
    for suites that actually ran (so ``--only`` subsets pass).  Errored
    rows always gate — they are about this run, not the baseline.
    ``structural=True`` reports timing ratios but never gates on them
    (errored/missing rows only — shared CI runners are too noisy for a
    timing threshold).
    """
    with open(baseline_path) as f:
        base_doc = json.load(f)
    old = {(r["suite"], r["name"]): r["us_per_call"]
           for r in base_doc["rows"]}
    base_meta = base_doc.get("meta", {})
    # quick changes K (compile amortization) and impl changes which rows
    # exist: per-call ratios and row presence are only comparable when
    # this run was recorded the same way as the baseline
    comparable = run_meta is None or all(
        run_meta.get(k) == base_meta.get(k) for k in ("quick", "impl"))
    fresh = {(r["suite"], r["name"]): r for r in records}
    executed = {r["suite"] for r in records}
    problems = []
    print(f"# --- compare vs {baseline_path} "
          f"(threshold +{threshold:.0%}) ---", file=sys.stderr)
    for r in records:
        base = old.get((r["suite"], r["name"]))
        new = r["us_per_call"]
        if new is None:
            if str(r.get("derived", "")).startswith("ERROR:"):
                print(f"# {r['suite']}/{r['name']}: ERRORED this run "
                      f"({r['derived']})", file=sys.stderr)
                problems.append({**r, "problem": "errored"})
            # else: a correctness-only row (nan us by design) — no gate
            continue
        if not base:
            # new row, or the baseline errored there (None) or recorded
            # 0 us: no meaningful ratio to gate on
            continue
        ratio = new / base
        flag = (" REGRESSION" if comparable and not structural
                and ratio > 1 + threshold else "")
        print(f"# {r['suite']}/{r['name']}: {base:.1f} -> {new:.1f} us "
              f"({ratio - 1:+.0%} vs baseline){flag}", file=sys.stderr)
        if flag:
            problems.append({**r, "problem": "regression",
                             "baseline_us": base, "ratio": ratio})
    if structural:
        print("# (structural mode: timing regressions reported, "
              "not gated)", file=sys.stderr)
        for suite, prefixes in REQUIRED_PREFIXES.items():
            if suite not in executed:
                continue
            for pre in prefixes:
                ok = any(s == suite and n.startswith(pre)
                         and not str(r.get("derived", "")
                                     ).startswith("ERROR:")
                         for (s, n), r in fresh.items())
                if not ok:
                    print(f"# {suite}: REQUIRED row prefix {pre!r} "
                          f"produced no healthy rows", file=sys.stderr)
                    problems.append({"suite": suite, "name": pre,
                                     "problem": "required-missing"})
    if not comparable:
        print("# (regression/missing gates off: run quick/impl settings "
              "differ from the baseline's)", file=sys.stderr)
    else:
        for (suite, name), base in old.items():
            if suite in executed and (suite, name) not in fresh:
                print(f"# {suite}/{name}: MISSING from this run "
                      f"(baseline {base} us)", file=sys.stderr)
                problems.append({"suite": suite, "name": name,
                                 "problem": "missing", "baseline_us": base})
    if problems:
        kinds = {}
        for p in problems:
            kinds[p["problem"]] = kinds.get(p["problem"], 0) + 1
        desc = ", ".join(f"{v} {k}" for k, v in sorted(kinds.items()))
        print(f"# gate FAILS: {desc} (threshold +{threshold:.0%})",
              file=sys.stderr)
    else:
        print("# no regressions, no missing/errored rows", file=sys.stderr)
    return problems


if __name__ == "__main__":
    main()
