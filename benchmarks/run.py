"""Benchmark suite driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a header comment per
section).  ``--quick`` shrinks iteration counts for CI.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: topologies,scaling,"
                         "straggler,packet_loss,heterogeneity,kernels")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from . import (bench_heterogeneity, bench_kernels, bench_packet_loss,
                   bench_scaling, bench_straggler, bench_topologies)

    suites = {
        "topologies": lambda: bench_topologies.run(
            K=4000 if args.quick else 12_000),
        "scaling": lambda: bench_scaling.run(),
        "straggler": lambda: bench_straggler.run(
            rounds=400 if args.quick else 1200),
        "packet_loss": lambda: bench_packet_loss.run(
            K=5000 if args.quick else 14_000),
        "heterogeneity": lambda: bench_heterogeneity.run(
            K=4000 if args.quick else 12_000),
        "kernels": lambda: bench_kernels.run(),
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failed = False
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001
            failed = True
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
