"""Table II / Fig. 5-6: R-FAST vs the five baselines, with and without a
straggler (one node 4x slower).  Metric: virtual time to target loss +
final accuracy.  Reproduces the paper's headline 1.5-2x speedup of R-FAST
over synchronous methods (which pay the straggler at every barrier).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import get_scenario, get_topology
from repro.core.baselines import (run_adpsgd, run_dpsgd, run_osgp,
                                  run_ring_allreduce, run_sab)
from .common import (csv_row, eval_fn_for, logistic_setup,
                     run_rfast_logistic, stopwatch, time_to_loss)


def _grad_mean_adapter(prob):
    """Baselines expect mean-style gradients; rescale Σ-style ∇f_i by n so
    step sizes are comparable across methods."""
    gfn = prob.grad_fn()
    return gfn


def run(target: float = 0.35, n: int = 8, rounds: int = 1200,
        gamma: float = 5e-3) -> list[str]:
    rows = []
    for straggler in (False, True):
        # the registry's canonical profiles (4x last node / all-equal)
        sc = get_scenario("straggler" if straggler else "uniform", n)
        tag = sc.name
        prob = logistic_setup(n)
        gfn = _grad_mean_adapter(prob)
        eval_fn = eval_fn_for(prob)
        K = rounds * n

        # --- R-FAST (async, event-driven) ------------------------------
        state, metrics, wall = run_rfast_logistic(
            prob, "binary_tree", K, gamma=gamma, scenario=sc,
            eval_every=200)
        t_rfast = time_to_loss(metrics, target)
        acc = metrics[-1]["acc"]
        rows.append(csv_row(f"straggler/{tag}/R-FAST", wall / K * 1e6,
                            f"vtime={t_rfast:.1f};acc={acc:.3f};speedup=1.00"))

        topo_d = get_topology("directed_ring", n)
        topo_u = get_topology("undirected_ring", n)
        x0 = jnp.zeros((n, prob.p), jnp.float32)

        def bench_sync(name, fn, *args, **kw):
            with stopwatch() as sw:
                _, ms = fn(*args, scenario=sc, eval_fn=eval_fn,
                           eval_every=25, **kw)
            wall = sw["s"]
            t = time_to_loss(ms, target)
            rows.append(csv_row(
                f"straggler/{tag}/{name}", wall / rounds * 1e6,
                f"vtime={t:.1f};acc={ms[-1]['acc']:.3f};"
                f"speedup={t/t_rfast:.2f}x_slower" if t < np.inf else
                f"vtime=inf;acc={ms[-1]['acc']:.3f}"))

        bench_sync("Ring-AllReduce", run_ring_allreduce, n, gfn,
                   jnp.zeros(prob.p), gamma / 1.0, rounds)
        bench_sync("D-PSGD", run_dpsgd, topo_u, gfn, x0, gamma, rounds)
        bench_sync("S-AB", run_sab, topo_d, gfn, x0, gamma, rounds)

        def bench_async(name, fn, topo, **kw):
            with stopwatch() as sw:
                _, ms = fn(topo, gfn, x0, gamma, K, scenario=sc,
                           eval_fn=eval_fn, eval_every=200, **kw)
            wall = sw["s"]
            t = time_to_loss(ms, target)
            rows.append(csv_row(
                f"straggler/{tag}/{name}", wall / K * 1e6,
                f"vtime={t:.1f};acc={ms[-1]['acc']:.3f};"
                f"ratio={t/t_rfast:.2f}"))

        bench_async("AD-PSGD", run_adpsgd, topo_u)
        bench_async("OSGP", run_osgp, topo_d)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
