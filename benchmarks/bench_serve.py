"""Serving benchmark: continuous-batching throughput, tail latency, and
tail latency THROUGH a live weight swap.

The full async loop in one bench: ``launch/train.py --publish-dir``
trains a reduced LM under the straggler scenario and publishes a
checkpoint per chunk; the serving engine then replays the published
sequence — it starts on the FIRST checkpoint and the later ones are
re-published mid-run at scripted step counts, so the engine's poll/flip
path runs under live Zipfian traffic.  Percentiles are over per-step
engine latency (admissions + one fused decode for all B slots), which is
what a swap could stall; ``swap_p99_us`` is the same percentile
restricted to swap-affected steps (the manifest-poll/npz-load step and
the flip step), and the committed gate holds it within 2x ``p99_us``.

``staleness_vs_loss`` is the correctness row (nan us by design): for
every checkpoint that answered requests, the mean checkpoint age at
answer time and the eval loss of those weights on the training
objective — later checkpoints must serve strictly lower loss.
"""
from __future__ import annotations

import tempfile

import numpy as np


def run(quick: bool = False, seed: int = 0) -> list[str]:
    import jax

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.configs import get_config
    from repro.core.paramvec import ravel
    from repro.data.objectives import make_lm_problem
    from repro.launch import train
    from repro.models.transformer import init_params
    from repro.serve import (ServeEngine, Scheduler, WeightStore,
                             cache as serve_cache, make_workload)
    from .common import csv_row

    arch, nodes, seq = "llama3-8b", 3, 16
    steps = 9 if quick else 18
    n_requests = 48 if quick else 160
    B = 8

    pub = tempfile.mkdtemp(prefix="bench_serve_pub_")
    res = train.main(["--arch", arch, "--reduced", "--nodes", str(nodes),
                     "--steps", str(steps), "--batch-per-node", "2",
                      "--seq", str(seq), "--scenario", "straggler",
                      "--log-every", str(max(1, steps // 3)),
                      "--seed", str(seed), "--publish-dir", pub])
    published = res["published"]
    cfg = get_config(arch).reduced()
    template = init_params(cfg, jax.random.PRNGKey(seed))
    trees = {k: load_checkpoint(pub, template, step=k) for k in published}

    # serve dir replays the published sequence: checkpoint 0 up front,
    # the rest re-published at scripted engine steps below
    serve_dir = tempfile.mkdtemp(prefix="bench_serve_live_")
    save_checkpoint(serve_dir, published[0], trees[published[0]])

    store = WeightStore(jax.device_put(trees[published[0]]),
                        step=published[0])
    serve_cache.clear()
    eng = ServeEngine(cfg, store, batch=B, max_len=64,
                      buckets=(4, 8, 16), poll_every=4,
                      ckpt_dir=serve_dir)

    warm = make_workload(3 * B, vocab=cfg.vocab, max_prompt=16, max_gen=4,
                         seed=seed + 1)
    eng.run(warm)
    eng.step_records.clear()
    warm_stats = dict(serve_cache.stats())

    reqs = make_workload(n_requests, vocab=cfg.vocab, max_prompt=16,
                         max_gen=8, rate_rps=0.0, s=1.2, seed=seed + 2)
    est_steps = max(3, sum(r.gen for r in reqs) // B)
    triggers = {max(1, est_steps // 3): published[1]} if len(published) > 1 \
        else {}
    if len(published) > 2:
        triggers[max(2, 2 * est_steps // 3)] = published[2]

    sched = Scheduler(reqs)
    import time
    t0 = time.perf_counter()
    fired = set()
    while len(sched) or eng.in_flight or store.staged:
        for trig, k in triggers.items():
            if eng._step >= trig and k not in fired:
                save_checkpoint(serve_dir, k, trees[k])
                fired.add(k)
        eng.step(sched)
    wall = time.perf_counter() - t0

    done = [r for r in reqs if r.done]
    step_us = [r["us"] for r in eng.step_records]
    swap_us = [r["us"] for r in eng.step_records if r["swap"]]
    p50 = float(np.percentile(step_us, 50))
    p99 = float(np.percentile(step_us, 99))
    swap_p99 = float(np.percentile(swap_us, 99)) if swap_us else p50
    rps = len(done) / wall
    end_stats = dict(serve_cache.stats())
    steady = (end_stats["misses"] == warm_stats["misses"])

    # eval loss of each serving checkpoint on the training objective,
    # paired with the mean checkpoint age at answer time
    prob = make_lm_problem(cfg, nodes, batch_per_node=2, seq_len=seq,
                           seed=seed)
    pairs = []
    for k in sorted({r.weights_step for r in done}):
        served = [r for r in done if r.weights_step == k]
        age = float(np.mean([r.weights_age_s for r in served]))
        loss = float(prob.mean_loss(ravel(prob.spec, trees[k])))
        pairs.append((k, age, loss, len(served)))

    rows = [
        csv_row("serve/reqs_per_s", 1e6 / rps,
                f"rps={rps:.2f};served={len(done)}/{len(reqs)};B={B};"
                f"steady_state={steady};entries={end_stats['entries']}"),
        csv_row("serve/p50_us", p50, f"steps={len(step_us)}"),
        csv_row("serve/p99_us", p99, f"steps={len(step_us)}"),
        csv_row("serve/swap_p99_us", swap_p99,
                f"swap_steps={len(swap_us)};swaps={len(store.swaps)};"
                f"ratio_vs_p99={swap_p99 / p99:.2f}"),
        csv_row("serve/staleness_vs_loss", float("nan"),
                "|".join(f"step{k}:age_s={a:.3f}:loss={l:.4f}:reqs={m}"
                         for k, a, l, m in pairs)),
    ]
    return rows

if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("\n".join(run(quick=args.quick, seed=args.seed)))
