"""End-to-end driver: train an LM with the production R-FAST runtime.

Default is a CI-scale reduced model; pass ``--full`` to train the real
~100M-param ``rfast-100m`` config for a few hundred steps (hours on CPU,
minutes on real accelerators).

    PYTHONPATH=src python examples/train_rfast.py                  # smoke
    PYTHONPATH=src python examples/train_rfast.py --full --steps 300
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
ap.add_argument("--steps", type=int, default=0)
ap.add_argument("--loss-prob", type=float, default=0.1,
                help="simulated packet loss (exercises robust tracking)")
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "rfast-100m",
       "--nodes", "4", "--topology", "binary_tree",
       "--loss-prob", str(args.loss_prob),
       "--ckpt", "/tmp/rfast_ckpt"]
if args.full:
    cmd += ["--steps", str(args.steps or 300), "--seq", "512",
            "--batch-per-node", "8", "--gamma", "1e-3"]
else:
    cmd += ["--reduced", "--steps", str(args.steps or 60), "--seq", "64",
            "--batch-per-node", "2"]
raise SystemExit(subprocess.call(cmd))
