"""End-to-end driver: train an LM with the R-FAST protocol.

Default is a CI-scale reduced model through the synchronous production
runtime; pass ``--full`` to train the real ~100M-param ``rfast-100m``
config for a few hundred steps (hours on CPU, minutes on real
accelerators).  Pass ``--scenario <name>`` to train *fully
asynchronously* instead: the named NetworkScenario (stragglers, lossy
links, crash/recovery — see ``repro.core.scenario.SCENARIOS``) is
realized into a per-event trace and the model rides the wavefront
engine on the flat-parameter substrate.

    PYTHONPATH=src python examples/train_rfast.py                  # smoke
    PYTHONPATH=src python examples/train_rfast.py --full --steps 300
    PYTHONPATH=src python examples/train_rfast.py --scenario straggler
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
ap.add_argument("--steps", type=int, default=0)
ap.add_argument("--scenario", default="",
                help="train asynchronously under a named NetworkScenario "
                     "(e.g. straggler, packet_loss, crash_recovery)")
ap.add_argument("--loss-prob", type=float, default=0.1,
                help="simulated packet loss in the synchronous rounds "
                     "(exercises robust tracking); ignored with --scenario")
args = ap.parse_args()

# ckpt dirs are regime- and scale-specific: the sync runtime persists a
# ProtocolState pytree, --scenario a flat RFASTState, and --full a
# different parameter count — mixing them in one dir cannot resume
ckpt = (f"/tmp/rfast_ckpt_{args.scenario or 'sync'}"
        f"_{'full' if args.full else 'reduced'}")
cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "rfast-100m",
       "--nodes", "4", "--topology", "binary_tree",
       "--ckpt", ckpt]
if args.scenario:
    cmd += ["--scenario", args.scenario]   # the scenario owns loss/delay
else:
    cmd += ["--loss-prob", str(args.loss_prob)]
if args.full:
    cmd += ["--steps", str(args.steps or 300), "--seq", "512",
            "--batch-per-node", "8", "--gamma", "1e-3"]
else:
    cmd += ["--reduced", "--steps", str(args.steps or 60), "--seq", "64",
            "--batch-per-node", "2"]
raise SystemExit(subprocess.call(cmd))
