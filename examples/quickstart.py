"""Quickstart: train logistic regression with R-FAST over a binary tree,
fully asynchronously, with packet loss — in ~30 lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import binary_tree, generate_schedule, run_rfast
from repro.data import make_logistic_problem

N_NODES = 7

# 1. node-local data shards (heterogeneous: label-sorted, large ς)
prob = make_logistic_problem(N_NODES, m=2800, d=64, batch=16,
                             heterogeneous=True)

# 2. two spanning-tree communication graphs W (pull) / A (push) rooted at 0
topo = binary_tree(N_NODES)
print("common roots:", topo.roots())

# 3. an asynchronous schedule: node 6 is a 4x straggler, 20% packet loss
sched = generate_schedule(
    topo, 12_000,
    compute_time=[1, 1, 1, 1, 1, 1, 4.0],
    loss_prob=0.2, latency=0.3, seed=0)
print(f"realized delay bound D={sched.D}, activation bound T={sched.T}")


# 4. run the exact Algorithm-2 recursion
def eval_fn(state, t):
    x_bar = jnp.asarray(state.x).mean(0)
    return {"loss": float(prob.mean_loss(x_bar)),
            "acc": float(prob.accuracy(x_bar)), "t": t}


state, metrics = run_rfast(
    topo, sched, prob.grad_fn(),
    x0=jnp.zeros((N_NODES, prob.p)), gamma=5e-3,
    eval_every=2000, eval_fn=eval_fn)

for m in metrics:
    print(f"k={m['k']:6d}  vtime={m['t']:8.1f}  "
          f"loss={m['loss']:.4f}  acc={m['acc']:.3f}")
