"""Fig. 4a reproduction: R-FAST over five topologies, loss-vs-epoch table,
plus a dynamic-graph coda: the sole common root of ``robust_tree``
departs mid-run and the epochized engine re-elects a new root on the
surviving subgraph (DESIGN.md §11).

    PYTHONPATH=src python examples/topology_zoo.py
"""
import jax.numpy as jnp

from repro.core import (generate_schedule, get_scenario, get_topology,
                        run_epochs, run_rfast)
from repro.data import make_logistic_problem

n, K = 7, 10_000
prob = make_logistic_problem(n, m=2800, d=64, batch=16, heterogeneous=True)

print(f"{'topology':>16} | common roots | final loss | acc")
print("-" * 55)
for name in ("binary_tree", "line", "directed_ring", "exponential",
             "mesh2d"):
    topo = get_topology(name, n)
    sched = generate_schedule(topo, K, latency=0.3, seed=0)
    state, _ = run_rfast(topo, sched, prob.grad_fn(),
                         jnp.zeros((n, prob.p)), gamma=5e-3)
    x_bar = jnp.asarray(state.x).mean(0)
    print(f"{name:>16} | {str(topo.roots()):>12} | "
          f"{float(prob.mean_loss(x_bar)):10.4f} | "
          f"{float(prob.accuracy(x_bar)):.3f}")

# ------------------------------------------------------------------ #
# mid-run root re-election: node 0 (the ONLY common root of the tree)
# leaves permanently; the trace splits into topology epochs and the
# engine migrates state onto a rebuilt plan rooted at a survivor.
# ------------------------------------------------------------------ #
print("\nroot failover on robust_tree (sole common root departs):")
topo = get_topology("robust_tree", n)
trace = get_scenario("root_failover", n).realize_epochs(topo, K, seed=0)
for i, ep in enumerate(trace.epochs):
    act = int(ep.topology.active_mask().sum())
    print(f"  epoch {i}: t0={ep.t0:6.1f}  events {ep.k0}..{ep.k0 + ep.K}"
          f"  root={ep.root}  active={act}/{n}  graph={ep.topology.name}")
state, _ = run_epochs(trace, prob.grad_fn(), jnp.zeros((n, prob.p)),
                      gamma=5e-3, seed=0)
alive = trace.epochs[-1].topology.active_mask()
x_bar = jnp.asarray(state.x)[alive].mean(0)
print(f"  survivors' final loss {float(prob.mean_loss(x_bar)):.4f} | "
      f"acc {float(prob.accuracy(x_bar)):.3f}")
