"""Fig. 4a reproduction: R-FAST over five topologies, loss-vs-epoch table.

    PYTHONPATH=src python examples/topology_zoo.py
"""
import jax.numpy as jnp

from repro.core import generate_schedule, get_topology, run_rfast
from repro.data import make_logistic_problem

n, K = 7, 10_000
prob = make_logistic_problem(n, m=2800, d=64, batch=16, heterogeneous=True)

print(f"{'topology':>16} | common roots | final loss | acc")
print("-" * 55)
for name in ("binary_tree", "line", "directed_ring", "exponential",
             "mesh2d"):
    topo = get_topology(name, n)
    sched = generate_schedule(topo, K, latency=0.3, seed=0)
    state, _ = run_rfast(topo, sched, prob.grad_fn(),
                         jnp.zeros((n, prob.p)), gamma=5e-3)
    x_bar = jnp.asarray(state.x).mean(0)
    print(f"{name:>16} | {str(topo.roots()):>12} | "
          f"{float(prob.mean_loss(x_bar)):10.4f} | "
          f"{float(prob.accuracy(x_bar)):.3f}")
