"""Straggler robustness head-to-head (the paper's Table II story):
R-FAST vs Ring-AllReduce vs OSGP with one 4x-slow node — every
algorithm on the SAME NetworkScenario virtual clock (the runnable doc
for DESIGN.md §7).

    PYTHONPATH=src python examples/straggler_robustness.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (binary_tree, directed_ring, generate_schedule,
                        get_scenario, run_rfast)
from repro.core.baselines import run_osgp, run_ring_allreduce
from repro.data import make_logistic_problem

n, target = 8, 0.35
scenario = get_scenario("straggler", n)   # last node 4x slow, latency 0.3
prob = make_logistic_problem(n, m=2800, d=64, batch=16, heterogeneous=True)
gfn = prob.grad_fn()


def eval_fn(x, t):
    xb = jnp.asarray(x.x if hasattr(x, "x") else x)
    if xb.ndim == 2:
        xb = xb.mean(0)
    return {"loss": float(prob.mean_loss(xb)), "t": t}


def t_to(ms):
    return next((m["t"] for m in ms if m["loss"] <= target), float("inf"))


K = 9600
# one scenario realization drives R-FAST's schedule...
sched = generate_schedule(binary_tree(n), K, scenario=scenario)
_, ms = run_rfast(binary_tree(n), sched, gfn, jnp.zeros((n, prob.p)),
                  gamma=5e-3, eval_every=300, eval_fn=eval_fn)
t_rfast = t_to(ms)
print(f"R-FAST         : vtime-to-loss={t_rfast:8.1f}  (1.00x)")

# ... the same scenario's barrier clock prices the synchronous rounds ...
rounds = K // n
_, ms = run_ring_allreduce(n, gfn, jnp.zeros(prob.p), 5e-3, rounds,
                           scenario=scenario, eval_fn=eval_fn,
                           eval_every=30)
t_ring = t_to(ms)
print(f"Ring-AllReduce : vtime-to-loss={t_ring:8.1f}  "
      f"({t_ring/t_rfast:.2f}x slower — pays the straggler every barrier)")

# ... and the same scenario's event clock drives OSGP's pushes.
_, ms = run_osgp(directed_ring(n), gfn, jnp.zeros((n, prob.p)), 5e-3, K,
                 scenario=scenario, eval_fn=eval_fn, eval_every=300)
t_osgp = t_to(ms)
print(f"OSGP           : vtime-to-loss={t_osgp:8.1f}  "
      f"({t_osgp/t_rfast:.2f}x)")
